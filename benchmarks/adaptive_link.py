"""Adaptive compression under link degradation: what does annealing the
rank to the *measured* link buy, and what does it cost?

Runs the SAME scenario (4 clusters, hub outer sync, one cluster's uplink
degraded mid-run, real ``core/diloco.py`` rounds on the quadratic problem)
with a fixed rank and with the bandwidth-aware controller modes, and
reports:

 - **round time through the degraded window**: the fixed-rank run eats the
   full exposed comm of an oversized payload on the slow link; the
   bandwidth/hybrid controller drops r_t so the outer sync keeps fitting
   the §2.3 overlap budget;
 - **consensus-loss gap at equal wall-clock**: compressing harder during
   the window costs per-round accuracy, but the adaptive run finishes its
   rounds sooner; at the adaptive run's total elapsed time, its loss must
   be within the stated tolerance of whatever the fixed-rank run had
   reached by that same time (one-sided: being better is not a failure);
 - **per-EDGE ranks under gossip**: on a ring, only the degraded cluster's
   own edges drop rank; healthy edges keep shipping full-rank factors.

  python -m benchmarks.adaptive_link [--fast] [--json out.json]

Exit status is non-zero if either acceptance criterion fails.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Any, Dict

import numpy as np

from repro.core.adaptive import AdaptiveSpec
from repro.sim import (FaultSchedule, LinkProfile, QuadraticSpec, Scenario,
                       simulate)
from repro.sim.faults import LinkDegradation

N_CLUSTERS = 4
R1 = 8
# stated acceptance tolerances:
#  - the adaptive run's degraded-window mean round time must undercut the
#    fixed-rank run by at least this factor;
#  - at the adaptive run's total wall-clock, its loss may exceed the loss
#    the fixed-rank run had reached by that same elapsed time by at most
#    LOSS_TOL_REL (relative, one-sided) + LOSS_TOL_ABS (floor).
TIME_GAIN_MIN = 1.5
LOSS_TOL_REL = 0.25
LOSS_TOL_ABS = 1e-3


def build_scenario(rounds: int, window: slice, **kw) -> Scenario:
    base = dict(
        n_clusters=N_CLUSTERS, rounds=rounds, h_steps=4, t_step_s=0.05,
        link=LinkProfile(bytes_per_s=200_000),
        faults=FaultSchedule((LinkDegradation(window.start, window.stop,
                                              factor=0.05, cluster=1),)),
        compressor="diloco_x",
        compressor_kw={"rank": R1, "min_dim_for_lowrank": 8}, rank=R1,
        n_params=2e5, seed=0)
    base.update(kw)
    return Scenario(**base)


def run(fast: bool = False) -> Dict[str, Any]:
    rounds = 8 if fast else 14
    window = slice(rounds // 4, (3 * rounds) // 4)
    spec = QuadraticSpec(n_clusters=N_CLUSTERS, d=16, n_mats=2, h_steps=4,
                         seed=0)
    variants = {
        "fixed": None,
        "bandwidth": AdaptiveSpec(mode="bandwidth", r1=R1, r_min=2,
                                  window=3),
        "hybrid": AdaptiveSpec(mode="hybrid", r1=R1, r_min=2, window=3),
    }
    out: Dict[str, Any] = {
        "rounds": rounds, "degraded_rounds": [window.start, window.stop],
        "time_gain_min": TIME_GAIN_MIN,
        "loss_tol_rel": LOSS_TOL_REL, "loss_tol_abs": LOSS_TOL_ABS,
        "variants": {},
    }
    for name, ada in variants.items():
        sc = build_scenario(rounds, window, adaptive=ada)
        tl = simulate(sc, numeric=spec.problem())
        win = tl.events[window]
        out["variants"][name] = {
            "rank_schedule": tl.rank_schedule(),
            "round_s": [round(e.t_round_s, 6) for e in tl.events],
            "degraded_mean_round_s": float(np.mean([e.t_round_s
                                                    for e in win])),
            "total_time_s": round(tl.total_time_s, 6),
            "total_wire_bytes": tl.total_wire_bytes,
            "losses": [None if e.loss is None else round(e.loss, 6)
                       for e in tl.events],
            "final_loss": tl.losses()[-1],
            "timeline_table": tl.table(),
        }

    # gossip leg: per-EDGE ranks on a ring — only the degraded cluster's
    # own edges compress harder (bandwidth mode keeps the healthy edges at
    # r1, making the per-edge property directly assertable)
    sc_ring = build_scenario(
        rounds, window, topology="ring",
        adaptive=AdaptiveSpec(mode="bandwidth", r1=R1, r_min=2, window=3))
    tl_ring = simulate(sc_ring, numeric=spec.problem())
    ring_rows = [list(e.ranks) for e in tl_ring.events]
    win_rows = ring_rows[window]
    per_edge_ok = (
        all(row[1] < R1 for row in win_rows)             # degraded uplink…
        and all(row[c] == R1 for row in win_rows         # …its edges only
                for c in (0, 2, 3)))
    out["gossip_ring"] = {
        "ranks_per_round": ring_rows,
        "per_edge_isolation_ok": per_edge_ok,
    }

    fixed = out["variants"]["fixed"]
    bw = out["variants"]["bandwidth"]
    gain = (fixed["degraded_mean_round_s"]
            / max(bw["degraded_mean_round_s"], 1e-12))
    # equal-wall-clock comparison: at the adaptive run's total elapsed
    # time, which loss had each run reached?  (The adaptive run has its
    # final loss; the fixed run has completed only the rounds whose
    # cumulative time fits the same budget.)
    t_budget = bw["total_time_s"]
    cum = np.cumsum(fixed["round_s"])
    done = int(np.searchsorted(cum, t_budget + 1e-9, side="right"))
    fixed_loss_at_budget = (fixed["losses"][done - 1] if done
                            else float("inf"))
    loss_gap = bw["final_loss"] - fixed_loss_at_budget
    loss_ok = loss_gap <= LOSS_TOL_ABS + LOSS_TOL_REL * abs(
        fixed_loss_at_budget)
    out["criteria"] = {
        "degraded_round_time_gain": round(gain, 4),
        "time_recovered": gain >= TIME_GAIN_MIN,
        "wallclock_budget_s": t_budget,
        "fixed_rounds_done_at_budget": done,
        "loss_fixed_at_budget": fixed_loss_at_budget,
        "loss_bandwidth_at_budget": bw["final_loss"],
        "final_loss_gap_at_budget": loss_gap,
        "final_loss_gap_at_equal_rounds": (bw["final_loss"]
                                           - fixed["final_loss"]),
        "loss_within_tol": loss_ok,
        "per_edge_isolation_ok": per_edge_ok,
        "ok": (gain >= TIME_GAIN_MIN) and loss_ok and per_edge_ok,
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default="")
    args = ap.parse_args()

    out = run(fast=args.fast)
    lo, hi = out["degraded_rounds"]
    print(f"degraded link: cluster 1 x0.05 @ rounds [{lo},{hi})")
    print(f"{'variant':>10} {'win_round_s':>12} {'total_s':>9} "
          f"{'final_loss':>11}  rank schedule")
    for name, row in out["variants"].items():
        sched = " ".join("-" if r is None else str(r)
                         for r in row["rank_schedule"])
        print(f"{name:>10} {row['degraded_mean_round_s']:>12.3f} "
              f"{row['total_time_s']:>9.2f} {row['final_loss']:>11.4f}  "
              f"{sched}")
    print("\n--- bandwidth-adaptive timeline ---")
    print(out["variants"]["bandwidth"]["timeline_table"])
    crit = out["criteria"]
    print(f"\ndegraded-window round time: fixed/bandwidth = "
          f"{crit['degraded_round_time_gain']:.2f}x (need >= "
          f"{TIME_GAIN_MIN}x)  => "
          f"{'PASS' if crit['time_recovered'] else 'FAIL'}")
    print(f"loss at equal wall-clock ({crit['wallclock_budget_s']:.2f}s): "
          f"bandwidth {crit['loss_bandwidth_at_budget']:.4f} vs fixed "
          f"{crit['loss_fixed_at_budget']:.4f} (after "
          f"{crit['fixed_rounds_done_at_budget']} rounds; signed gap "
          f"{crit['final_loss_gap_at_budget']:+.4f}, tol {LOSS_TOL_ABS} + "
          f"{LOSS_TOL_REL:.0%} rel, one-sided)  => "
          f"{'PASS' if crit['loss_within_tol'] else 'FAIL'}")
    print(f"ring per-edge isolation (only the degraded uplink drops rank): "
          f"{'PASS' if crit['per_edge_isolation_ok'] else 'FAIL'}")

    if args.json:
        for row in out["variants"].values():
            row.pop("timeline_table", None)
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.json}")
    sys.exit(0 if crit["ok"] else 1)


if __name__ == "__main__":
    main()
