"""Roofline table (spec deliverable g): reads the dry-run matrix JSON and
emits per (arch x shape x mesh) the three roofline terms, the dominant
bottleneck, MODEL_FLOPS / HLO_FLOPS utilization, and the amortized outer
(1 Gbps) term. This is the §Roofline source of record."""
from __future__ import annotations

import json
import math
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 0.125e9


def model_flops(arch: str, shape_name: str) -> float:
    """6*N(active)*tokens for train; 2*N for one decode token; prefill
    2*N*tokens (fwd only)."""
    from repro.configs.base import SHAPES, get_config
    from repro.models.model import count_active_params

    cfg = get_config(arch)
    n = count_active_params(cfg)
    s = SHAPES[shape_name]
    tokens = s.global_batch * s.seq_len
    if s.kind == "train":
        return 6.0 * n * tokens
    if s.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * s.global_batch          # one token per sequence


def build_rows(results: List[dict], h_steps: int = 125) -> List[dict]:
    rows = []
    for r in results:
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "multi_pod": r.get("multi_pod"),
                         "status": r.get("status"),
                         "reason": r.get("reason", r.get("error", ""))[:120]})
            continue
        key = [k for k in ("train", "prefill", "decode") if k in r][0]
        a = r[key]
        n_chips = 512 if r.get("multi_pod") else 256
        mf = model_flops(r["arch"], r["shape"])
        # compute/memory terms anchored on the analytic model (XLA
        # cost_analysis counts scan bodies once — see benchmarks/analytic);
        # collectives + footprint from the compiled artifact.
        from benchmarks.analytic import analytic_terms
        at = analytic_terms(r["arch"], r["shape"], n_chips=n_chips,
                            multi_pod=bool(r.get("multi_pod")))
        t_c, t_m = at.t_compute, at.t_memory
        t_i = a["t_collective_ici"]
        t_d = a.get("t_collective_dcn_1gbps", 0.0)
        terms = {"compute": t_c, "memory": t_m, "ici": t_i, "dcn": t_d}
        dominant = max(terms, key=terms.get)
        row = {
            "arch": r["arch"], "shape": r["shape"],
            "multi_pod": bool(r.get("multi_pod")), "kind": key,
            "status": "ok",
            "t_compute_s": t_c, "t_memory_s": t_m,
            "t_ici_s": t_i, "t_dcn_s": t_d,
            "dominant": dominant,
            "analytic_flops_per_dev": at.flops_per_dev,
            "hlo_flops_per_dev": a["hlo_flops_per_device"],
            "hlo_scan_undercount_x": at.flops_per_dev / max(
                a["hlo_flops_per_device"], 1.0),
            "model_flops_total": mf,
            "useful_flops_frac": (mf / n_chips) / max(at.flops_per_dev, 1.0),
            "mem_gb_per_dev": a["per_device_memory_bytes"] / 1e9,
            "fits_v5e_16g": a["per_device_memory_bytes"] < 16e9,
        }
        if "outer" in r:
            o = r["outer"]
            # amortized 1 Gbps outer term per inner step
            cross = o.get("cross_cluster_bytes", 0)
            row["outer_cross_cluster_mb"] = cross / 1e6
            row["outer_dcn_s"] = cross / DCN_BW
            row["outer_amortized_frac"] = (
                cross / DCN_BW / max(h_steps * max(t_c, t_m), 1e-9))
        rows.append(row)
    return rows


def outer_step_rows(rank: int = 2048, block: int = 256) -> List[dict]:
    """Analytic fused-vs-unfused Alg. 1 outer-step compressor cost at the
    full 107B-config matrix shapes (paper rank 2048), on the v5e roofline
    constants above.

    FLOPs are identical either way (3 rank-r projections + Cholesky-QR);
    what fusion changes is HBM traffic.  Per-element passes over the
    (m, n) matrix: the unfused chain pays ~11 (EF add read x2 + write,
    three matmul reads of M, reconstruct write + read x2 for the EF
    residual and the cast, residual/cast writes), the fused pipeline ~8
    (each of the three kernels streams delta+e once, reconstruct and
    residual never round-trip).  Factor traffic: ~7 (m+n) r unfused
    (projection writes, orthonormalize, separate quantize+pack+unpack
    passes) vs ~3 (m+n) r fused (pack in the projection flush, dequant
    inside the reconstruct kernel).  Wire time is the int4+scales payload
    at the paper's 1 Gbps inter-cluster link — the column that decides
    whether the outer step stays wire-dominated (the overlap budget of
    §2.3 only has to hide max(compute, wire))."""
    shapes = [("attn_qkv_8192x8192", 8192, 8192),
              ("mlp_up_8192x49152", 8192, 49152),
              ("mlp_down_49152x8192", 49152, 8192)]
    rows = []
    for name, m, n in shapes:
        r = min(rank, m, n)
        flops = 6.0 * m * n * r + 4.0 * m * r * r + (4.0 / 3.0) * r ** 3
        bytes_unfused = 4.0 * (11 * m * n + 7 * (m + n) * r)
        bytes_fused = 4.0 * (8 * m * n + 3 * (m + n) * r)
        t_unf = max(flops / PEAK_FLOPS, bytes_unfused / HBM_BW)
        t_fus = max(flops / PEAK_FLOPS, bytes_fused / HBM_BW)
        wire_bytes = (m + n) * r / 2 + math.ceil((m + n) * r / block) * 2
        t_wire = wire_bytes / DCN_BW
        rows.append({
            "matrix": name, "m": m, "n": n, "rank": r,
            "gflops": flops / 1e9,
            "hbm_mb_unfused": bytes_unfused / 1e6,
            "hbm_mb_fused": bytes_fused / 1e6,
            "hbm_traffic_cut_x": bytes_unfused / bytes_fused,
            "t_outer_unfused_s": t_unf,
            "t_outer_fused_s": t_fus,
            "t_wire_1gbps_s": t_wire,
            "wire_dominated": t_wire > t_fus,
            "outer_compute_frac_of_wire": t_fus / t_wire,
        })
    return rows


def advice(row: dict) -> str:
    d = row.get("dominant")
    if d == "memory":
        return ("memory-bound: fuse/bf16 the f32 chains, bigger per-device "
                "batch, or Pallas-fused attention to cut HBM traffic")
    if d == "compute":
        return "compute-bound: near roofline; only kernel-level wins left"
    if d == "ici":
        return ("collective-bound: reshard (fewer all-gathers), overlap "
                "collectives with compute, or switch TP<->FSDP mix")
    return "DCN-bound: raise H or compression ratio (Alg. 3)"


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    rows = build_rows(results)
    print(f"{'arch':24s} {'shape':12s} {'mesh':6s} {'dom':7s} "
          f"{'t_comp':>9s} {'t_mem':>9s} {'t_ici':>9s} {'useful%':>8s} "
          f"{'GB/dev':>7s}")
    for row in rows:
        if row.get("status") != "ok":
            print(f"{row['arch']:24s} {row['shape']:12s} "
                  f"{'mp' if row.get('multi_pod') else 'sp':6s} "
                  f"-- {row.get('status')}: {row.get('reason', '')[:60]}")
            continue
        print(f"{row['arch']:24s} {row['shape']:12s} "
              f"{'mp' if row['multi_pod'] else 'sp':6s} "
              f"{row['dominant']:7s} {row['t_compute_s']:9.4f} "
              f"{row['t_memory_s']:9.4f} {row['t_ici_s']:9.4f} "
              f"{100*row['useful_flops_frac']:7.1f}% "
              f"{row['mem_gb_per_dev']:7.1f}")


if __name__ == "__main__":
    main()
