"""Kernel microbenchmarks: us/call for the compressor/attention hot spots,
jnp reference path vs Pallas interpret path (interpret mode measures the
Python-executed kernel body — correctness-lane numbers, not TPU numbers;
the BlockSpec tiling is what carries to hardware)."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(smoke: bool = False) -> Dict[str, float]:
    """``smoke``: shrink inputs and skip the Pallas interpret paths (their
    Python-executed kernel bodies are the slow part) — a seconds-scale
    bit-rot check of every jnp reference path for CI."""
    from repro.kernels import ref

    out = {}
    n = 1 << 16 if smoke else 1 << 20
    tag = "64k" if smoke else "1M"
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    out[f"quant4_pack_ref_{tag}"] = _time(
        jax.jit(lambda v: ref.quant4_pack_ref(v)[0]), x)

    d = 256 if smoke else 1024
    a = jax.random.normal(jax.random.PRNGKey(1), (d, d))
    b = jax.random.normal(jax.random.PRNGKey(2), (d, 128))
    out[f"powersgd_proj_ref_{d}x{d}xr128"] = _time(
        jax.jit(ref.matmul_ref), a, b)

    s = 256 if smoke else 1024
    q = jax.random.normal(jax.random.PRNGKey(3), (1, s, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, s, 1, 64))
    out[f"flash_attn_ref_{s}"] = _time(
        jax.jit(lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_)),
        q, k, k)

    if not smoke:
        from repro.kernels.lowrank_mm import matmul_pallas
        from repro.kernels.quant4 import quant4_pack_pallas
        out["quant4_pack_pallas_1M"] = _time(
            lambda v: quant4_pack_pallas(v)[0], x, iters=2)
        out["powersgd_proj_pallas"] = _time(matmul_pallas, a, b, iters=2)
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k},{v:.1f},us_per_call")
