"""Kernel microbenchmarks: us/call for the compressor/attention hot spots,
jnp reference path vs Pallas interpret path (interpret mode measures the
Python-executed kernel body — correctness-lane numbers, not TPU numbers;
the BlockSpec tiling is what carries to hardware)."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> Dict[str, float]:
    from repro.kernels import ref
    from repro.kernels.quant4 import quant4_pack_pallas
    from repro.kernels.lowrank_mm import matmul_pallas

    out = {}
    x = jax.random.normal(jax.random.PRNGKey(0), (1 << 20,))
    out["quant4_pack_ref_1M"] = _time(
        jax.jit(lambda v: ref.quant4_pack_ref(v)[0]), x)
    out["quant4_pack_pallas_1M"] = _time(
        lambda v: quant4_pack_pallas(v)[0], x, iters=2)

    a = jax.random.normal(jax.random.PRNGKey(1), (1024, 1024))
    b = jax.random.normal(jax.random.PRNGKey(2), (1024, 128))
    out["powersgd_proj_ref_1024x1024xr128"] = _time(
        jax.jit(ref.matmul_ref), a, b)
    out["powersgd_proj_pallas"] = _time(matmul_pallas, a, b, iters=2)

    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1024, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 1024, 1, 64))
    out["flash_attn_ref_1k"] = _time(
        jax.jit(lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_)),
        q, k, k)
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k},{v:.1f},us_per_call")
