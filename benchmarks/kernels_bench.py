"""Kernel microbenchmarks: us/call for the compressor/attention hot spots,
jnp reference path vs Pallas interpret path (interpret mode measures the
Python-executed kernel body — correctness-lane numbers, not TPU numbers;
the BlockSpec tiling is what carries to hardware).

The ``outer_step_*`` section times the full Alg. 1 compressor for one
parameter matrix three ways:

  outer_step_unfused_*   the ref op-chain dispatched op by op (each arrow
                         its own XLA call, every intermediate crossing
                         HBM) — the pre-fusion production shape of the
                         compressor and the "before" side of the tentpole
  outer_step_refjit_*    the same chain under one jax.jit (XLA's own
                         partial fusion — the strongest CPU baseline)
  outer_step_fused_*     the fused Pallas pipeline (kernels/fused_compress)

Shapes are 107B-config per-device shards: d_model 8192 / d_ff 24576 with
4-way tensor sharding gives (2048, 2048) and (2048, 6144) matrices, and
the paper's rank-2048 compressor sharded the same way gives r = 512/1024.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5) -> float:
    # Block on the warm-up so the first timed iteration doesn't absorb
    # in-flight compile/compute, and on every timed dispatch so each
    # iteration pays its full cost (async dispatch otherwise overlaps
    # them and only the last sync is honest).
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _outer_step_bench(out: Dict[str, float], smoke: bool) -> None:
    """Fused-vs-unfused Alg. 1 outer-step compressor (the tentpole's
    before/after numbers)."""
    from repro.kernels import ref
    from repro.kernels.fused_compress import fused_compress_ef

    shapes = ([(256, 256, 32)] if smoke else
              [(2048, 2048, 512), (2048, 6144, 512), (2048, 2048, 1024)])
    iters = 2 if smoke else 3
    for m, n, r in shapes:
        d = jax.random.normal(jax.random.PRNGKey(0), (m, n), jnp.float32)
        e = jax.random.normal(jax.random.PRNGKey(1), (m, n),
                              jnp.float32) * 0.1
        q = jax.random.normal(jax.random.PRNGKey(2), (n, r), jnp.float32)

        def unfused(d_, e_, q_):
            # eager: every chain op is its own XLA dispatch
            return ref.outer_step_ref(d_, e_, q_)[:3]

        refjit = jax.jit(lambda d_, e_, q_: ref.outer_step_ref(d_, e_, q_))
        # row_cap covers the matrix in one tile: on the CPU interpret lane
        # the binding constraint is per-grid-step overhead, not VMEM
        fused = jax.jit(lambda d_, e_, q_: fused_compress_ef(
            d_, e_, q_, row_cap=8192))

        tag = f"{m}x{n}_r{r}"
        t_unf = _time(unfused, d, e, q, iters=iters)
        t_jit = _time(refjit, d, e, q, iters=iters)
        t_fus = _time(fused, d, e, q, iters=iters)
        out[f"outer_step_unfused_{tag}"] = t_unf
        out[f"outer_step_refjit_{tag}"] = t_jit
        out[f"outer_step_fused_{tag}"] = t_fus
        out[f"outer_step_fused_speedup_{tag}"] = t_unf / t_fus


def run(smoke: bool = False) -> Dict[str, float]:
    """``smoke``: shrink inputs and skip the slowest Pallas interpret paths
    (their Python-executed kernel bodies are the slow part) — a
    seconds-scale bit-rot check of every jnp reference path for CI."""
    from repro.kernels import ref

    out = {}
    n = 1 << 16 if smoke else 1 << 20
    tag = "64k" if smoke else "1M"
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    out[f"quant4_pack_ref_{tag}"] = _time(
        jax.jit(lambda v: ref.quant4_pack_ref(v)[0]), x)

    d = 256 if smoke else 1024
    a = jax.random.normal(jax.random.PRNGKey(1), (d, d))
    b = jax.random.normal(jax.random.PRNGKey(2), (d, 128))
    out[f"powersgd_proj_ref_{d}x{d}xr128"] = _time(
        jax.jit(ref.matmul_ref), a, b)

    s = 256 if smoke else 1024
    q = jax.random.normal(jax.random.PRNGKey(3), (1, s, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, s, 1, 64))
    out[f"flash_attn_ref_{s}"] = _time(
        jax.jit(lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_)),
        q, k, k)

    _outer_step_bench(out, smoke)

    if not smoke:
        from repro.kernels.lowrank_mm import matmul_pallas
        from repro.kernels.quant4 import quant4_pack_pallas
        out["quant4_pack_pallas_1M"] = _time(
            lambda v: quant4_pack_pallas(v)[0], x, iters=2)
        out["powersgd_proj_pallas"] = _time(matmul_pallas, a, b, iters=2)
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k},{v:.1f},us_per_call")
