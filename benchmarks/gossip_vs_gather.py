"""Gossip vs gather: what does dropping the hub cost, and what does it buy?

Runs the SAME 8-cluster scenario (same link model, same churn schedule,
same quadratic problem through the real ``core/diloco.py`` rounds) under
the hub/gather outer sync (``star``, the paper's setting) and under
neighbor-gossip mixing graphs (``ring``/``torus``/``random``), and reports:

 - **bytes-on-wire per round** (all links): gossip ships each compressed
   pseudo-gradient to ``deg`` neighbors instead of relaying ``n-1``
   payloads per member through the hub — strictly less for every
   connected graph with max degree < n-1;
 - **convergence gap**: final consensus loss (the quadratic evaluated at
   the alive-mean outer params) vs the gather baseline, with the pass
   tolerance stated in the output;
 - **timeline under churn**: per-round time/loss/disagreement while a
   straggler fires and a cluster leaves and rejoins.

  python -m benchmarks.gossip_vs_gather [--fast] [--json out.json]
  python -m benchmarks.gossip_vs_gather --proc-equivalence   # + the proc
                                  # backend's ring run, gated bit-for-bit

Exit status is non-zero if either acceptance criterion fails.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict

import numpy as np

from repro.sim import (FaultSchedule, Join, Leave, LinkProfile,
                       QuadraticSpec, Scenario, Straggler, simulate)
from repro.topology import MixingMatrix, make_topology

N_CLUSTERS = 8
# stated acceptance tolerance: the gossip final consensus loss may differ
# from gather's by at most this relative margin (plus a small absolute
# floor for near-zero losses)
LOSS_TOL_REL = 0.10
LOSS_TOL_ABS = 1e-3


def build_scenario(topology: str, rounds: int) -> Scenario:
    return Scenario(
        n_clusters=N_CLUSTERS, rounds=rounds, h_steps=4, t_step_s=0.05,
        link=LinkProfile(bytes_per_s=200_000),
        faults=FaultSchedule((
            Straggler(3, 2, 5, 2.5),
            Leave(5, rounds // 3), Join(5, (2 * rounds) // 3),
        )),
        compressor="diloco_x",
        compressor_kw={"rank": 8, "min_dim_for_lowrank": 8}, rank=8,
        n_params=2e5, topology=topology, seed=0)


def _final_consensus_loss(tl, spec: QuadraticSpec) -> float:
    """Quadratic loss at the final *consensus* params: gather keeps one
    global replica; gossip replicas disagree, so evaluate the mean over
    the finally-alive rows (what 'the model' is in a hubless run)."""
    from repro.topology import GOSSIP_KINDS

    eval_fn = spec.problem().eval_fn
    fp = {k: np.asarray(v) for k, v in tl.final_params.items()}
    if tl.scenario["topology"] in GOSSIP_KINDS:        # stacked rows
        alive = list(tl.events[-1].alive)
        fp = {k: v[alive].mean(axis=0) for k, v in fp.items()}
    return float(eval_fn(fp))


def run(fast: bool = False) -> Dict[str, Any]:
    rounds = 6 if fast else 14
    topologies = ["star", "ring"] if fast else ["star", "ring", "torus",
                                                "random"]
    spec = QuadraticSpec(n_clusters=N_CLUSTERS, d=16, n_mats=2, h_steps=4,
                         seed=0)
    out: Dict[str, Any] = {"rounds": rounds, "topologies": {},
                           "loss_tol_rel": LOSS_TOL_REL,
                           "loss_tol_abs": LOSS_TOL_ABS}
    for topo in topologies:
        sc = build_scenario(topo, rounds)
        tl = simulate(sc, numeric=spec.problem())
        gap = MixingMatrix.metropolis(make_topology(
            topo, N_CLUSTERS)).spectral_gap()
        out["topologies"][topo] = {
            "spectral_gap": round(gap, 6),
            "bytes_per_round": [e.wire_bytes_total for e in tl.events],
            "total_bytes_on_links": tl.total_wire_bytes_on_links,
            "round_s": [round(e.t_round_s, 6) for e in tl.events],
            "losses": [None if e.loss is None else round(e.loss, 6)
                       for e in tl.events],
            "disagreement": [None if e.disagreement is None
                             else round(e.disagreement, 8)
                             for e in tl.events],
            "final_consensus_loss": _final_consensus_loss(tl, spec),
            "timeline_table": tl.table(),
        }

    star = out["topologies"]["star"]
    ring = out["topologies"]["ring"]
    # criterion (a): per-round bytes-on-wire strictly below gather, every
    # round where anyone communicated at all
    pairs = [(g, s) for g, s in zip(ring["bytes_per_round"],
                                    star["bytes_per_round"]) if s > 0]
    bytes_below = bool(pairs) and all(g < s for g, s in pairs)
    # criterion (b): final consensus loss within the stated tolerance —
    # one-sided: gossip may not be WORSE than gather by more than the
    # margin (being better is not a failure)
    l_star, l_ring = star["final_consensus_loss"], ring["final_consensus_loss"]
    loss_gap = l_ring - l_star
    loss_ok = loss_gap <= LOSS_TOL_ABS + LOSS_TOL_REL * abs(l_star)
    out["criteria"] = {
        "bytes_below_gather": bytes_below,
        "bytes_saved_frac": round(
            1.0 - ring["total_bytes_on_links"]
            / max(star["total_bytes_on_links"], 1), 6),
        "final_loss_star": l_star,
        "final_loss_ring": l_ring,
        "final_loss_gap": loss_gap,
        "loss_within_tol": loss_ok,
        "ok": bytes_below and loss_ok,
    }
    return out


def check_proc_equivalence(fast: bool = True) -> Dict[str, Any]:
    """Ring gossip on the proc backend (real processes + p2p sockets),
    gated bit-for-bit against the in-process run — scaled down to 4
    clusters so the gate stays cheap enough to run anywhere."""
    from repro.sim.proc.equivalence import check_equivalence

    n = 4
    sc = Scenario(
        n_clusters=n, rounds=4 if fast else 6, h_steps=4, t_step_s=0.04,
        link=LinkProfile(bytes_per_s=100_000), topology="ring",
        compressor="diloco_x",
        compressor_kw={"rank": 4, "min_dim_for_lowrank": 8}, rank=4,
        n_params=1e5, seed=0)
    spec = QuadraticSpec(n_clusters=n, d=8, n_mats=2, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    rep.pop("timelines", None)
    return rep


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default="")
    ap.add_argument("--proc-equivalence", action="store_true",
                    help="also run ring gossip on the proc backend and "
                         "gate it bit-for-bit against the model")
    args = ap.parse_args()

    out = run(fast=args.fast)
    print(f"{'topology':>8} {'spectral_gap':>13} {'MB_on_links':>12} "
          f"{'final_loss':>11}")
    for topo, row in out["topologies"].items():
        print(f"{topo:>8} {row['spectral_gap']:>13.4f} "
              f"{row['total_bytes_on_links'] / 1e6:>12.2f} "
              f"{row['final_consensus_loss']:>11.4f}")
    print("\n--- ring timeline under churn ---")
    print(out["topologies"]["ring"]["timeline_table"])
    crit = out["criteria"]
    print(f"\nbytes-on-wire: ring {'<' if crit['bytes_below_gather'] else '>='} "
          f"gather every round "
          f"({100 * crit['bytes_saved_frac']:.1f}% saved)  "
          f"=> {'PASS' if crit['bytes_below_gather'] else 'FAIL'}")
    print(f"final consensus loss: ring {crit['final_loss_ring']:.4f} vs "
          f"gather {crit['final_loss_star']:.4f} (signed gap "
          f"{crit['final_loss_gap']:+.4f}, tol "
          f"{LOSS_TOL_ABS} + {LOSS_TOL_REL:.0%} rel, one-sided)  "
          f"=> {'PASS' if crit['loss_within_tol'] else 'FAIL'}")

    if args.proc_equivalence:
        rep = check_proc_equivalence(fast=args.fast)
        out["proc_equivalence"] = rep
        print(f"proc ring-gossip equivalence: bitwise={rep['hash_match']} "
              f"timing={rep['timing_ok']} => "
              f"{'PASS' if rep['ok'] else 'FAIL'}")
        crit["ok"] = crit["ok"] and rep["ok"]

    if args.json:
        for row in out["topologies"].values():
            row.pop("timeline_table", None)
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.json}")
    sys.exit(0 if crit["ok"] else 1)


if __name__ == "__main__":
    main()
