"""Beyond-paper: decentralized scaling sweep.

The paper evaluates one cluster topology; here we sweep the number of
clusters C (2..32) and local steps H for qwen1.5-107b over 1 Gbps and ask
when the outer sync stops hiding behind local compute (the §2.3 overlap
condition T_comm <= H * t_step) — i.e. the operating envelope of DiLoCoX,
and what Alg. 3's rank annealing buys at each point.
"""
from __future__ import annotations

import json
from typing import Dict, List

from repro.core import comm
from repro.core.compression import LowRankQuant, tree_shapes


def run(arch: str = "qwen1.5-107b") -> Dict:
    from benchmarks.throughput import (A800_PEAK, MFU, N_GPUS,
                                       TOKENS_PER_STEP, model_setup)

    cfg, shapes, n_params = model_setup(arch)
    t_step = 6.0 * n_params * TOKENS_PER_STEP / (
        N_GPUS.get(arch, 160) * A800_PEAK * MFU)
    rows: List[dict] = []
    for C in (2, 4, 8, 16, 32):
        for H in (25, 125, 500):
            for rank in (2048, 512, 128):
                dlx = LowRankQuant(rank=rank, bits=4)
                wire = dlx.wire_bytes(shapes)
                sc = comm.CommScenario(n_clusters=C, t_step_s=t_step,
                                       tokens_per_step=TOKENS_PER_STEP * C
                                       // 2)
                r = comm.method_throughput(
                    "dlx", param_bytes_fp32=n_params * 4.0,
                    wire_bytes=wire, h_steps=H, overlap=True, sc=sc)
                rows.append({
                    "clusters": C, "H": H, "rank": rank,
                    "comm_s": round(r.comm_s_per_round, 1),
                    "hidden": r.exposed_comm_s == 0.0,
                    "exposed_s": round(r.exposed_comm_s, 1),
                    "tokens_per_s": round(r.tokens_per_s, 0),
                    "overlap_margin": round(
                        H * t_step / max(r.comm_s_per_round, 1e-9), 2),
                })
    # envelope: largest C fully hidden at each (H, rank)
    envelope = {}
    for row in rows:
        key = f"H={row['H']},r={row['rank']}"
        if row["hidden"]:
            envelope[key] = max(envelope.get(key, 0), row["clusters"])
    return {"arch": arch, "t_step_s": round(t_step, 2), "rows": rows,
            "max_fully_hidden_clusters": envelope}


if __name__ == "__main__":
    out = run()
    print(f"{'C':>3} {'H':>4} {'rank':>5} {'comm_s':>8} {'hidden':>7} "
          f"{'margin':>7}")
    for r in out["rows"]:
        print(f"{r['clusters']:>3} {r['H']:>4} {r['rank']:>5} "
              f"{r['comm_s']:>8} {str(r['hidden']):>7} "
              f"{r['overlap_margin']:>7}")
    print(json.dumps(out["max_fully_hidden_clusters"], indent=1))
