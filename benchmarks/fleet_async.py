"""64-cluster async fleet: bounded-stale rounds vs the global barrier.

The barrier outer loop makes every cluster pay for the slowest member of
every round.  At fleet scale that cost explodes: with 64 WAN sites on
diurnal bandwidth cycles (sites degrade on phase-shifted "night" windows),
transient stragglers, and membership churn, SOMEONE is always slow, so the
whole fleet idles at every barrier.  The event-driven engine
(``repro.sim.engine``) removes the barrier: each cluster commits outer
steps against the freshest published peer deltas, gated only by
``max_staleness``.

This benchmark drives both policies over the SAME trace-driven fleet
scenario and reports the acceptance criteria:

 - ``barrier_idle_cut``  >= 0.5 — bounded staleness recovers at least half
   of the cluster-seconds the barrier burned waiting (the ISSUE gate);
 - ``overlap_efficiency`` of the async run >= 0.9 — eager
   publish-at-finish keeps nearly all wire time behind compute (the gate
   wait is the only exposed time left; the barrier run's own efficiency
   is reported alongside but is not comparable, since its §2.3 delayed
   sync prices comm per-round rather than per-commit);
 - ``makespan_gain`` > 1 — wall-clock win of the async fleet;
 - ``wall_clock_win`` >= 1 on a small numeric leg — at the async fleet's
   makespan the async run's loss is at or below where the (slower)
   barrier run had gotten: recovered idle became convergence progress —
   with ``final_loss_ratio_at_budget`` additionally bounded (<= 3.0) so
   the per-round staleness tax is a tax, never a divergence.

  python -m benchmarks.fleet_async [--fast]

Registered in ``benchmarks/run.py`` (including ``--smoke``): the fleet
legs are timing-only event-engine runs and the numeric leg is a tiny
quadratic, so the whole thing is CI-cheap.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List

from repro.sim import (FaultSchedule, Join, Leave, LinkProfile,
                       QuadraticSpec, Scenario, Straggler, simulate)
from repro.sim.faults import LinkDegradation

FLEET_CLUSTERS = 64
DIURNAL_PERIOD = 8          # local rounds per simulated "day"
NIGHT_FACTOR = 0.25         # bandwidth multiplier during a site's night


def fleet_faults(n_clusters: int, rounds: int) -> FaultSchedule:
    """Deterministic trace: phase-shifted diurnal bandwidth for every
    site, a few transient stragglers, and leave/join churn."""
    ev: List[Any] = []
    for c in range(n_clusters):
        # night windows, phase-shifted across the fleet (c's timezone)
        phase = (c * DIURNAL_PERIOD) // n_clusters
        k0 = phase
        while k0 < rounds:
            ev.append(LinkDegradation(k0, min(k0 + DIURNAL_PERIOD // 2,
                                              rounds),
                                      NIGHT_FACTOR, cluster=c))
            k0 += DIURNAL_PERIOD
    # every 8th site stalls 2.5x for a 3-round window
    for i, c in enumerate(range(0, n_clusters, 8)):
        s0 = (1 + 2 * i) % max(rounds - 3, 1)
        ev.append(Straggler(c, s0, min(s0 + 3, rounds), 2.5))
    # churn: three sites drop out mid-run and rejoin near the end
    for c in (3, n_clusters // 2, n_clusters - 5):
        if 0 <= c < n_clusters and rounds >= 6:
            ev.append(Leave(c, rounds // 3))
            ev.append(Join(c, rounds - 2))
    return FaultSchedule(tuple(ev))


def fleet_scenario(rounds: int, *, sync: str,
                   n_clusters: int = FLEET_CLUSTERS) -> Scenario:
    return Scenario(
        n_clusters=n_clusters, rounds=rounds, h_steps=30, t_step_s=0.3,
        sync=sync, max_staleness=2, topology="ring",
        link=LinkProfile(bytes_per_s=0.125e9, latency_s=0.03, jitter=0.1),
        compressor="diloco_x", compressor_kw={"rank": 64}, rank=64,
        n_params=1e9, seed=7, faults=fleet_faults(n_clusters, rounds))


def _ledger(tl) -> Dict[str, float]:
    from repro.obs import OverlapLedger
    led = OverlapLedger.from_timeline(tl)
    return {"idle_s": round(led.barrier_idle_s, 3),
            "comm_s": round(led.comm_s, 3),
            "hidden_comm_s": round(led.hidden_comm_s, 3),
            "overlap_efficiency": round(led.overlap_efficiency, 6),
            "makespan_s": round(tl.total_time_s, 3)}


def numeric_gap(rounds: int) -> Dict[str, float]:
    """Small numeric leg, barrier vs bounded_stale, two readings:

    - equal WALL CLOCK (the async claim): the async run's final loss must
      be <= the loss the barrier run had reached when the async fleet's
      makespan elapsed — asynchrony converts recovered idle into
      convergence progress;
    - equal ROUND budget (the sanity bound): stale mixing pays some
      convergence tax per round, but it must stay a bounded factor, not a
      divergence.
    """
    mk = lambda: QuadraticSpec(n_clusters=4, d=8, h_steps=4,
                               seed=1).problem()
    # transient straggler window — the fleet regime the barrier pays for
    # in full and bounded staleness absorbs (a PERMANENT straggler would
    # pace both policies identically through the gate)
    kw = dict(n_clusters=4, rounds=rounds, h_steps=4, seed=3, t_step_s=0.02,
              topology="ring", compressor="diloco_x",
              compressor_kw={"rank": 4}, rank=4,
              link=LinkProfile(bytes_per_s=2e8, latency_s=0.01,
                               jitter=0.1),
              faults=FaultSchedule((
                  Straggler(1, 1, max(2, rounds // 2), 3.0),)))
    tl_b = simulate(Scenario(**kw), numeric=mk())
    tl_a = simulate(Scenario(**kw, sync="bounded_stale", max_staleness=2),
                    numeric=mk())
    loss_b = tl_b.losses()[-1]
    # async "final" loss: mean over the last commit of each cluster (the
    # single last event is one arbitrary cluster's replica)
    last = {}
    for e in tl_a.events:
        last[e.cluster] = e.loss
    loss_a = sum(last.values()) / len(last)
    # barrier loss on the async wall-clock budget: last barrier round that
    # completed before the async fleet finished ALL its legs
    t_async = tl_a.total_time_s
    cum, loss_b_at_t = 0.0, tl_b.losses()[0]
    for e in tl_b.events:
        cum += e.t_round_s
        if cum > t_async:
            break
        loss_b_at_t = e.loss
    return {"barrier_final_loss": round(loss_b, 6),
            "async_final_loss": round(loss_a, 6),
            "async_makespan_s": round(t_async, 3),
            "barrier_loss_at_async_makespan": round(loss_b_at_t, 6),
            "final_loss_ratio": round(loss_a / loss_b, 6),
            "wall_clock_win": round(loss_b_at_t / loss_a, 6)}


def run(fast: bool = False) -> Dict[str, Any]:
    rounds = 10 if fast else 16
    tl_b = simulate(fleet_scenario(rounds, sync="barrier"))
    tl_a = simulate(fleet_scenario(rounds, sync="bounded_stale"))
    barrier, asynch = _ledger(tl_b), _ledger(tl_a)
    gap = numeric_gap(6 if fast else 10)

    idle_cut = (1.0 - asynch["idle_s"] / barrier["idle_s"]
                if barrier["idle_s"] > 0 else 0.0)
    makespan_gain = (barrier["makespan_s"] / asynch["makespan_s"]
                     if asynch["makespan_s"] > 0 else 0.0)
    max_stale = max((s for e in tl_a.events for _, s in e.staleness),
                    default=0)
    criteria = {
        "barrier_idle_cut": round(idle_cut, 6),
        "overlap_efficiency_async": asynch["overlap_efficiency"],
        "overlap_efficiency_barrier": barrier["overlap_efficiency"],
        "makespan_gain": round(makespan_gain, 6),
        "final_loss_ratio_at_budget": gap["final_loss_ratio"],
        "wall_clock_win": gap["wall_clock_win"],
        "max_staleness_seen": max_stale,
        "ok": bool(idle_cut >= 0.5
                   and asynch["overlap_efficiency"] >= 0.9
                   and makespan_gain > 1.0
                   and gap["wall_clock_win"] >= 1.0
                   and gap["final_loss_ratio"] <= 3.0
                   and max_stale <= 2),
    }
    return {"n_clusters": FLEET_CLUSTERS, "rounds": rounds,
            "barrier": barrier, "bounded_stale": asynch,
            "numeric": gap, "criteria": criteria}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    out = run(fast=args.fast)
    print(json.dumps(out, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    if not out["criteria"]["ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
