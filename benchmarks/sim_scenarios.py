"""Scenario-sweep benchmark on the virtual decentralized cluster.

What decentralized reality costs: sweeps straggler severity, link
degradation, and membership churn over the paper's operating point and
reports effective-throughput retention vs the clean run.  Also re-derives
the Fig. 4 / §4.2.2 method comparison (357x at 107B, 32x at 1.3B) through
the round-by-round simulator instead of closed-form arithmetic —
the two must agree on clean links (tests/test_sim.py asserts it).

  python -m benchmarks.sim_scenarios                 # modeled sweeps
  python -m benchmarks.sim_scenarios --backend proc  # real processes +
                                     # rate-limited sockets, checked
                                     # against the model (repro.sim.proc)
"""
from __future__ import annotations

import argparse
import json
from dataclasses import replace
from typing import Dict

from repro.sim import (FaultSchedule, Join, Leave, LinkDegradation,
                       LinkProfile, Scenario, Straggler, compare_methods,
                       simulate)

# the paper's two throughput operating points (§4.2.2, calibrated exactly
# as benchmarks/throughput.py does: t_step from a FLOPs model at MFU 4.5%)
A800_PEAK = 312e12
MFU = 0.045
TOKENS_PER_STEP = 36_000
OPERATING_POINTS = {
    # arch: (n_params, n_gpus, rank)
    "opt-1.3b": (1.3e9, 16, 64),
    "qwen1.5-107b": (107e9, 160, 2048),
}


def paper_scenario(arch: str, *, rounds: int = 4, n_clusters: int = 2,
                   h_steps: int = 125) -> Scenario:
    n_params, n_gpus, rank = OPERATING_POINTS[arch]
    t_step = 6.0 * n_params * TOKENS_PER_STEP / (n_gpus * A800_PEAK * MFU)
    return Scenario(n_clusters=n_clusters, rounds=rounds, h_steps=h_steps,
                    t_step_s=t_step, tokens_per_step=TOKENS_PER_STEP,
                    n_params=n_params, compressor="diloco_x",
                    compressor_kw={"rank": rank}, rank=rank)


def fault_sweep(base: Scenario) -> Dict[str, Dict[str, float]]:
    """Throughput retention under injected faults, vs the clean run."""
    R = base.rounds
    cases = {
        "clean": FaultSchedule(()),
        "straggler_2x": FaultSchedule((Straggler(1, 0, R, 2.0),)),
        "straggler_5x": FaultSchedule((Straggler(1, 0, R, 5.0),)),
        "link_half": FaultSchedule((LinkDegradation(0, R, 0.5),)),
        "link_tenth": FaultSchedule((LinkDegradation(0, R, 0.1),)),
        "churn": FaultSchedule((Leave(1, R // 3), Join(1, 2 * R // 3))),
        "jittery": None,                       # 20% sigma link/step noise
    }
    out = {}
    clean_tps = None
    for name, faults in cases.items():
        sc = (replace(base, link=replace(base.link, jitter=0.2))
              if faults is None else replace(base, faults=faults))
        tl = simulate(sc)
        tps = tl.tokens_per_s
        if name == "clean":
            clean_tps = tps
        out[name] = {
            "tokens_per_s": round(tps, 1),
            "retention": round(tps / clean_tps, 4) if clean_tps else 1.0,
            "exposed_comm_frac": round(tl.exposed_comm_frac, 4),
        }
    return out


def run(fast: bool = True) -> Dict:
    """Entry for benchmarks/run.py: method comparison + fault sweeps."""
    out = {"methods": {}, "fault_sweep": {}}
    for arch in OPERATING_POINTS:
        base = paper_scenario(arch, rounds=4 if fast else 12)
        _, _, rank = OPERATING_POINTS[arch]
        cmp = compare_methods(base, rank=rank)
        out["methods"][arch] = {
            "tokens_per_s": {k: round(v, 1)
                             for k, v in cmp["tokens_per_s"].items()},
            "speedup_vs_allreduce": {
                k: round(v, 1)
                for k, v in cmp["speedup_vs_allreduce"].items()},
        }
        out["fault_sweep"][arch] = fault_sweep(base)
    # churn at higher cluster counts (the regime the paper never measures)
    base8 = replace(paper_scenario("opt-1.3b", rounds=12), n_clusters=8)
    out["fault_sweep"]["opt-1.3b_8clusters"] = fault_sweep(base8)
    return out


def run_proc(rounds: int = 5, n_clusters: int = 2) -> Dict:
    """The churn sweep's straggler+leave/join case on the *multi-process*
    backend (``repro.sim.proc``): real worker processes, token-bucket
    sockets, kill/respawn — asserted against the in-process model
    (bit-for-bit outer state, timing within tolerance)."""
    from repro.sim import QuadraticSpec
    from repro.sim.proc import check_equivalence

    sc = Scenario(
        n_clusters=n_clusters, rounds=rounds, h_steps=4, t_step_s=0.05,
        link=LinkProfile(bytes_per_s=50_000, jitter=0.1),
        faults=FaultSchedule((Straggler(1, 1, min(3, rounds - 1), 2.5),
                              Leave(1, rounds // 2),
                              Join(1, rounds - 1))),
        compressor="diloco_x",
        compressor_kw={"rank": 8, "min_dim_for_lowrank": 8}, rank=8,
        n_params=2e5, seed=0)
    spec = QuadraticSpec(n_clusters=n_clusters, d=8, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    tls = rep.pop("timelines")
    return {
        "ok": rep["ok"],
        "bitwise_equal": rep["hash_match"],
        "timing_ok": rep["timing_ok"],
        "max_abs_time_err_s": rep["max_abs_time_err_s"],
        "max_rel_time_err": rep["max_rel_time_err"],
        "tokens_per_s": {"proc_measured": round(tls["proc"].tokens_per_s, 1),
                         "modeled": round(tls["model"].tokens_per_s, 1)},
        "structural_fingerprint": rep["proc_fingerprint"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=["model", "proc"], default="model")
    args = ap.parse_args()

    if args.backend == "proc":
        r = run_proc()
        print(f"sim_proc.bitwise_equal,{int(r['bitwise_equal'])},bool")
        print(f"sim_proc.max_rel_time_err,{r['max_rel_time_err']},frac")
        print(json.dumps(r, indent=1))
        if not r["ok"]:
            raise SystemExit(1)
        return

    r = run(fast=True)
    for arch, m in r["methods"].items():
        for k, v in m["speedup_vs_allreduce"].items():
            print(f"sim_methods.{arch}.{k},{v},x_vs_allreduce")
    for tag, sweep in r["fault_sweep"].items():
        for case, row in sweep.items():
            print(f"sim_faults.{tag}.{case},{row['retention']},retention")
    print(json.dumps(r, indent=1))


if __name__ == "__main__":
    main()
