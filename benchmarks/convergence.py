"""Paper Fig. 3: convergence comparison of AllReduce / DiLoCoX /
OpenDiLoCo-style / CocktailSGD-style at matched communication budgets.

Offline scaling: the OPT-1.3B experiment is reproduced at reduced width on
the synthetic stream (DESIGN.md §3) — loss *ordering and gaps* are the
claim under test, not absolute values. Methods are matched the way the
paper matches them (§4.1.3): DiLoCoX H=125->here H, int4+low-rank;
OpenDiLoCo H 4x larger (its "excessively large H"), fp16, synchronous;
CocktailSGD per-step aggressive compression, no local training.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict

import numpy as np


def run(rounds: int = 12, h: int = 10, seed: int = 0,
        fast: bool = False) -> Dict:
    from repro.configs.base import get_config
    from repro.train import trainer as T

    cfg = dataclasses.replace(get_config("opt-1.3b").reduced(),
                              vocab_size=128)
    if fast:
        rounds, h = 6, 6
    # hetero: per-cluster data sources (Assumption 3.3) — the decentralized
    # setting's defining property
    base = dict(n_clusters=2, local_batch=8, seq_len=32, inner_lr=3e-3,
                seed=seed, hetero=0.7)
    total_steps = rounds * h
    out: Dict = {"steps": total_steps}

    # vanilla AllReduce (loss reference)
    r = T.run_allreduce_training(cfg, T.TrainConfig(**base, h_steps=1),
                                 total_steps)
    out["allreduce"] = {"eval": r.eval_losses, "final": r.eval_losses[-1]}

    # DiLoCoX: delay + low-rank+int4 + error feedback
    tc = T.TrainConfig(**base, h_steps=h, compressor="diloco_x",
                       compressor_kw=dict(rank=32, bits=4),
                       delay=True, compress=True,
                       outer_lr=0.5, outer_momentum=0.7)
    r = T.run_diloco_training(cfg, tc, rounds)
    out["diloco_x"] = {"eval": r.eval_losses, "final": r.eval_losses[-1],
                       "wire_bytes": r.wire_bytes_per_round[0]}

    # OpenDiLoCo-style: synchronous, fp16, H 4x larger (gradient staleness)
    tc = T.TrainConfig(**base, h_steps=4 * h, compressor="fp16",
                       delay=False, compress=True,
                       outer_lr=0.7, outer_momentum=0.9)
    r = T.run_diloco_training(cfg, tc, max(2, rounds // 4))
    out["opendiloco"] = {"eval": r.eval_losses, "final": r.eval_losses[-1],
                         "wire_bytes": r.wire_bytes_per_round[0]}

    # CocktailSGD-style: per-step aggressive compression, no local training
    tc = T.TrainConfig(**base, compressor="cocktail",
                       compressor_kw=dict(random_ratio=0.1, topk_ratio=0.08,
                                          bits=4))
    r = T.run_compressed_ddp_training(cfg, tc, total_steps)
    out["cocktail"] = {"eval": r.eval_losses, "final": r.eval_losses[-1],
                       "wire_bytes": r.wire_bytes_per_round[0]}

    # scale-transferable orderings (EXPERIMENTS.md §Convergence): AllReduce
    # best; DiLoCoX within a modest gap of AllReduce (the delay penalty the
    # paper's own Table 1 shows); DiLoCoX beats CocktailSGD. The paper's
    # large OpenDiLoCo penalty (H=500 staleness at 1.3B on WikiText) does
    # NOT reproduce at toy scale even with heterogeneity — reported, not
    # asserted.
    out["ordering_ok"] = bool(
        out["allreduce"]["final"] <= out["diloco_x"]["final"] + 0.05
        and out["diloco_x"]["final"] < out["cocktail"]["final"] + 0.3
        and out["diloco_x"]["final"] < out["diloco_x"]["eval"][0] - 0.8)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
