"""Paper Table 1: ablation of One-Step-Delay Overlap and Adaptive Gradient
Compression (Qwen1.5-107B in the paper; reduced-width here for the loss
column, full-scale comm model for the throughput column).

Expected ordering (paper): loss(AllReduce) <= loss(w/o compression) <=
loss(w/o overlap) <= loss(full); throughput strictly reversed.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict

from repro.core import comm
from repro.core.compression import LowRankQuant, tree_shapes


def throughput_column(n_clusters: int = 2, h: int = 125,
                      rank: int = 2048) -> Dict[str, float]:
    from benchmarks.throughput import (A800_PEAK, MFU, N_GPUS,
                                       TOKENS_PER_STEP, model_setup)

    cfg, shapes, n_params = model_setup("qwen1.5-107b")
    t_step = 6.0 * n_params * TOKENS_PER_STEP / (
        N_GPUS["qwen1.5-107b"] * A800_PEAK * MFU)
    sc = comm.CommScenario(n_clusters=n_clusters, t_step_s=t_step,
                           tokens_per_step=TOKENS_PER_STEP)
    pb = n_params * 4.0
    dlx = LowRankQuant(rank=rank, bits=4)
    full = comm.method_throughput("full", param_bytes_fp32=pb,
                                  wire_bytes=dlx.wire_bytes(shapes),
                                  h_steps=h, overlap=True, sc=sc)
    no_overlap = comm.method_throughput("no_overlap", param_bytes_fp32=pb,
                                        wire_bytes=dlx.wire_bytes(shapes),
                                        h_steps=h, overlap=False, sc=sc)
    no_comp = comm.method_throughput("no_comp", param_bytes_fp32=pb,
                                     wire_bytes=pb, h_steps=h,
                                     overlap=True, sc=sc)
    allreduce = comm.method_throughput("allreduce", param_bytes_fp32=pb,
                                       wire_bytes=pb, h_steps=1,
                                       overlap=False, sc=sc,
                                       allreduce_per_step=True)
    return {"full": full.tokens_per_s, "wo_overlap": no_overlap.tokens_per_s,
            "wo_compression": no_comp.tokens_per_s,
            "allreduce": allreduce.tokens_per_s}


def loss_column(rounds: int = 10, h: int = 10, seed: int = 0
                ) -> Dict[str, float]:
    from repro.configs.base import get_config
    from repro.train import trainer as T

    cfg = dataclasses.replace(get_config("opt-1.3b").reduced(),
                              vocab_size=128)
    base = dict(n_clusters=2, local_batch=8, seq_len=32, inner_lr=3e-3,
                seed=seed, outer_lr=0.5, outer_momentum=0.7, hetero=0.7)
    out = {}
    tc = T.TrainConfig(**base, h_steps=h, compressor="diloco_x",
                       compressor_kw=dict(rank=32, bits=4),
                       delay=True, compress=True)
    out["full"] = T.run_diloco_training(cfg, tc, rounds).eval_losses[-1]
    tc = dataclasses.replace(tc, delay=False)
    out["wo_overlap"] = T.run_diloco_training(cfg, tc, rounds).eval_losses[-1]
    tc = dataclasses.replace(tc, delay=True, compress=False)
    out["wo_compression"] = T.run_diloco_training(cfg, tc,
                                                  rounds).eval_losses[-1]
    ar = T.run_allreduce_training(
        cfg, T.TrainConfig(**base, h_steps=1), rounds * h)
    out["allreduce"] = ar.eval_losses[-1]
    return out


def run(fast: bool = False) -> Dict:
    tp = throughput_column()
    ls = loss_column(rounds=6 if fast else 10, h=6 if fast else 10)
    paper = {"full": (4.20, 3728), "wo_overlap": (4.15, 2197),
             "wo_compression": (4.02, 1168), "allreduce": (3.90, 10.4)}
    rows = {k: {"loss": round(ls[k], 3), "tokens_per_s": round(tp[k], 1),
                "paper_loss": paper[k][0], "paper_tokens_per_s": paper[k][1]}
            for k in ("full", "wo_overlap", "wo_compression", "allreduce")}
    ordering_tp = (tp["full"] > tp["wo_overlap"] > tp["wo_compression"]
                   > tp["allreduce"])
    return {"rows": rows, "throughput_ordering_ok": bool(ordering_tp)}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
