"""Analytic per-device FLOPs / HBM-traffic model for the roofline.

Why this exists: XLA's ``cost_analysis()`` counts each ``while``-loop body
ONCE, so anything inside a scan-over-layers (all matmul FLOPs, activation
traffic) is undercounted by ~n_layers x in the compiled dry-run, while
GSPMD-hoisted collectives are counted correctly. §Roofline therefore
anchors the compute/memory terms on this analytic model (exact parameter
shapes via eval_shape, explicit multipliers below) and takes collective
bytes + per-device memory footprint from the compiled artifact. The raw
HLO numbers are kept in the table as a sanity column with the measured
undercount ratio.

Multipliers:
  train   : fwd 2*N_act FLOPs/token, bwd 2x fwd, remat re-forward 1x
            -> 8*N_act per token, + attention quadratic term with the same
            factor.
  prefill : 2*N_act per token (+ attention, fwd only).
  decode  : 2*N_act per token over context via KV cache: attention term is
            linear in context (2*B*ctx*H*dh per layer); SSM/ring-window
            layers are O(1) per token.

HBM traffic per device (train): 3 passes over resident params (fwd, bwd,
remat) + optimizer update (m,v,p read+write in f32) + activation
write/read per layer (~8*d bytes/token incl. attention io) + materialized
attention-score traffic for the chunked-softmax path (zero if the Pallas
flash kernel is used — that delta is a §Perf lever).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, get_config, get_shape

PEAK_FLOPS = 197e12
HBM_BW = 819e9


@dataclass
class AnalyticTerms:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    t_compute: float
    t_memory: float


def _attn_dims(cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    if cfg.attn_type == "mla":
        qk = cfg.mla.nope_head_dim + cfg.mla.rope_head_dim
        v = cfg.mla.v_head_dim
        return cfg.n_heads, qk, v
    return cfg.n_heads, hd, hd


def attention_flops(cfg: ModelConfig, B: int, S: int, *, decode: bool,
                    ctx: int = 0) -> float:
    """Global attention FLOPs (QK^T + PV), causal-halved, window-aware."""
    H, dqk, dv = _attn_dims(cfg)
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn_layers = cfg.n_layers // cfg.hybrid.shared_attn_period
    if cfg.family == "ssm":
        # mLSTM parallel form ~ attention-shaped; sLSTM linear
        n_attn_layers = cfg.n_layers * 7 // 8
        H, dqk, dv = 4, cfg.ssm.expand * cfg.d_model // 4, \
            cfg.ssm.expand * cfg.d_model // 4
    total = 0.0
    for i in range(n_attn_layers):
        if cfg.global_every:
            local = (i % cfg.global_every) != cfg.global_every - 1
            span = min(cfg.sliding_window, S) if local else S
        else:
            span = min(cfg.sliding_window, S) if cfg.sliding_window else S
        if decode:
            eff = min(span, ctx)
            total += 2.0 * 2 * B * eff * H * (dqk + dv) / 2
        else:
            total += 2.0 * B * S * span * H * (dqk + dv) / 2  # causal half
    if cfg.is_encdec:
        # encoder self (bidirectional, n_enc_layers) + decoder cross
        F = cfg.n_frontend_tokens
        total += 2.0 * B * F * F * H * (dqk + dv) * cfg.n_enc_layers
        total += 2.0 * B * (1 if decode else S) * F * H * (dqk + dv) \
            * cfg.n_layers
    return total


def analytic_terms(arch: str, shape_name: str, *, n_chips: int,
                   multi_pod: bool) -> AnalyticTerms:
    from repro.models.model import count_active_params

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_act = count_active_params(cfg)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        tokens = B * S
        mat = 8.0 * n_act * tokens                 # fwd+bwd+remat
        attn = 4.0 * attention_flops(cfg, B, S, decode=False)
        flops = mat + attn
        # memory: params*3 + adam(f32 m,v,p r/w ~ 24B/param on the shards)
        # (cluster-stacked: every chip holds its own cluster's shard only)
        p_bytes = n_act * 2.0                      # bf16 resident
        mem = (3 * p_bytes + 24.0 * n_act
               + tokens * cfg.d_model * 2.0 * 8 * cfg.n_layers / n_chips
               * n_chips                           # global activation io
               )
        # attention score traffic (chunked softmax materializes scores once
        # fwd + once in remat-bwd, f32)
        H, dqk, dv = _attn_dims(cfg)
        span = min(cfg.sliding_window, S) if cfg.sliding_window else S
        mem += 2.0 * B * S * span * H * 4.0 * cfg.n_layers / 2
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * n_act * tokens + attention_flops(cfg, B, S,
                                                       decode=False)
        p_bytes = n_act * 2.0
        H, dqk, dv = _attn_dims(cfg)
        span = min(cfg.sliding_window, S) if cfg.sliding_window else S
        mem = (p_bytes + tokens * cfg.d_model * 2.0 * 4 * cfg.n_layers
               / n_chips * n_chips
               + 1.0 * B * S * span * H * 4.0 * cfg.n_layers / 2)
    else:  # decode: one token, context = S
        flops = 2.0 * n_act * B + attention_flops(cfg, B, 1, decode=True,
                                                  ctx=S)
        # decode is param+cache-bandwidth bound: read all params + cache
        H, dqk, dv = _attn_dims(cfg)
        if cfg.attn_type == "mla":
            cache_per_tok = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
        else:
            cache_per_tok = 2 * cfg.n_kv_heads * cfg.resolved_head_dim
        span = min(cfg.sliding_window, S) if cfg.sliding_window else S
        n_full = cfg.n_layers
        cache_bytes = 0.0
        if cfg.family in ("dense", "vlm", "moe", "audio"):
            for i in range(cfg.n_layers):
                if cfg.global_every:
                    local = (i % cfg.global_every) != cfg.global_every - 1
                    eff = span if local else S
                elif cfg.sliding_window:
                    eff = span
                else:
                    eff = S
                cache_bytes += B * eff * cache_per_tok * 2.0
        elif cfg.family == "hybrid":
            n_attn = cfg.n_layers // cfg.hybrid.shared_attn_period
            cache_bytes += B * S * cache_per_tok * 2.0 * 0 + \
                B * S * 2 * cfg.n_heads * cfg.resolved_head_dim * 2.0
            # mamba states are O(1): d_inner*d_state per layer
            d_inner = cfg.ssm.expand * cfg.d_model
            cache_bytes += cfg.n_layers * B * d_inner * cfg.ssm.d_state * 4.0
        elif cfg.family == "ssm":
            d_inner = cfg.ssm.expand * cfg.d_model
            hd = d_inner // 4
            cache_bytes += cfg.n_layers * B * 4 * hd * hd * 4.0
        mem = n_act * 2.0 + cache_bytes
    return AnalyticTerms(
        flops_per_dev=flops / n_chips,
        hbm_bytes_per_dev=mem / n_chips,
        t_compute=flops / n_chips / PEAK_FLOPS,
        t_memory=mem / n_chips / HBM_BW,
    )
