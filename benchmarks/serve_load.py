"""Poisson-arrival serving load: continuous batching vs the static-batch
baseline at equal KV memory, on the paged engine.

  PYTHONPATH=src python -m benchmarks.serve_load [--smoke]

Both policies run the *identical* jit-compiled paged decode step (shared
``step_fn``) over the identical request trace and the identical page
pool — the only difference is admission: ``continuous`` recycles a slot
the step after its sequence finishes, ``static`` admits a wave and drains
it. Per-step wall time is therefore equal by construction, so the
deterministic decode-tokens-per-step ratio IS the tokens/s ratio — that
is what the ≥1.5x gate asserts (measured tokens/s is reported alongside
but not gated: CI machine noise).

The arrival trace is Poisson in *engine steps* at a fixed seed; the gate
re-runs the continuous engine and asserts an identical admission-order
fingerprint (scheduler determinism).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Tuple

import numpy as np

# trace shape: mostly-short generations with an occasional long tail —
# the regime where static batching wastes slots on the drain
GEN_CHOICES = (4, 6, 8, 28)
GEN_PROBS = (0.45, 0.25, 0.15, 0.15)


def build_trace(*, seed: int, n_requests: int, rate: float,
                prompt_lens: Tuple[int, int], vocab: int
                ) -> List[Tuple[int, List[int], int]]:
    """[(arrival_step, prompt, max_new)] — Poisson arrivals, fixed seed."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        gen = int(rng.choice(GEN_CHOICES, p=GEN_PROBS))
        prompt = rng.integers(0, vocab, plen).tolist()
        out.append((int(t), prompt, gen))
    return out


def _run_engine(params, cfg, trace, *, policy: str, max_seqs: int,
                page_size: int, n_pages: int, max_pages: int, step_fn):
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(params, cfg, max_seqs=max_seqs, page_size=page_size,
                      n_pages=n_pages, max_pages_per_seq=max_pages,
                      eos_id=None, policy=policy, step_fn=step_fn)
    for arrival, prompt, gen in trace:
        eng.submit(prompt, gen, arrival=arrival)
    return eng.run()


def run(fast: bool = True, *, seed: int = 0) -> Dict:
    import jax

    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serve.engine import make_paged_decode_step

    cfg = get_config("opt-1.3b").reduced()      # the paper's serving model
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    max_seqs, page_size = 4, 8
    n_requests = 16 if fast else 48
    trace = build_trace(seed=seed, n_requests=n_requests, rate=1.0,
                        prompt_lens=(5, 10), vocab=cfg.vocab_size)
    max_total = max(len(p) + g for _, p, g in trace)
    max_pages = -(-max_total // page_size)
    n_pages = max_seqs * max_pages              # equal-memory pool for both

    step_fn = jax.jit(make_paged_decode_step(cfg), donate_argnums=(1,))
    # warm up the shared executable so compile time lands on neither run
    import jax.numpy as jnp

    from repro.serve.engine import init_kv_pages
    step_fn(params, init_kv_pages(cfg, n_pages=n_pages,
                                  page_size=page_size),
            jnp.zeros(max_seqs, jnp.int32), jnp.zeros(max_seqs, jnp.int32),
            jnp.zeros(max_seqs, bool),
            jnp.zeros((max_seqs, max_pages), jnp.int32))
    kw = dict(max_seqs=max_seqs, page_size=page_size, n_pages=n_pages,
              max_pages=max_pages, step_fn=step_fn)
    cont = _run_engine(params, cfg, trace, policy="continuous", **kw)
    cont2 = _run_engine(params, cfg, trace, policy="continuous", **kw)
    stat = _run_engine(params, cfg, trace, policy="static", **kw)

    gain = cont["decode_tok_per_step"] / max(stat["decode_tok_per_step"],
                                             1e-9)
    deterministic = (cont["admission_fingerprint"]
                     == cont2["admission_fingerprint"])
    criteria = {
        "throughput_gain": round(gain, 3),
        "deterministic": deterministic,
        "p99_reported": bool(np.isfinite(cont["per_token_ms_p99"])),
        "ok": bool(gain >= 1.5 and deterministic
                   and np.isfinite(cont["per_token_ms_p99"])),
    }
    return {"trace": {"n_requests": n_requests, "seed": seed,
                      "max_seqs": max_seqs, "page_size": page_size,
                      "n_pages": n_pages},
            "continuous": cont, "static": stat, "criteria": criteria}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    r = run(fast=args.smoke)
    for name in ("continuous", "static"):
        s = r[name]
        print(f"serve_load.{name}.decode_tok_per_step,"
              f"{s['decode_tok_per_step']:.3f},tokens_per_step")
        print(f"serve_load.{name}.decode_tok_s,{s['decode_tok_s']:.1f},"
              f"tokens_per_s")
    c = r["continuous"]
    print(f"serve_load.ttft_p50,{c['ttft_steps_p50']:.0f},steps")
    print(f"serve_load.ttft_p99,{c['ttft_steps_p99']:.0f},steps")
    print(f"serve_load.per_token_p50,{c['per_token_ms_p50']:.2f},ms")
    print(f"serve_load.per_token_p99,{c['per_token_ms_p99']:.2f},ms")
    crit = r["criteria"]
    print(f"serve_load.throughput_gain,{crit['throughput_gain']},x_vs_static")
    print(f"serve_load.deterministic,{int(crit['deterministic'])},bool")
    print(f"serve_load.ok,{int(crit['ok'])},bool")
    if not crit["ok"]:
        raise AssertionError(f"serve-load acceptance criteria failed: {crit}")


if __name__ == "__main__":
    main()
