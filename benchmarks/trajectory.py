"""Benchmark trajectory diff: current run vs the previous artifact.

  python -m benchmarks.trajectory CURRENT.json PREVIOUS.json \
      [--threshold 2.0] [--warn-only]

Both files are ``benchmarks/run.py --json`` artifacts
(``{"sections": {...}, "failures": [...]}``), but the loader is
schema-tolerant: a file without a ``sections`` key is flattened whole, so
older artifacts (or hand-made baselines) still diff.  Every numeric leaf
becomes a dotted path (``fig4_opt-1.3b.methods.diloco_x.tokens_per_s``)
and matching paths are compared as a ratio.

Regression heuristic: a leaf regresses when it moves by more than
``--threshold``x in EITHER direction (default 2x).  Benchmarks mix
higher-is-better (tokens/s) and lower-is-better (loss, µs/call) metrics
and this tool doesn't know which is which, so any 2x jump — up or down —
is worth a human look; that is deliberately a tripwire, not a verdict.
Leaves present on only one side are listed but never fail the run (the
benchmark set grows PR over PR).

Exit status: 1 when any leaf regresses, unless ``--warn-only`` (the CI
mode — artifact retention makes the previous file best-effort, so the
step must not gate merges on its availability).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Tuple

# ratios are meaningless next to zero; leaves smaller than this are
# compared by absolute difference against the same threshold instead
_EPS = 1e-12


def flatten(doc: Any, prefix: str = "") -> Dict[str, float]:
    """Numeric leaves of a nested dict/list as {dotted.path: float}.
    Bools are skipped (they're pass/fail flags, not magnitudes); list
    elements use their index as the path segment."""
    out: Dict[str, float] = {}
    if isinstance(doc, bool):
        return out
    if isinstance(doc, (int, float)):
        out[prefix or "value"] = float(doc)
        return out
    if isinstance(doc, dict):
        items = [(str(k), v) for k, v in doc.items()]
    elif isinstance(doc, list):
        items = [(str(i), v) for i, v in enumerate(doc)]
    else:
        return out
    for k, v in items:
        path = f"{prefix}.{k}" if prefix else k
        out.update(flatten(v, path))
    return out


def load_metrics(path: str) -> Dict[str, float]:
    """Flatten a run.py artifact; tolerate both the ``{"sections": ...}``
    wrapper and a bare metrics document."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict) and isinstance(doc.get("sections"), dict):
        doc = doc["sections"]
    return flatten(doc)


def compare(current: Dict[str, float], previous: Dict[str, float],
            threshold: float = 2.0) -> Dict[str, Any]:
    """Diff two flattened metric maps.

    Returns ``{"rows": [(path, prev, cur, factor, regressed)],
    "regressions": [...], "only_current": [...], "only_previous": [...]}``
    with rows sorted by severity (largest factor first)."""
    rows: List[Tuple[str, float, float, float, bool]] = []
    for path in sorted(set(current) & set(previous)):
        prev, cur = previous[path], current[path]
        if abs(prev) < _EPS or abs(cur) < _EPS:
            # near-zero side: ratio blows up on noise — compare absolutely
            factor = 1.0 if abs(cur - prev) < threshold else float("inf")
        else:
            factor = max(abs(cur / prev), abs(prev / cur))
        regressed = factor > threshold or (cur * prev < 0)
        rows.append((path, prev, cur, factor, regressed))
    rows.sort(key=lambda r: (-r[3], r[0]))
    return {
        "rows": rows,
        "regressions": [r for r in rows if r[4]],
        "only_current": sorted(set(current) - set(previous)),
        "only_previous": sorted(set(previous) - set(current)),
    }


def format_table(diff: Dict[str, Any], max_rows: int = 40) -> str:
    lines = [f"{'metric':58s} {'previous':>12s} {'current':>12s} "
             f"{'factor':>8s}"]
    for path, prev, cur, factor, regressed in diff["rows"][:max_rows]:
        mark = "  <-- REGRESSION" if regressed else ""
        fstr = "inf" if factor == float("inf") else f"{factor:.2f}x"
        lines.append(f"{path[:58]:58s} {prev:12.4g} {cur:12.4g} "
                     f"{fstr:>8s}{mark}")
    hidden = len(diff["rows"]) - max_rows
    if hidden > 0:
        lines.append(f"... {hidden} more leaves within threshold")
    for key, label in (("only_current", "new"), ("only_previous", "gone")):
        if diff[key]:
            lines.append(f"{label} ({len(diff[key])}): "
                         + ", ".join(diff[key][:8])
                         + (" ..." if len(diff[key]) > 8 else ""))
    return "\n".join(lines)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="diff two benchmark artifacts; exit 1 on >threshold "
                    "regressions")
    ap.add_argument("current", help="this run's --json artifact")
    ap.add_argument("previous", help="the prior run's artifact")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="flag leaves that moved more than THIS x either "
                         "way (default 2.0)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but always exit 0 (CI mode)")
    args = ap.parse_args(argv)

    try:
        current = load_metrics(args.current)
    except (OSError, ValueError) as e:
        print(f"trajectory: cannot read current artifact: {e}",
              file=sys.stderr)
        sys.exit(0 if args.warn_only else 2)
    try:
        previous = load_metrics(args.previous)
    except (OSError, ValueError) as e:
        # no baseline is the common cold-start case — never an error
        print(f"trajectory: no previous artifact ({e}); nothing to diff")
        sys.exit(0)

    diff = compare(current, previous, threshold=args.threshold)
    print(format_table(diff))
    n = len(diff["regressions"])
    if n:
        print(f"trajectory: {n} leaves moved >"
              f"{args.threshold}x vs previous run"
              + (" (warn-only)" if args.warn_only else ""))
        sys.exit(0 if args.warn_only else 1)
    print(f"trajectory: ok ({len(diff['rows'])} shared leaves within "
          f"{args.threshold}x)")


if __name__ == "__main__":
    main()
