"""Heterogeneous local-step scheduling under stragglers: what does the
per-cluster H policy buy, and what does it cost?

The outer sync is a barrier on the slowest alive cluster, so a global H
makes every fast cluster idle for ``H*(t_slow - t_own)`` seconds per
round.  This benchmark runs the SAME straggler scenarios (real
``core/diloco.py`` rounds on the quadratic problem) with the uniform
``global`` policy and with ``balance`` (``core.adaptive.plan_h``: each
cluster's H follows its modeled step time, so slow sites do fewer local
steps and everyone lands near the barrier together) and reports:

 - **barrier idle**: cluster-seconds burnt waiting at the end-of-round
   barrier (``Timeline.total_barrier_idle_s``) — balance must cut it by
   at least ``IDLE_CUT_MIN`` on every straggler scenario;
 - **round time**: the balance barrier tightens toward the fastest
   cluster's full budget, so total wall-clock drops too;
 - **loss at equal wall-clock**: the straggler trains fewer steps under
   balance, which costs per-round accuracy, but the balance run finishes
   its rounds far sooner; at the balance run's total elapsed time its
   loss must be within the stated one-sided tolerance of whatever the
   global run had reached by that same time (the same equal-budget rule
   ``benchmarks/adaptive_link.py`` uses);
 - **gossip clamp**: on a ring, the spectral-gap certificate floors every
   cluster's H at ``ceil(h_base * (1 - gap))`` — slow mixing is not
   allowed to silently buy replica disagreement (asserted: the clamp
   binds, and the realized disagreement stays in the global run's range).

  python -m benchmarks.straggler_h [--fast] [--json out.json]

Exit status is non-zero if any acceptance criterion fails.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Any, Dict

import numpy as np

from repro.core.adaptive import HSpec, gap_h_floor
from repro.sim import (FaultSchedule, LinkProfile, QuadraticSpec, Scenario,
                       Straggler, simulate)

N_CLUSTERS = 4
H_BASE = 6
# stated acceptance tolerances:
#  - balance must cut the summed barrier-idle cluster-seconds by at least
#    IDLE_CUT_MIN (the ISSUE floor is 25%; a 4x straggler leaves far more
#    on the table) on every scenario;
#  - at the balance run's total wall-clock, its loss may exceed the loss
#    the global run had reached by that same elapsed time by at most
#    LOSS_TOL_REL (relative, one-sided) + LOSS_TOL_ABS (floor) — the
#    straggler contributes fewer inner steps, never zero (h_min).
IDLE_CUT_MIN = 0.25
LOSS_TOL_REL = 0.15
LOSS_TOL_ABS = 1e-3


def build_scenario(rounds: int, **kw) -> Scenario:
    base = dict(
        n_clusters=N_CLUSTERS, rounds=rounds, h_steps=H_BASE, t_step_s=0.05,
        link=LinkProfile(bytes_per_s=1e6),
        compressor="diloco_x",
        compressor_kw={"rank": 4, "min_dim_for_lowrank": 8}, rank=4,
        n_params=2e5, seed=0)
    base.update(kw)
    return Scenario(**base)


def _run_pair(sc: Scenario, spec: QuadraticSpec) -> Dict[str, Any]:
    out = {}
    for name, hs in (("global", None),
                     ("balance", HSpec(policy="balance", h_min=1))):
        tl = simulate(dataclasses.replace(sc, h_spec=hs),
                      numeric=spec.problem())
        out[name] = {
            "h_schedule": tl.h_schedule(),
            "barrier_idle_s": round(tl.total_barrier_idle_s, 6),
            "barrier_idle_frac": round(tl.barrier_idle_frac, 6),
            "round_s": [round(e.t_round_s, 6) for e in tl.events],
            "total_time_s": round(tl.total_time_s, 6),
            "losses": [round(x, 6) for x in tl.losses()],
            "final_loss": tl.losses()[-1],
            "timeline_table": tl.table(),
            "disagreement": [e.disagreement for e in tl.events],
        }
    return out


def run(fast: bool = False) -> Dict[str, Any]:
    rounds = 8 if fast else 14
    spec = QuadraticSpec(n_clusters=N_CLUSTERS, d=16, n_mats=2,
                         h_steps=H_BASE, seed=0)
    scenarios = {
        # one persistent 4x straggler — the canonical heterogeneous site
        "persistent": build_scenario(
            rounds,
            faults=FaultSchedule((Straggler(1, 1, rounds, 4.0),))),
        # a straggler window plus per-round jitter — the schedule must
        # track the modeled step times round by round
        "windowed_jitter": build_scenario(
            rounds, link=LinkProfile(bytes_per_s=1e6, jitter=0.08),
            faults=FaultSchedule((Straggler(2, rounds // 4,
                                            (3 * rounds) // 4, 3.0),))),
    }
    out: Dict[str, Any] = {
        "rounds": rounds, "idle_cut_min": IDLE_CUT_MIN,
        "loss_tol_rel": LOSS_TOL_REL, "loss_tol_abs": LOSS_TOL_ABS,
        "scenarios": {},
    }
    all_ok = True
    for tag, sc in scenarios.items():
        pair = _run_pair(sc, spec)
        g, b = pair["global"], pair["balance"]
        idle_cut = 1.0 - (b["barrier_idle_s"]
                          / max(g["barrier_idle_s"], 1e-12))
        # equal-wall-clock comparison: at the balance run's total elapsed
        # time, which loss had each run reached?  (The balance run has its
        # final loss; the global run has completed only the rounds whose
        # cumulative time fits the same budget.)
        t_budget = b["total_time_s"]
        cum = np.cumsum(g["round_s"])
        done = int(np.searchsorted(cum, t_budget + 1e-9, side="right"))
        g_loss_at_budget = g["losses"][done - 1] if done else float("inf")
        loss_gap = b["final_loss"] - g_loss_at_budget
        loss_ok = loss_gap <= LOSS_TOL_ABS + LOSS_TOL_REL * abs(
            g_loss_at_budget)
        row_ok = (idle_cut >= IDLE_CUT_MIN) and loss_ok
        pair["criteria"] = {
            "barrier_idle_cut": round(idle_cut, 4),
            "idle_cut_ok": idle_cut >= IDLE_CUT_MIN,
            "time_saved_s": round(g["total_time_s"] - b["total_time_s"], 6),
            "wallclock_budget_s": t_budget,
            "global_rounds_done_at_budget": done,
            "loss_global_at_budget": g_loss_at_budget,
            "loss_balance_at_budget": b["final_loss"],
            "final_loss_gap_at_budget": round(loss_gap, 6),
            "final_loss_gap_at_equal_rounds": round(
                b["final_loss"] - g["final_loss"], 6),
            "loss_within_tol": loss_ok,
            "ok": row_ok,
        }
        all_ok &= row_ok
        out["scenarios"][tag] = pair

    # gossip leg: on a ring the spectral-gap certificate clamps the H
    # spread — a 4-ring's masked MH matrix has gap 2/3, so no cluster may
    # drop below ceil(H * 1/3) even though the straggler's proportional
    # share would be far lower
    sc_ring = build_scenario(
        rounds, topology="ring",
        faults=FaultSchedule((Straggler(1, 1, rounds, 6.0),)))
    tl_ring = simulate(dataclasses.replace(
        sc_ring, h_spec=HSpec(policy="balance", h_min=1)),
        numeric=spec.problem())
    tl_ring_g = simulate(sc_ring, numeric=spec.problem())
    from repro.topology import MixingMatrix
    gap = MixingMatrix.metropolis(sc_ring.topo()).spectral_gap()
    floor = gap_h_floor(HSpec(policy="balance", h_min=1), H_BASE, gap)
    ring_h = [h for row in tl_ring.h_schedule() for h in row]
    clamp_binds = floor > 1 and min(ring_h) == floor
    dis_b = max(e.disagreement for e in tl_ring.events)
    dis_g = max(e.disagreement for e in tl_ring_g.events)
    # heterogeneous H must not blow up replica disagreement vs uniform H
    # (one-sided, generous headroom: the clamp is what keeps this bounded)
    dis_ok = dis_b <= 2.0 * dis_g + 1e-9
    out["gossip_ring"] = {
        "spectral_gap": round(float(gap), 6),
        "h_floor": floor,
        "h_schedule": tl_ring.h_schedule(),
        "clamp_binds": clamp_binds,
        "max_disagreement_balance": dis_b,
        "max_disagreement_global": dis_g,
        "disagreement_ok": dis_ok,
        "barrier_idle_cut": round(
            1.0 - tl_ring.total_barrier_idle_s
            / max(tl_ring_g.total_barrier_idle_s, 1e-12), 4),
    }
    all_ok = all_ok and clamp_binds and dis_ok
    out["criteria"] = {
        "idle_cut_ok_all": all(p["criteria"]["idle_cut_ok"]
                               for p in out["scenarios"].values()),
        "loss_ok_all": all(p["criteria"]["loss_within_tol"]
                           for p in out["scenarios"].values()),
        "gossip_clamp_binds": clamp_binds,
        "gossip_disagreement_ok": dis_ok,
        "ok": bool(all_ok),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default="")
    args = ap.parse_args()

    out = run(fast=args.fast)
    print(f"{'scenario':>16} {'policy':>8} {'idle_s':>8} {'total_s':>8} "
          f"{'final_loss':>11}")
    for tag, pair in out["scenarios"].items():
        for name in ("global", "balance"):
            row = pair[name]
            print(f"{tag:>16} {name:>8} {row['barrier_idle_s']:>8.3f} "
                  f"{row['total_time_s']:>8.2f} {row['final_loss']:>11.4f}")
        crit = pair["criteria"]
        print(f"{'':>16} idle cut {crit['barrier_idle_cut']:.1%} "
              f"(need >= {out['idle_cut_min']:.0%}); at equal wall-clock "
              f"({crit['wallclock_budget_s']:.2f}s) balance "
              f"{crit['loss_balance_at_budget']:.4f} vs global "
              f"{crit['loss_global_at_budget']:.4f} "
              f"(gap {crit['final_loss_gap_at_budget']:+.4f}, one-sided)"
              f"  => {'PASS' if crit['ok'] else 'FAIL'}")
    print("\n--- balance timeline (persistent straggler) ---")
    print(out["scenarios"]["persistent"]["balance"]["timeline_table"])
    gr = out["gossip_ring"]
    print(f"\nring gossip clamp: spectral gap {gr['spectral_gap']:.3f} => "
          f"H floor {gr['h_floor']} (of {H_BASE}); schedule min "
          f"{min(h for row in gr['h_schedule'] for h in row)}; "
          f"disagreement balance/global = "
          f"{gr['max_disagreement_balance']:.4g}/"
          f"{gr['max_disagreement_global']:.4g}  => "
          f"{'PASS' if gr['clamp_binds'] and gr['disagreement_ok'] else 'FAIL'}")
    print(f"straggler_h.ok={int(out['criteria']['ok'])}")

    if args.json:
        for pair in out["scenarios"].values():
            for name in ("global", "balance"):
                pair[name].pop("timeline_table", None)
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.json}")
    sys.exit(0 if out["criteria"]["ok"] else 1)


if __name__ == "__main__":
    main()
