"""Analytic per-device memory planner: for every (arch x mesh) predict the
resident-byte budget (params, inner Adam, outer DiLoCoX state, PowerSGD
warm starts, activation working set) under the Mode A sharding rules, and
compare with the dry-run's measured memory_analysis. The planner is what a
deployment would consult BEFORE compiling — and the comparison validates
both it and the sharding rules.

  PYTHONPATH=src python -m benchmarks.memory_plan [dryrun_results.json]

Observed planner-vs-XLA gap (EXPERIMENTS.md): the resident-state columns
match the dry-run arg_bytes closely, but XLA's scheduled temp peak runs
2-10x above the activation estimate (unfused f32 chains, attention score
buffers, scan carries) — the planner's `total` is a LOWER bound and the
headroom factor is itself a fusion-quality metric per arch (seamless's
45x gap flagged the unchunked encoder attention as the next §Perf target).
"""
from __future__ import annotations

import json
import math
import sys
from typing import Dict, Optional

BF16, F32 = 2, 4


def plan(arch: str, *, n_clusters: int = 2, n_chips: int = 256,
         rank: int = 128, batch_tokens_per_device: int = 65536,
         d_model: Optional[int] = None) -> Dict[str, float]:
    from repro.configs.base import get_config
    from repro.models.model import count_params

    cfg = get_config(arch)
    n = count_params(cfg)
    chips_per_cluster = n_chips // n_clusters
    # Mode A: params 2-D sharded (data x model) within the cluster
    p_dev = n * BF16 / chips_per_cluster
    adam_dev = n * 2 * F32 / chips_per_cluster          # m + v
    # outer state: anchor + momentum (unstacked, sharded over the full mesh)
    outer_dev = n * (BF16 + F32) / n_chips
    # per-cluster delta + error buffers (f32, stacked, cluster-sharded)
    buffers_dev = n * 2 * F32 / chips_per_cluster
    # PowerSGD warm starts: sum over matrices of n*r f32 ~ bounded by
    # (r / min_dim) of param count; use the exact accounting
    from repro.core.mesh_compression import MeshCompressionConfig
    from repro.launch.steps import params_specs
    ccfg = MeshCompressionConfig(rank=rank)
    q_elems = 0
    for x in __import__("jax").tree.leaves(params_specs(cfg)):
        shp = x.shape
        if len(shp) >= 2 and min(shp[-2], shp[-1]) >= ccfg.min_dim_for_lowrank:
            lead = math.prod(shp[:-2]) if len(shp) > 2 else 1
            q_elems += lead * shp[-1] * min(rank, shp[-2], shp[-1])
    q_dev = q_elems * F32 / chips_per_cluster
    # activation working set (remat: one unit's internals + layer carries)
    d = cfg.d_model
    act_dev = batch_tokens_per_device * d * BF16 * 12
    total = p_dev + adam_dev + outer_dev + buffers_dev + q_dev + act_dev
    return {"arch": arch, "params_gb": p_dev / 1e9,
            "adam_gb": adam_dev / 1e9, "outer_gb": outer_dev / 1e9,
            "ef_buffers_gb": buffers_dev / 1e9, "powersgd_q_gb": q_dev / 1e9,
            "activations_gb": act_dev / 1e9, "total_gb": total / 1e9,
            "fits_v5e": total < 16e9, "fits_v5p": total < 95e9}


def main() -> None:
    from repro.configs.base import ARCH_IDS
    path = sys.argv[1] if len(sys.argv) > 1 else None
    measured = {}
    if path:
        for r in json.load(open(path)):
            if r.get("status") == "ok" and "train" in r \
                    and not r.get("multi_pod"):
                measured[r["arch"]] = (
                    r["train"]["per_device_memory_bytes"] / 1e9)
    print(f"{'arch':24s} {'params':>7s} {'adam':>6s} {'outer':>6s} "
          f"{'EF':>6s} {'Q':>6s} {'acts':>6s} {'TOTAL':>7s} "
          f"{'measured':>9s} {'fits':>9s}")
    for arch in [a for a in ARCH_IDS if a not in ("opt-1.3b",)]:
        p = plan(arch)
        m = measured.get(arch)
        fits = "v5e" if p["fits_v5e"] else ("v5p" if p["fits_v5p"]
                                            else ">v5p")
        print(f"{arch:24s} {p['params_gb']:7.2f} {p['adam_gb']:6.2f} "
              f"{p['outer_gb']:6.2f} {p['ef_buffers_gb']:6.2f} "
              f"{p['powersgd_q_gb']:6.2f} {p['activations_gb']:6.2f} "
              f"{p['total_gb']:7.1f} "
              f"{(f'{m:8.1f}G' if m else '      --'):>9s} {fits:>9s}")


if __name__ == "__main__":
    main()
