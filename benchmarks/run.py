"""Benchmark harness entry point — one function per paper table/figure.

  python -m benchmarks.run [--fast] [--skip-convergence] [--smoke]

Prints ``name,value,unit`` CSV lines per benchmark plus JSON blobs to
benchmarks/out/. Mapping to the paper:
  fig3_convergence   -> Fig. 3 (loss: AllReduce/DiLoCoX/OpenDiLoCo/Cocktail)
  fig4_throughput    -> Fig. 4 + §4.2.2 (357x / 32x speedups)
  table1_ablation    -> Table 1 (overlap/compression ablation)
  kernels            -> compressor/attention hot-spot microbench
  roofline           -> EXPERIMENTS.md §Roofline source (needs
                        dryrun_results.json from launch/dryrun.py --all)
  sim_scenarios      -> beyond-paper: Fig. 4 methods + fault/churn sweeps
                        replayed on the virtual cluster (repro.sim)

``--smoke`` runs every cheap (analytic / tiny-jit) entrypoint and none of
the training-based ones — CI's bit-rot check.  Any benchmark exception is
reported, counted, and turns the exit status non-zero; one broken table no
longer hides behind the ones that printed before it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback
from typing import Callable, List


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--skip-convergence", action="store_true",
                    help="skip the (slow) training-based benchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="fast bit-rot check: every analytic entrypoint, "
                         "tiny kernel timings, no training (implies "
                         "--skip-convergence)")
    ap.add_argument("--out-dir", default="benchmarks/out")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every section's metrics dict (plus "
                         "the failure list) to PATH — the machine-readable "
                         "artifact CI uploads as the perf trajectory")
    args = ap.parse_args()
    if args.smoke:
        args.skip_convergence = True
    os.makedirs(args.out_dir, exist_ok=True)

    blobs = {}
    failures: List[str] = []

    def section(name: str, fn: Callable[[], None]) -> None:
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"benchmarks.failed.{name},1,bool")

    # Fig. 4 / 357x
    def fig4() -> None:
        from benchmarks import throughput
        for arch in ("opt-1.3b", "qwen1.5-107b"):
            r = throughput.run(arch)
            blobs[f"fig4_{arch}"] = r
            for m, v in r["methods"].items():
                print(f"fig4_throughput.{arch}.{m},{v['tokens_per_s']},"
                      f"tokens_per_s")
            print(f"fig4_speedup.{arch}.diloco_x,"
                  f"{r['speedup_vs_allreduce']['diloco_x']},x_vs_allreduce")
    section("fig4_throughput", fig4)

    # kernels
    def kernels() -> None:
        from benchmarks import kernels_bench
        kb = kernels_bench.run(smoke=args.smoke)
        blobs["kernels"] = kb
        for k, v in kb.items():
            print(f"kernels.{k},{v:.1f},us_per_call")
    section("kernels", kernels)

    # Table 1 (throughput column always; loss column unless skipped)
    def table1() -> None:
        from benchmarks import ablation
        if args.skip_convergence:
            tp = ablation.throughput_column()
            blobs["table1_throughput"] = tp
            for k, v in tp.items():
                print(f"table1_ablation.{k},{v:.1f},tokens_per_s")
        else:
            ab = ablation.run(fast=args.fast)
            blobs["table1"] = ab
            for k, v in ab["rows"].items():
                print(f"table1_ablation.{k}.loss,{v['loss']},nll")
                print(f"table1_ablation.{k}.throughput,{v['tokens_per_s']},"
                      f"tokens_per_s")
            print(f"table1_ablation.ordering_ok,"
                  f"{int(ab['throughput_ordering_ok'])},bool")
    section("table1_ablation", table1)

    # Fig. 3 convergence
    def fig3() -> None:
        from benchmarks import convergence
        cv = convergence.run(fast=args.fast)
        blobs["fig3"] = cv
        for m in ("allreduce", "diloco_x", "opendiloco", "cocktail"):
            print(f"fig3_convergence.{m}.final_loss,{cv[m]['final']:.3f},nll")
        print(f"fig3_convergence.ordering_ok,{int(cv['ordering_ok'])},bool")
    if not args.skip_convergence:
        section("fig3_convergence", fig3)

    # beyond-paper: decentralized scaling envelope
    def scaling_env() -> None:
        from benchmarks import scaling
        sc = scaling.run()
        blobs["scaling"] = sc
        for k, v in sc["max_fully_hidden_clusters"].items():
            print(f"scaling.max_hidden_clusters.{k},{v},clusters")
    section("scaling", scaling_env)

    # beyond-paper: virtual-cluster fault/churn scenario sweep (sim/)
    def sim_sweep() -> None:
        from benchmarks import sim_scenarios
        ss = sim_scenarios.run(fast=args.fast or args.skip_convergence)
        blobs["sim_scenarios"] = ss
        for arch, m in ss["methods"].items():
            print(f"sim_methods.{arch}.diloco_x,"
                  f"{m['speedup_vs_allreduce']['diloco_x']},x_vs_allreduce")
        for tag, sweep in ss["fault_sweep"].items():
            for case, row in sweep.items():
                print(f"sim_faults.{tag}.{case},{row['retention']},retention")
    section("sim_scenarios", sim_sweep)

    # beyond-paper: gossip vs gather over the topology subsystem
    def gossip() -> None:
        from benchmarks import gossip_vs_gather
        gg = gossip_vs_gather.run(fast=args.fast or args.skip_convergence)
        for row in gg["topologies"].values():
            row.pop("timeline_table", None)
        blobs["gossip_vs_gather"] = gg
        crit = gg["criteria"]
        print(f"gossip_vs_gather.bytes_saved_frac,"
              f"{crit['bytes_saved_frac']},frac")
        print(f"gossip_vs_gather.final_loss_gap,"
              f"{crit['final_loss_gap']:.4f},nll")
        print(f"gossip_vs_gather.ok,{int(crit['ok'])},bool")
        if not crit["ok"]:
            raise AssertionError("gossip-vs-gather acceptance criteria "
                                 "failed")
    section("gossip_vs_gather", gossip)

    # beyond-paper: bandwidth-aware adaptive compression on a degraded link
    def adaptive_link_bench() -> None:
        from benchmarks import adaptive_link
        al = adaptive_link.run(fast=args.fast or args.skip_convergence)
        for row in al["variants"].values():
            row.pop("timeline_table", None)
        blobs["adaptive_link"] = al
        crit = al["criteria"]
        print(f"adaptive_link.degraded_round_time_gain,"
              f"{crit['degraded_round_time_gain']},x_vs_fixed")
        print(f"adaptive_link.loss_gap_at_budget,"
              f"{crit['final_loss_gap_at_budget']:.4f},nll")
        print(f"adaptive_link.ok,{int(crit['ok'])},bool")
        if not crit["ok"]:
            raise AssertionError("adaptive-link acceptance criteria failed")
    section("adaptive_link", adaptive_link_bench)

    # beyond-paper: heterogeneous local-step scheduling under stragglers
    def straggler_h_bench() -> None:
        from benchmarks import straggler_h
        sh = straggler_h.run(fast=args.fast or args.skip_convergence)
        for pair in sh["scenarios"].values():
            for name in ("global", "balance"):
                pair[name].pop("timeline_table", None)
        blobs["straggler_h"] = sh
        for tag, pair in sh["scenarios"].items():
            print(f"straggler_h.{tag}.barrier_idle_cut,"
                  f"{pair['criteria']['barrier_idle_cut']},frac")
            print(f"straggler_h.{tag}.loss_gap_at_budget,"
                  f"{pair['criteria']['final_loss_gap_at_budget']},nll")
        print(f"straggler_h.gossip_clamp_binds,"
              f"{int(sh['criteria']['gossip_clamp_binds'])},bool")
        print(f"straggler_h.ok,{int(sh['criteria']['ok'])},bool")
        if not sh["criteria"]["ok"]:
            raise AssertionError("straggler-h acceptance criteria failed")
    section("straggler_h", straggler_h_bench)

    # beyond-paper: 64-cluster bounded-stale fleet vs the global barrier
    def fleet_async_bench() -> None:
        from benchmarks import fleet_async
        fa = fleet_async.run(fast=args.fast or args.skip_convergence)
        blobs["fleet_async"] = fa
        crit = fa["criteria"]
        print(f"fleet_async.barrier_idle_cut,"
              f"{crit['barrier_idle_cut']},frac")
        print(f"fleet_async.overlap_efficiency,"
              f"{crit['overlap_efficiency_async']},frac")
        print(f"fleet_async.makespan_gain,{crit['makespan_gain']},"
              f"x_vs_barrier")
        print(f"fleet_async.wall_clock_win,{crit['wall_clock_win']},"
              f"x_loss_at_makespan")
        print(f"fleet_async.ok,{int(crit['ok'])},bool")
        if not crit["ok"]:
            raise AssertionError("fleet-async acceptance criteria failed")
    section("fleet_async", fleet_async_bench)

    # beyond-paper: paged-KV continuous-batching serving under Poisson load
    def serve_load_bench() -> None:
        from benchmarks import serve_load
        sl = serve_load.run(fast=args.fast or args.skip_convergence)
        blobs["serve_load"] = sl
        crit = sl["criteria"]
        cont = sl["continuous"]
        print(f"serve_load.throughput_gain,{crit['throughput_gain']},"
              f"x_vs_static")
        print(f"serve_load.decode_tok_s,{cont['decode_tok_s']:.1f},"
              f"tokens_per_s")
        print(f"serve_load.per_token_p99,{cont['per_token_ms_p99']:.2f},ms")
        print(f"serve_load.ttft_p99,{cont['ttft_steps_p99']:.0f},steps")
        print(f"serve_load.deterministic,{int(crit['deterministic'])},bool")
        print(f"serve_load.ok,{int(crit['ok'])},bool")
        if not crit["ok"]:
            raise AssertionError("serve-load acceptance criteria failed")
    section("serve_load", serve_load_bench)

    # analytic fused-vs-unfused outer-step compressor roofline (no inputs)
    def roofline_outer() -> None:
        from benchmarks import roofline
        rows = roofline.outer_step_rows()
        blobs["roofline_outer_step"] = rows
        for row in rows:
            print(f"roofline_outer.{row['matrix']}.hbm_cut,"
                  f"{row['hbm_traffic_cut_x']:.2f},x_traffic")
            print(f"roofline_outer.{row['matrix']}.wire_dominated,"
                  f"{int(row['wire_dominated'])},bool")
    section("roofline_outer_step", roofline_outer)

    # roofline (if the dry-run matrix has been produced)
    def roofline_rows() -> None:
        from benchmarks import roofline
        with open("dryrun_results.json") as f:
            rows = roofline.build_rows(json.load(f))
        blobs["roofline"] = rows
        ok = sum(1 for r in rows if r.get("status") == "ok")
        print(f"roofline.combos_ok,{ok},count")
    if os.path.exists("dryrun_results.json"):
        section("roofline", roofline_rows)

    with open(os.path.join(args.out_dir, "results.json"), "w") as f:
        json.dump(blobs, f, indent=1, default=str)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"sections": blobs, "failures": failures},
                      f, indent=1, default=str)

    # Prometheus text exposition of every numeric leaf (same flattening
    # the trajectory diff uses), for scraping benchmark history into a
    # dashboard without parsing the nested JSON
    import re
    from benchmarks.trajectory import flatten
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    for path, value in sorted(flatten(blobs).items()):
        name = "bench_" + re.sub(r"[^a-zA-Z0-9_]", "_", path)
        reg.gauge(name, help=f"benchmark leaf {path}").set(value)
    reg.gauge("bench_failures",
              help="benchmark sections that raised").set(len(failures))
    reg.write_prometheus(os.path.join(args.out_dir, "results.prom"))
    if failures:
        print(f"benchmarks.done,0,bool  # FAILED: {', '.join(failures)}")
        sys.exit(1)
    print("benchmarks.done,1,bool")


if __name__ == "__main__":
    main()
