"""Paper Fig. 4 + the 357x headline: end-to-end throughput of AllReduce /
OpenDiLoCo / CocktailSGD / DiLoCoX over a 1 Gbps decentralized link.

Everything is *derived*, not transcribed:
 - wire bytes: real parameter shapes (eval_shape) x each method's
   compressor accounting (core.compression), including index overheads the
   paper's "compression ratio" quietly ignores;
 - ring-AllReduce / all-gather times at 1 Gbps (core.comm);
 - local step time from a FLOPs model of the paper's hardware:
   t_step = 6 N tokens / (n_gpus * 312 TF * MFU). Fitting MFU to the
   paper's published throughputs gives a consistent ~4.5% on BOTH models
   (OPT-1.3B on 16 A800s and Qwen1.5-107B on 160 A800s — low MFU is
   plausible for 40G A800s + cross-node PP), so MFU=0.045 is the single
   calibrated constant; tokens/step = 36k inferred the same way.
 - the §2.3 one-step-delay overlap hides comm behind H*t_step.

The claim under reproduction: DiLoCoX ~357x vs AllReduce and ~1.35x vs
CocktailSGD at 107B; ~32x vs AllReduce at 1.3B (paper §4.2.2).
"""
from __future__ import annotations

import json
from typing import Dict

from repro.core import comm
from repro.core.compression import (CocktailSGD, FP16, LowRankQuant,
                                    tree_shapes)

A800_PEAK = 312e12
MFU = 0.045
TOKENS_PER_STEP = 36_000
N_GPUS = {"opt-1.3b": 16, "qwen1.5-107b": 160}


def model_setup(arch: str):
    from repro.configs.base import get_config
    from repro.launch import steps
    from repro.models.model import count_params

    cfg = get_config(arch)
    p_specs = steps.params_specs(cfg)
    shapes = tree_shapes(p_specs)
    n_params = count_params(cfg)
    return cfg, shapes, n_params


def run(arch: str = "qwen1.5-107b", n_clusters: int = 2,
        h_steps: int = 125, rank: int = 2048) -> Dict:
    cfg, shapes, n_params = model_setup(arch)
    n_gpus = N_GPUS.get(arch, 16)
    t_step = 6.0 * n_params * TOKENS_PER_STEP / (n_gpus * A800_PEAK * MFU)
    sc = comm.CommScenario(n_clusters=n_clusters, t_step_s=t_step,
                           tokens_per_step=TOKENS_PER_STEP)

    param_bytes = n_params * 4.0
    rows = {}
    rows["allreduce"] = comm.method_throughput(
        "allreduce", param_bytes_fp32=param_bytes,
        wire_bytes=param_bytes, h_steps=1, overlap=False, sc=sc,
        allreduce_per_step=True)
    fp16 = FP16()
    rows["opendiloco"] = comm.method_throughput(
        "opendiloco", param_bytes_fp32=param_bytes,
        wire_bytes=fp16.wire_bytes(shapes), h_steps=4 * h_steps,
        overlap=False, sc=sc)
    cocktail = CocktailSGD(random_ratio=0.1,
                           topk_ratio=0.04 if "107" in arch else 0.08,
                           bits=4)
    rows["cocktail"] = comm.method_throughput(
        "cocktail", param_bytes_fp32=param_bytes,
        wire_bytes=cocktail.wire_bytes(shapes), h_steps=1, overlap=False,
        sc=sc, allreduce_per_step=True)
    # paper hyperparams: r=2048 at 107B; at 1.3B the paper used quant+H
    # only ("we did not use the adaptive algorithm"), r=64 matches its 500x
    dlx = LowRankQuant(rank=rank if "107" in arch else 64, bits=4)
    rows["diloco_x"] = comm.method_throughput(
        "diloco_x", param_bytes_fp32=param_bytes,
        wire_bytes=dlx.wire_bytes(shapes), h_steps=h_steps, overlap=True,
        sc=sc)

    out = {"arch": arch, "n_params": n_params,
           "t_step_s": round(t_step, 3), "n_gpus": n_gpus, "methods": {}}
    for k, r in rows.items():
        out["methods"][k] = {
            "tokens_per_s": round(r.tokens_per_s, 1),
            "t_round_s": round(r.t_round_s, 2),
            "comm_s": round(r.comm_s_per_round, 2),
            "exposed_comm_s": round(r.exposed_comm_s, 2),
            "wire_MB": round(r.wire_bytes / 1e6, 1),
            "compression_x": round(param_bytes / r.wire_bytes, 1),
        }
    ar = rows["allreduce"].tokens_per_s
    out["speedup_vs_allreduce"] = {
        k: round(r.tokens_per_s / ar, 1) for k, r in rows.items()}
    out["diloco_x_vs_cocktail"] = round(
        rows["diloco_x"].tokens_per_s / rows["cocktail"].tokens_per_s, 2)
    out["paper_reference"] = (
        {"allreduce": 10.4, "cocktail": 2427, "diloco_x": 3728,
         "speedup": 357} if "107" in arch else
        {"allreduce": 745, "cocktail": 16161, "diloco_x": 23880,
         "speedup": 32})
    return out


if __name__ == "__main__":
    for arch in ("opt-1.3b", "qwen1.5-107b"):
        print(json.dumps(run(arch), indent=1))
