"""Unit + property tests for the DiLoCoX compressor stack (paper §2.4,
Lemma 3.6) — hypothesis drives shapes/ranks/bit-widths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compression as C

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# quantization properties
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(n=st.integers(8, 2000), bits=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 10))
def test_quant_elementwise_bound(n, bits, seed):
    """|dequant(x) - x| <= scale/2 per element, scale = blockmax/qmax."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
    block = 256
    out = np.asarray(C.quantize_sim(jnp.asarray(x), bits, block))
    qmax = 2.0 ** (bits - 1) - 1
    pad = (-n) % block
    xp = np.pad(x, (0, pad)).reshape(-1, block)
    scale = np.abs(xp).max(1) / qmax
    bound = np.repeat(np.maximum(scale, 1e-12), block)[:n] / 2 + 1e-6
    assert (np.abs(out - x) <= bound).all()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 500), bits=st.sampled_from([4, 8]),
       seed=st.integers(0, 5))
def test_quant_idempotent(n, bits, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    once = C.quantize_sim(x, bits)
    twice = C.quantize_sim(once, bits)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=0, atol=1e-6)


def test_quant_zero_input():
    x = jnp.zeros((100,))
    assert np.allclose(np.asarray(C.quantize_sim(x, 4)), 0.0)


# ---------------------------------------------------------------------------
# Lemma 3.6: end-to-end compressor error bound
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(m=st.integers(64, 200), n=st.integers(64, 200),
       rank=st.sampled_from([8, 16, 32]), bits=st.sampled_from([4, 8]),
       seed=st.integers(0, 5))
def test_lemma_3_6_error_bound(m, n, rank, bits, seed):
    """E||C(x)-x||^2 <= omega^2 ||x||^2 with omega^2 = 1 - (r/d) 2^{-q}
    (paper Lemma 3.6), for Gaussian inputs."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n)) / np.sqrt(n)
    comp = C.LowRankQuant(rank=rank, bits=bits)
    state = comp.init_state({"w": x})
    out, _ = comp.roundtrip({"w": x}, state)
    err = float(jnp.sum((out["w"] - x) ** 2))
    nrm = float(jnp.sum(x ** 2))
    d = min(m, n)
    omega2 = 1.0 - (min(rank, d) / d) * (2.0 ** (-bits))
    assert err / nrm <= omega2 + 1e-3, (err / nrm, omega2)


def test_lowrank_exact_at_full_rank():
    """rank >= min(m,n) and high bits => near-exact reconstruction after the
    warm-start iteration converges."""
    x = jax.random.normal(jax.random.PRNGKey(0), (48, 64))
    # build an exactly rank-16 matrix
    u = jax.random.normal(jax.random.PRNGKey(1), (48, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (16, 64))
    xl = (u @ v) / 16.0
    comp = C.LowRankQuant(rank=16, bits=16, min_dim_for_lowrank=8)
    state = comp.init_state({"w": xl})
    # a few warm-start iterations (PowerSGD subspace converges)
    out = None
    for _ in range(4):
        out, state = comp.roundtrip({"w": xl}, state)
    rel = float(jnp.linalg.norm(out["w"] - xl) / jnp.linalg.norm(xl))
    assert rel < 0.05, rel


def test_rank_mask_matches_true_rank():
    """rank_scalar masking == a compressor built with that smaller rank
    (same warm start), the jit-shape-stable adaptive-rank trick."""
    x = jax.random.normal(jax.random.PRNGKey(0), (96, 128))
    big = C.LowRankQuant(rank=32, bits=16, min_dim_for_lowrank=8)
    st_b = big.init_state({"w": x})
    out_m, _ = big.roundtrip({"w": x}, st_b, rank_scalar=jnp.asarray(8))
    small = C.LowRankQuant(rank=8, bits=16, min_dim_for_lowrank=8)
    st_s = {"w": jax.tree.leaves(st_b)[0][:, :8]}
    out_s, _ = small.roundtrip({"w": x}, st_s)
    np.testing.assert_allclose(np.asarray(out_m["w"]),
                               np.asarray(out_s["w"]), atol=2e-3)


# ---------------------------------------------------------------------------
# wire-bytes accounting (feeds the 357x throughput model)
# ---------------------------------------------------------------------------

def test_wire_bytes_ratios():
    shapes = {"w1": (4096, 4096), "w2": (4096, 16384), "b": (4096,)}
    raw = sum(np.prod(s) for s in shapes.values()) * 4
    lr = C.LowRankQuant(rank=64, bits=4)
    ratio = raw / lr.wire_bytes(shapes)
    assert ratio > 100, ratio   # low-rank+int4 compresses >100x here
    # adaptive rank shrinks the wire
    assert lr.wire_bytes(shapes, rank=16) < lr.wire_bytes(shapes, rank=64)
    # fp16 is exactly 2x
    assert abs(raw / C.FP16().wire_bytes(shapes) - 2.0) < 1e-6


def test_compression_ratio_paper_107b_setting():
    """Paper §4.1.3: rank 2048 on the 107B model ~ 'approximately 2x'
    low-rank compression, int4 ~8x, LocalSGD H=125 amortizes the rest of the
    1000x communication reduction."""
    d = 8192
    shapes = {"w": (d, 4 * d)}
    lr = C.LowRankQuant(rank=2048, bits=4)
    raw = d * 4 * d * 4
    wire = lr.wire_bytes(shapes)
    # (m+n)*r*0.5 bytes vs m*n*4: (8192+32768)*2048 / 2 = 42MB vs 1073MB
    assert 20 < raw / wire < 40, raw / wire


# ---------------------------------------------------------------------------
# baselines sanity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["topk", "random_sparse", "cocktail"])
def test_sparse_compressors_shrink_wire(name):
    comp = C.make_compressor(name)
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 256))}
    state = comp.init_state(tree)
    out, state2 = comp.roundtrip(tree, state)
    assert out["w"].shape == tree["w"].shape
    nz = float((out["w"] != 0).mean())
    assert nz < 0.5
    assert comp.wire_bytes(C.tree_shapes(tree)) < 256 * 256 * 4


def test_random_sparse_unbiased():
    """E[roundtrip(x)] == x for random sparsification (importance-weighted)."""
    comp = C.RandomSparse(ratio=0.25)
    x = {"w": jnp.ones((64, 64))}
    state = comp.init_state(x)
    acc = jnp.zeros((64, 64))
    n = 200
    for _ in range(n):
        out, state = comp.roundtrip(x, state)
        acc = acc + out["w"]
    assert abs(float(acc.mean()) / n - 1.0) < 0.1


# ---------------------------------------------------------------------------
# compressor backend switch (ref vs fused pallas kernels)
# ---------------------------------------------------------------------------

def _backend_pair(**kw):
    kw.setdefault("rank", 8)
    kw.setdefault("min_dim_for_lowrank", 8)
    return (C.LowRankQuant(**kw),
            C.LowRankQuant(backend="pallas", **kw))


def test_backend_validation():
    with pytest.raises(ValueError):
        C.LowRankQuant(backend="cuda")
    with pytest.raises(ValueError):
        C.LowRankQuant(backend="pallas", bits=8)
    assert C.make_compressor(
        "diloco_x", rank=4, backend="pallas").backend == "pallas"


def test_backend_pallas_matches_ref_roundtrip():
    """Same warm start, same wire format: the pallas backend's roundtrip
    tracks the ref chain within quantization-step tolerance over several
    rounds (warm starts drift by reorder ulps, so not bitwise)."""
    cr, cp = _backend_pair()
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (48, 32)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (32,))}
    sr, sp = cr.init_state(params), cp.init_state(params)
    for rnd in range(3):
        delta = jax.tree.map(lambda x: x * (0.3 + 0.1 * rnd), params)
        outr, sr = cr.roundtrip(delta, sr)
        outp, sp = cp.roundtrip(delta, sp)
        for k in outr:
            a, b = np.asarray(outr[k]), np.asarray(outp[k])
            assert np.max(np.abs(a - b)) < 5e-2 * max(np.abs(a).max(), 1.0), \
                f"round {rnd} leaf {k}"


def test_backend_pallas_quant_only_bitwise_under_jit():
    """Small/1-D tensors skip low-rank: under jit both backends run the
    identical f32 op sequence, so the values are bitwise equal."""
    cr, cp = _backend_pair()
    x = {"b": jax.random.normal(jax.random.PRNGKey(7), (300,))}
    sr, sp = cr.init_state(x), cp.init_state(x)
    outr = jax.jit(lambda t, s: cr.roundtrip(t, s)[0])(x, sr)
    outp = jax.jit(lambda t, s: cp.roundtrip(t, s)[0])(x, sp)
    np.testing.assert_array_equal(np.asarray(outr["b"]),
                                  np.asarray(outp["b"]))


def test_backend_pallas_jit_rank_traced():
    """One compiled roundtrip serves every adaptive r_t (jit shape
    stability), and masked warm-start columns stay exactly zero."""
    _, cp = _backend_pair()
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 48))}
    sp = cp.init_state(params)
    fn = jax.jit(lambda t, s, r: cp.roundtrip(t, s, rank_scalar=r))
    for rt in (8, 5, 2):
        out, s2 = fn(params, sp, jnp.int32(rt))
        assert out["w"].shape == (64, 48)
        assert np.all(np.isfinite(np.asarray(out["w"])))
        if rt < 8:
            assert not np.asarray(s2["w"])[:, rt:].any()
