"""Adaptive compression controller (core/adaptive.py): Alg. 3 property
tests, the stable-rank estimator vs exact SVD, the bandwidth/hybrid budget
solver (incl. per-edge gossip ranks), and the trainer's executed-rank
accounting (regression: the logged rank/H used to be the NEXT round's)."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import adaptive
from repro.core.adaptive import (AdaGradCmpConfig, AdaGradCmpState,
                                 AdaptiveSpec, adagradcmp_update)
from repro.core.compression import LowRankQuant
from repro.topology import make_topology

SHAPES = {"w0": (64, 64), "w1": (64, 64)}


def _compressor(r1=16):
    return LowRankQuant(rank=r1, min_dim_for_lowrank=8)


# ---------------------------------------------------------------------------
# Alg. 3 (adagradcmp_update) properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(r1=st.integers(8, 128), h1=st.integers(16, 300),
       c=st.integers(1, 6), rp=st.floats(1.0, 200.0),
       mode=st.sampled_from(["paper", "overlap"]))
def test_adagradcmp_warmup_and_h_formulas(r1, h1, c, rp, mode):
    """Window warm-up returns exactly (r1, h1); the first post-warm-up
    step clamps r_min <= r_t <= r1 and applies the mode's H rule verbatim
    (paper: H1*(r1-r_t)/r1 with the h_min guard; overlap: H1*r_t/r1)."""
    cfg = AdaGradCmpConfig(window=c, r1=r1, h1=h1, mode=mode)
    s = AdaGradCmpState.create(cfg)
    for _ in range(c - 1):                      # t < window: warm-up
        s = adagradcmp_update(s, rp, cfg)
        assert (s.r_t, s.h_t) == (r1, h1)
    s = adagradcmp_update(s, rp, cfg)           # t == window: first anneal
    expect_r = min(r1, max(cfg.r_min, int(round(rp))))
    assert cfg.r_min <= s.r_t <= r1
    assert s.r_t == expect_r
    assert s.h_t >= cfg.h_min
    if mode == "paper":
        assert s.h_t == max(cfg.h_min,
                            int(round(h1 * (r1 - expect_r) / r1)))
    else:
        assert s.h_t == max(cfg.h_min, int(round(h1 * expect_r / r1)))


@settings(max_examples=15, deadline=None)
@given(r1=st.integers(8, 64), c=st.integers(2, 5), seed=st.integers(0, 99))
def test_adagradcmp_history_is_windowed_mean(r1, c, seed):
    """r_t equals the clamp of the rounded mean over exactly the last c
    observations, never more."""
    cfg = AdaGradCmpConfig(window=c, r1=r1, h1=100)
    s = AdaGradCmpState.create(cfg)
    rng = np.random.RandomState(seed)
    hist = []
    for _ in range(3 * c):
        rp = float(rng.uniform(1, 1.5 * r1))
        hist.append(rp)
        s = adagradcmp_update(s, rp, cfg)
    expect = min(r1, max(cfg.r_min,
                         int(round(float(np.mean(hist[-c:]))))))
    assert s.r_t == expect
    assert len(s.r_hist) == c


@settings(max_examples=10, deadline=None)
@given(m=st.integers(16, 48), n=st.integers(16, 48),
       decay=st.floats(0.3, 0.7), seed=st.integers(0, 50))
def test_stable_rank_matches_exact_svd(m, n, decay, seed):
    """Power-iteration stable rank vs the exact ||M||_F^2 / sigma_max^2
    from a full SVD, on matrices with a known (geometric) spectrum."""
    import jax.numpy as jnp

    rng = np.random.RandomState(seed)
    k = min(m, n)
    u, _ = np.linalg.qr(rng.randn(m, k))
    v, _ = np.linalg.qr(rng.randn(n, k))
    s = decay ** np.arange(k)
    M = (u * s) @ v.T
    exact = float((s ** 2).sum() / (s ** 2).max())
    est = float(adaptive.stable_rank(jnp.asarray(M, jnp.float32)))
    assert abs(est - exact) <= 0.1 * exact + 0.05


# ---------------------------------------------------------------------------
# the bandwidth/hybrid budget solver
# ---------------------------------------------------------------------------

def test_rank_gather_budget_boundary():
    """The chosen rank is the LARGEST whose modeled gather time fits the
    overlap budget: t(r) <= budget < t(r+1) (unless clamped at r1/r_min)."""
    comp = _compressor(16)
    spec = AdaptiveSpec(mode="bandwidth", r1=16, r_min=2, window=3)
    ctrl = spec.controller(comp)
    n_alive, latency, t_compute = 4, 0.0, 1.0

    def t_of(r):
        return (n_alive - 1) * comp.wire_bytes(SHAPES, rank=r) / bw

    bw = 1e12
    assert ctrl.rank_gather(comp, SHAPES, n_alive, bw, latency,
                            t_compute) == 16           # free link: r1
    bw = 1.0
    assert ctrl.rank_gather(comp, SHAPES, n_alive, bw, latency,
                            t_compute) == 2            # starved: r_min floor
    bw = 3 * comp.wire_bytes(SHAPES, rank=7) / t_compute   # mid-range
    r = ctrl.rank_gather(comp, SHAPES, n_alive, bw, latency, t_compute)
    assert 2 <= r < 16
    assert t_of(r) <= t_compute < t_of(r + 1)


def test_hybrid_is_min_of_spectral_and_bandwidth():
    comp = _compressor(16)
    spec = AdaptiveSpec(mode="hybrid", r1=16, r_min=2, window=2)
    ctrl = spec.controller(comp)
    assert ctrl.executed() == (16, spec.h1)     # pre-observe: (r1, h1)
    for _ in range(3):                          # anneal spectral state to ~6
        ctrl.observe_rank(6.0)
    assert ctrl.executed()[0] == 6
    # fat link: spectral wins
    assert ctrl.rank_gather(comp, SHAPES, 4, 1e12, 0.0, 1.0) == 6
    # starved link: bandwidth wins
    assert ctrl.rank_gather(comp, SHAPES, 4, 1.0, 0.0, 1.0) == 2


def test_gossip_per_edge_ranks_follow_each_uplink():
    """Only the degraded cluster's own send rank drops; healthy uplinks
    keep r1 (ring: every alive cluster ships to deg=2 neighbors)."""
    comp = _compressor(16)
    spec = AdaptiveSpec(mode="bandwidth", r1=16, r_min=2, window=3)
    ctrl = spec.controller(comp)
    topo = make_topology("ring", 4)
    alive = np.ones(4, bool)
    fat = 1e12
    bws = [fat, fat, 2 * comp.wire_bytes(SHAPES, rank=5), fat]  # c2 degraded
    ranks = ctrl.ranks_gossip(comp, SHAPES, topo, alive, bws, 0.0,
                              t_compute_s=1.0)
    assert ranks[0] == ranks[1] == ranks[3] == 16
    assert 2 <= ranks[2] < 16
    # dead clusters are simply absent from the decision
    alive[1] = False
    ranks = ctrl.ranks_gossip(comp, SHAPES, topo, alive, bws, 0.0, 1.0)
    assert sorted(ranks) == [0, 2, 3]


def test_adaptive_spec_roundtrip_and_scenario_meta():
    spec = AdaptiveSpec(mode="hybrid", window=4, r1=32, r_min=3,
                        overlap_frac=0.8)
    assert AdaptiveSpec.from_dict(spec.to_dict()) == spec
    from repro.sim import Scenario
    sc = Scenario(n_clusters=2, adaptive=spec)
    assert sc.meta()["adaptive"] == spec.to_dict()
    with pytest.raises(ValueError):
        AdaptiveSpec(mode="nope")


# ---------------------------------------------------------------------------
# trainer accounting (regression: train/trainer.py:171-176 off-by-one)
# ---------------------------------------------------------------------------

def test_trainer_logs_executed_rank_not_next_rounds():
    """wires/hs/rs for round r must record the controller state that round
    r EXECUTED.  With window=1 the controller anneals immediately after
    round 0, so the buggy post-update logging would report round 1's
    (r_t, H_t) as round 0's; the first adaptive round must pin to
    (r1, h1)."""
    from repro.configs.base import get_config
    from repro.train import trainer as T

    cfg = dataclasses.replace(get_config("opt-1.3b").reduced(),
                              vocab_size=64)
    tc = T.TrainConfig(n_clusters=2, local_batch=2, seq_len=16, h_steps=2,
                       compressor="diloco_x",
                       compressor_kw=dict(rank=32, min_dim_for_lowrank=8),
                       adaptive=True, adaptive_window=1, seed=0)
    res = T.run_diloco_training(cfg, tc, n_rounds=2)
    assert res.r_per_round[0] == 32        # r1: nothing observed yet
    assert res.h_per_round[0] == 2         # h1 == h_steps, not the h_min
                                           # floor the first anneal jumps to
    # the anneal shows up one round later, where it actually runs
    assert res.r_per_round[1] < 32
    assert res.h_per_round[1] >= 8         # paper-mode h_min floor
    # wire accounting follows the executed rank
    assert res.wire_bytes_per_round[0] > res.wire_bytes_per_round[1]
