"""Unit tests for the DiLoCoX round state machine: one-step-delay semantics,
error-feedback telescoping, adaptive controller (Alg. 3), and convergence
ordering on a tiny LM (the paper's Fig. 3 shape)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import adaptive, diloco
from repro.core.compression import Identity, LowRankQuant, make_compressor
from repro.optim import nesterov


def _const_inner(step_vec):
    """inner_fn that moves params by a constant (stacked over 1 cluster)."""
    def inner_fn(params, inner_opt, t):
        new = jax.tree.map(lambda p, s: (p - s)[None], params, step_vec)
        return new, inner_opt, jnp.zeros((1,))
    return inner_fn


def _mean0(tree):
    return jax.tree.map(lambda x: x.mean(0), tree)


def test_one_step_delay_shifts_by_one_round():
    """With a constant inner displacement and identity compression, the
    delayed trajectory equals the synchronous one shifted by exactly one
    outer round (the §2.3 semantics)."""
    params = {"w": jnp.zeros((4,))}
    step_vec = {"w": jnp.ones((4,))}
    comp = Identity()
    inner = _const_inner(step_vec)

    def run(delay, T):
        cfg = diloco.RoundConfig(outer_lr=1.0, outer_momentum=0.0,
                                 delay=delay, compress=False,
                                 error_feedback=False)
        st_ = diloco.init_state(params, None, 1, comp)
        traj = []
        for _ in range(T):
            st_, _ = diloco.diloco_round(st_, inner, comp, _mean0, cfg)
            traj.append(float(st_.params["w"][0]))
        return traj

    sync = run(False, 5)       # applies delta_t at round t
    delayed = run(True, 6)     # applies delta_{t-1} at round t
    # delayed round t+1 == sync round t
    np.testing.assert_allclose(delayed[1:], sync, atol=1e-6)
    # round 1 of delayed applied nothing (no pending delta yet)
    assert delayed[0] == 0.0


def test_error_feedback_telescopes():
    """Paper Alg. 2 EF: delta_{t} = raw_t + e_t with e_t = delta_{t-1} -
    Delta_{t-1}; cumulative applied Delta + pending + error == cumulative raw
    displacement (nothing lost)."""
    params = {"w": jnp.zeros((8,))}
    step_vec = {"w": jnp.linspace(0.1, 0.8, 8)}
    comp = LowRankQuant(rank=2, bits=8, min_dim_for_lowrank=1000)  # quant only
    inner = _const_inner(step_vec)
    cfg = diloco.RoundConfig(outer_lr=1.0, outer_momentum=0.0, delay=True,
                             compress=True, error_feedback=True)
    st_ = diloco.init_state(params, None, 1, comp)
    applied = jnp.zeros((8,))
    T = 6
    for t in range(T):
        prev = st_.params["w"]
        st_, _ = diloco.diloco_round(st_, inner, comp, _mean0, cfg)
        applied = applied + (prev - st_.params["w"])
    # raw displacement generated in T rounds = T * step_vec; of that,
    # applied + pending delta + current error buffer must account for all
    total = applied + st_.delta_pending["w"][0]
    np.testing.assert_allclose(np.asarray(total),
                               np.asarray(T * step_vec["w"]),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(r1=st.integers(16, 256), h1=st.integers(16, 500),
       c=st.integers(2, 8), mode=st.sampled_from(["paper", "overlap"]),
       seed=st.integers(0, 99))
def test_adaptive_controller_bounds(r1, h1, c, mode, seed):
    cfg = adaptive.AdaGradCmpConfig(window=c, r1=r1, h1=h1, mode=mode)
    st_ = adaptive.AdaGradCmpState.create(cfg)
    rng = np.random.RandomState(seed)
    for t in range(20):
        r_prime = float(rng.uniform(1, r1 * 1.5))
        st_ = adaptive.adagradcmp_update(st_, r_prime, cfg)
        assert cfg.r_min <= st_.r_t <= cfg.r1
        assert st_.h_t >= cfg.h_min
        if st_.t < c:      # warmup: Alg. 3 keeps (r1, H1)
            assert st_.r_t == r1 and st_.h_t == h1


def test_adaptive_rank_tracks_decreasing_rank():
    """As r' decreases, r_t follows (window-averaged) and overlap-mode H_t
    shrinks proportionally (comm volume matching)."""
    cfg = adaptive.AdaGradCmpConfig(window=3, r1=64, h1=120, mode="overlap")
    st_ = adaptive.AdaGradCmpState.create(cfg)
    for r_prime in [64, 64, 64, 32, 32, 32, 8, 8, 8]:
        st_ = adaptive.adagradcmp_update(st_, r_prime, cfg)
    assert st_.r_t == 8
    assert st_.h_t == max(cfg.h_min, round(120 * 8 / 64))


def test_stable_rank_estimator():
    u = jax.random.normal(jax.random.PRNGKey(0), (128, 4))
    v = jax.random.normal(jax.random.PRNGKey(1), (4, 96))
    low = u @ v                       # ~rank 4
    full = jax.random.normal(jax.random.PRNGKey(2), (128, 96))
    sr_low = float(adaptive.stable_rank(low))
    sr_full = float(adaptive.stable_rank(full))
    assert sr_low < 6
    assert sr_full > 20


def test_nesterov_descends_quadratic():
    """Outer optimizer sanity: minimizes 0.5||x||^2 fed with pseudo-grads."""
    x = {"w": jnp.ones((16,)) * 5}
    st_ = nesterov.init(x)
    for _ in range(50):
        g = {"w": 0.1 * x["w"]}       # pseudo-gradient = eta * grad
        x, st_ = nesterov.update(g, st_, x, lr=0.7, momentum=0.9)
    assert float(jnp.abs(x["w"]).max()) < 0.3
