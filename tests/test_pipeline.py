"""Mode B shard_map pipeline: loss/grad equivalence with the sequential
model, parametrized over stage counts (1 = degenerate single-stage, 2, 4)
crossed with uneven layer counts so the padded-slot path is exercised at
every width: (1,3) lps=3 pad=0, (2,5) lps=3 pad=1, (4,6) lps=2 pad=2.
Each combo runs in its own subprocess so the 8 host devices don't leak
into the main pytest process (which must keep 1 device per spec)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    STAGES = int(os.environ["PP_STAGES"])
    LAYERS = int(os.environ["PP_LAYERS"])
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.parallel import pipeline as PP

    cfg = dataclasses.replace(get_config('granite-3-8b').reduced(),
                              n_layers=LAYERS, vocab_size=128)
    pcfg = PP.PipelineConfig(n_stages=STAGES, n_micro=4)
    lps, pad = PP.layers_per_stage(cfg, pcfg)
    mesh = jax.make_mesh((1, 2, STAGES), ("clusters", "data", "model"))

    params = PP.init_pp_params(cfg, jax.random.PRNGKey(0), pcfg)
    paramsC = jax.tree.map(lambda x: x[None], params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8, 16), 0,
                                cfg.vocab_size)

    loss_fn = PP.make_pp_loss(cfg, mesh, pcfg, cluster_stacked=True)
    loss_pp = float(jax.jit(loss_fn)(paramsC, tokens))

    def ref_loss_from_pp(pC):
        p = jax.tree.map(lambda x: x[0], pC)
        sp = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                          p["stages"])
        # drop padded layers (active==0) from the sequential reference
        sp = jax.tree.map(lambda x: x[:cfg.n_layers], sp)
        rp = {"embed": p["embed"], "final_norm": p["final_norm"],
              "segments": [sp]}
        if "head" in p:
            rp["head"] = p["head"]
        return M.loss_fn(rp, cfg, {"tokens": tokens[0]}, remat=False)[0]

    ref = float(ref_loss_from_pp(paramsC))
    assert abs(loss_pp - ref) < 1e-4, (loss_pp, ref)

    g_pp = jax.jit(jax.grad(loss_fn))(paramsC, tokens)
    g_ref = jax.jit(jax.grad(ref_loss_from_pp))(paramsC)
    errs = {}
    flat_pp, _ = jax.tree_util.tree_flatten_with_path(g_pp)
    flat_rf = jax.tree.leaves(g_ref)
    for (path, a), b in zip(flat_pp, flat_rf):
        name = jax.tree_util.keystr(path)
        if "active" in name:
            continue                       # mask is not a trainable param
        errs[name] = float(jnp.abs(a - b).max())
    worst = max(errs.values())
    assert worst < 1e-3, errs
    print(f"PIPELINE-EQUIV-OK stages={STAGES} layers={LAYERS} "
          f"lps={lps} pad={pad} loss={loss_pp} worst_grad_err={worst}")
""")


@pytest.mark.slow
@pytest.mark.parametrize("stages,layers", [(1, 3), (2, 5), (4, 6)])
def test_pipeline_matches_sequential(stages, layers):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PP_STAGES"] = str(stages)
    env["PP_LAYERS"] = str(layers)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert f"PIPELINE-EQUIV-OK stages={stages} layers={layers}" in r.stdout
