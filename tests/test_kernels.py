"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle
(kernels/ref.py), sweeping shapes and dtypes (hypothesis + parametrize)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lowrank_mm import matmul_pallas
from repro.kernels.quant4 import quant4_pack_pallas, quant4_unpack_pallas


# ---------------------------------------------------------------------------
# quant4
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 5000), seed=st.integers(0, 20))
def test_quant4_pack_matches_ref(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 3.0
    p_ref, s_ref, _ = ref.quant4_pack_ref(x)
    p_pl, s_pl = quant4_pack_pallas(x)
    np.testing.assert_array_equal(np.asarray(p_pl), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                               rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 5000), seed=st.integers(0, 20))
def test_quant4_roundtrip_pallas(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 2.0
    p, s = quant4_pack_pallas(x)
    out = quant4_unpack_pallas(p, s, n)
    expect = ref.quant4_roundtrip_ref(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant4_dtypes(dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 5).astype(dtype)
    p, s = quant4_pack_pallas(x.astype(jnp.float32))
    out = quant4_unpack_pallas(p, s, 1024)
    err = np.abs(np.asarray(out) - np.asarray(x, np.float32))
    scale = np.abs(np.asarray(x, np.float32)).max() / 7
    assert err.max() <= scale / 2 + 1e-5


# ---------------------------------------------------------------------------
# tiled matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (100, 70, 36), (1, 512, 64),
                                   (333, 129, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(dtype)
    out = matmul_pallas(a, b)
    expect = ref.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
       seed=st.integers(0, 5))
def test_matmul_property(m, k, n, seed):
    a = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n))
    np.testing.assert_allclose(np.asarray(matmul_pallas(a, b, bm=64, bn=64,
                                                        bk=64)),
                               np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,d", [
    (1, 256, 4, 4, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA 2:1
    (1, 512, 8, 1, 32),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, H, KV, d, causal):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, H, d))
    k = jax.random.normal(kk, (B, S, KV, d))
    v = jax.random.normal(kv, (B, S, KV, d))
    out = flash_attention_pallas(q, k, v, causal=causal, bq=128, bk=128)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 2, 64)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64)).astype(dtype)
    out = flash_attention_pallas(q, k, v, bq=128, bk=128)
    expect = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_long_block_sweep():
    """Block-size sweep at longer sequence (the 32k-prefill configuration,
    scaled down)."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1024, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1024, 1, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 1024, 1, 64))
    expect = ref.flash_attention_ref(q, k, v)
    for bq, bk in [(128, 256), (256, 128), (512, 512)]:
        out = flash_attention_pallas(q, k, v, bq=bq, bk=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# fused outer-step compressor (kernels/fused_compress.py)
# ---------------------------------------------------------------------------

# Documented ulp bound for the fused reconstruct vs the oracle recon from
# the SAME payload: the only numeric freedom is matmul accumulation order,
# so |fused - oracle| <= ULP_K * eps * (|Pq| @ |Qq|^T) elementwise.
# ULP_K = 16 is generous (measured 0-2 ulp on CPU) to stay stable across
# both CI jax versions.
ULP_K = 16


def _fused_case(m, n, r, rt, dtype=jnp.float32, row_cap=2048):
    from repro.kernels.fused_compress import fused_compress_ef

    d = (jax.random.normal(jax.random.PRNGKey(0), (m, n)) * 0.3).astype(dtype)
    e = jax.random.normal(jax.random.PRNGKey(1), (m, n)) * 0.05
    q = jax.random.normal(jax.random.PRNGKey(2), (n, r))
    rs = None if rt is None else jnp.int32(rt)
    hat, e_new, q_new, pay = jax.jit(
        lambda d_, e_, q_: fused_compress_ef(d_, e_, q_, rs,
                                             row_cap=row_cap))(d, e, q)
    return d, e, q, hat, e_new, q_new, pay


def _assert_fused_contract(m, n, r, rt, d, e, hat, e_new, q_new, pay):
    """The full fused-kernel contract: wire bytes bit-identical to the ref
    packer, recon/EF within the ulp bound of the payload's own oracle
    recon, decompress dual exact, adaptive-rank columns exactly zero."""
    from repro.kernels.fused_compress import fused_decompress

    # 1) pack bytes bit-identical to ref.quant4_pack_ref on the factors
    pP, sP, _ = ref.quant4_pack_ref(np.asarray(pay.p_factor).reshape(-1))
    pQ, sQ, _ = ref.quant4_pack_ref(np.asarray(pay.q_factor).reshape(-1))
    np.testing.assert_array_equal(np.asarray(pay.packed_p), np.asarray(pP))
    np.testing.assert_array_equal(np.asarray(pay.packed_q), np.asarray(pQ))
    np.testing.assert_allclose(np.asarray(pay.scales_p), np.asarray(sP),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(pay.scales_q), np.asarray(sQ),
                               rtol=1e-6)

    # 2) recon within the documented ulp bound of the payload's oracle
    Pq = np.asarray(ref.quant4_unpack_ref(
        pay.packed_p, pay.scales_p, m * r)).reshape(m, r)
    Qq = np.asarray(ref.quant4_unpack_ref(
        pay.packed_q, pay.scales_q, n * r)).reshape(n, r)
    oracle = Pq @ Qq.T
    bound = ULP_K * np.finfo(np.float32).eps * (np.abs(Pq) @ np.abs(Qq).T)
    gap = np.abs(np.asarray(hat, np.float32) - oracle)
    if hat.dtype == jnp.bfloat16:       # cast after recon adds a bf16 ulp
        bound = bound + 0.008 * np.abs(oracle) + 1e-6
    assert np.all(gap <= bound + 1e-30), \
        f"recon gap {gap.max()} exceeds ulp bound {bound.max()}"

    # 3) EF residual: e' = (delta + e) - recon (f32 chain)
    M = np.asarray(d, np.float32) + np.asarray(e, np.float32)
    assert e_new.dtype == jnp.float32 and e_new.shape == (m, n)
    ef_gap = np.abs(np.asarray(e_new) - (M - oracle))
    assert np.all(ef_gap <= bound + 2e-6 * np.abs(M) + 1e-6)

    # 4) decompress dual reproduces the forward kernel's recon exactly
    dec = fused_decompress(pay.packed_p, pay.scales_p, pay.packed_q,
                           pay.scales_q, m, n, r,
                           out_dtype=hat.dtype)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(hat))

    # 5) adaptive rank: masked columns are exactly zero end to end
    if rt is not None:
        assert not np.asarray(pay.p_factor)[:, rt:].any()
        assert not np.asarray(pay.q_factor)[:, rt:].any()
        assert not np.asarray(q_new)[:, rt:].any()


def test_fused_compress_smoke():
    """Fast tier-1 gate: one small aligned case end to end."""
    m, n, r, rt = 64, 48, 8, None
    d, e, q, hat, e_new, q_new, pay = _fused_case(m, n, r, rt)
    _assert_fused_contract(m, n, r, rt, d, e, hat, e_new, q_new, pay)


@pytest.mark.slow
@pytest.mark.parametrize("m,n,r,rt", [
    (256, 256, 32, None),     # tile-aligned
    (257, 129, 8, 5),         # non-tile-multiple rows+cols, adaptive rank
    (128, 128, 64, 32),       # r = half masked
    (33, 500, 12, 7),         # wide, blocks straddle row boundaries
    (300, 200, 16, None),     # padded both dims
    (2048, 512, 64, 48),      # multi-tile rows at default row_cap
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_compress_shapes_dtypes(m, n, r, rt, dtype):
    d, e, q, hat, e_new, q_new, pay = _fused_case(m, n, r, rt, dtype)
    _assert_fused_contract(m, n, r, rt, d, e, hat, e_new, q_new, pay)


@pytest.mark.slow
@pytest.mark.parametrize("row_cap", [128, 512])
def test_fused_compress_small_tiles(row_cap):
    """The multi-grid-step path (k-loop accumulation + tile-boundary
    packing) must honor the same contract as single-tile grids."""
    m, n, r, rt = 384, 320, 16, 10
    d, e, q, hat, e_new, q_new, pay = _fused_case(m, n, r, rt,
                                                  row_cap=row_cap)
    _assert_fused_contract(m, n, r, rt, d, e, hat, e_new, q_new, pay)


@pytest.mark.slow
def test_fused_vs_ref_chain():
    """Chain-vs-chain: the fused pipeline against the independently-run
    unfused ref op-chain.  Scales can differ by 1 ulp between the two
    (XLA's divide-by-constant rewrite), which near a rounding tie can
    flip a single int4 code — so the bound allows one code step per
    factor on top of the reorder ulp bound."""
    from repro.kernels.fused_compress import fused_compress_ef

    for m, n, r, rt in [(128, 96, 16, None), (200, 333, 8, 6)]:
        d = jax.random.normal(jax.random.PRNGKey(3), (m, n)) * 0.3
        e = jax.random.normal(jax.random.PRNGKey(4), (m, n)) * 0.05
        q = jax.random.normal(jax.random.PRNGKey(5), (n, r))
        rs = None if rt is None else jnp.int32(rt)
        hat_f, e_f, qn_f, pay_f = jax.jit(lambda a, b, c: fused_compress_ef(
            a, b, c, rs))(d, e, q)
        hat_r, e_r, qn_r, pay_r = jax.jit(lambda a, b, c: ref.outer_step_ref(
            a, b, c, rs))(d, e, q)
        sP = np.asarray(pay_r.scales_p).max()
        sQ = np.asarray(pay_r.scales_q).max()
        Pq = np.abs(np.asarray(pay_r.p_factor)).max()
        Qq = np.abs(np.asarray(pay_r.q_factor)).max()
        atol = sP * Qq + sQ * Pq            # one int4 step per factor
        np.testing.assert_allclose(np.asarray(hat_f), np.asarray(hat_r),
                                   rtol=0, atol=atol)
        np.testing.assert_allclose(np.asarray(qn_f), np.asarray(qn_r),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_fused_rank_scalar_traced():
    """jit-shape-stable adaptive rank: ONE compiled function serves every
    r_t; masked columns stay exactly zero and smaller r_t reconstructs
    strictly less energy."""
    from repro.kernels.fused_compress import fused_compress_ef

    m, n, r = 96, 128, 16
    d = jax.random.normal(jax.random.PRNGKey(0), (m, n))
    e = jnp.zeros((m, n))
    q = jax.random.normal(jax.random.PRNGKey(2), (n, r))
    fn = jax.jit(lambda d_, e_, q_, rt: fused_compress_ef(d_, e_, q_, rt))
    norms = []
    for rt in (16, 8, 4):
        hat, _, q_new, pay = fn(d, e, q, jnp.int32(rt))
        assert hat.shape == (m, n) and q_new.shape == (n, r)
        if rt < r:
            assert not np.asarray(pay.q_factor)[:, rt:].any()
        norms.append(float(jnp.linalg.norm(hat)))
    assert norms[0] > norms[1] > norms[2] > 0


def test_fused_ops_dispatch(monkeypatch):
    """kernels.ops.fused_outer_step routes by REPRO_USE_PALLAS and both
    routes satisfy the same contract."""
    from repro.kernels import ops

    m, n, r = 48, 64, 8
    d = jax.random.normal(jax.random.PRNGKey(0), (m, n))
    e = jax.random.normal(jax.random.PRNGKey(1), (m, n)) * 0.1
    q = jax.random.normal(jax.random.PRNGKey(2), (n, r))
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    hat_r, e_r, qn_r, pay_r = ops.fused_outer_step(d, e, q)
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    hat_p, e_p, qn_p, pay_p = ops.fused_outer_step(d, e, q)
    assert hat_r.shape == hat_p.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(pay_p.packed_p),
                                  np.asarray(ref.quant4_pack_ref(
                                      np.asarray(pay_p.p_factor).reshape(-1)
                                  )[0]))
    np.testing.assert_allclose(np.asarray(hat_p), np.asarray(hat_r),
                               rtol=0, atol=0.3)
    np.testing.assert_allclose(np.asarray(qn_p), np.asarray(qn_r),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention (serve engine): pallas kernel vs ref gather path
# ---------------------------------------------------------------------------

def _paged_fixture(seed, *, S, P, ps, KV, G, dh, fill_frac=0.8):
    """Random page pool + table + lengths; scratch page 0 holds garbage to
    prove the masking contract kills unallocated reads."""
    from repro.serve.pages import PageManager

    rng = np.random.default_rng(seed)
    n_pages = S * P
    pm = PageManager(n_pages, ps, S, P)
    lengths = np.zeros(S, np.int32)
    for s in range(S):
        lengths[s] = rng.integers(1, int(P * ps * fill_frac) + 1)
        pm.admit(s, int(lengths[s]))
        for pos in range(int(lengths[s])):
            pm.ensure(s, pos)
    H = KV * G
    k = rng.normal(size=(1 + n_pages, ps, KV, dh)).astype(np.float32)
    v = rng.normal(size=(1 + n_pages, ps, KV, dh)).astype(np.float32)
    k[0] = 1e3          # scratch-page garbage must never leak into outputs
    v[0] = 1e3
    q = rng.normal(size=(S, 1, H, dh)).astype(np.float32)
    cache = {"k": jnp.asarray(k), "v": jnp.asarray(v)}
    return (jnp.asarray(q), cache, jnp.asarray(pm.page_table),
            jnp.asarray(lengths))


@pytest.mark.parametrize("window", [0, 5])
@pytest.mark.parametrize("S,P,ps,KV,G,dh", [
    (3, 4, 4, 2, 2, 8),
    (2, 3, 8, 1, 4, 16),
])
def test_paged_attention_pallas_matches_ref(window, S, P, ps, KV, G, dh):
    from repro.serve import attention_paged as ap

    q, cache, table, lengths = _paged_fixture(0, S=S, P=P, ps=ps, KV=KV,
                                              G=G, dh=dh)
    ref_out = ap.ref_paged_attention(q, cache, table, lengths,
                                     window=window)
    pal_out = ap.pallas_paged_attention(q, cache, table, lengths,
                                        window=window)
    assert not np.isnan(np.asarray(pal_out)).any()
    np.testing.assert_allclose(np.asarray(pal_out), np.asarray(ref_out),
                               rtol=1e-3, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1_000), ps=st.sampled_from([2, 4, 8]),
       g=st.sampled_from([1, 2, 4]))
def test_paged_attention_pallas_property(seed, ps, g):
    from repro.serve import attention_paged as ap

    q, cache, table, lengths = _paged_fixture(seed, S=2, P=3, ps=ps, KV=2,
                                              G=g, dh=8)
    ref_out = ap.ref_paged_attention(q, cache, table, lengths)
    pal_out = ap.pallas_paged_attention(q, cache, table, lengths)
    np.testing.assert_allclose(np.asarray(pal_out), np.asarray(ref_out),
                               rtol=1e-3, atol=1e-5)


def test_paged_write_kv_routes_inactive_to_scratch():
    from repro.serve import attention_paged as ap

    ps, KV, dh = 4, 2, 8
    cache = {"k": jnp.zeros((5, ps, KV, dh)), "v": jnp.zeros((5, ps, KV, dh))}
    table = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    lengths = jnp.asarray([5, 2], jnp.int32)       # row 0 -> page 2 slot 1
    k_new = jnp.ones((2, KV, dh))
    out = ap.write_kv(cache, k_new, k_new, table,
                      lengths, jnp.asarray([True, False]))
    k = np.asarray(out["k"])
    assert k[2, 1].all()                            # active row landed
    assert not k[3].any() and not k[4].any()        # inactive row did not
    assert k[0, 2].all()                            # ... it went to scratch
