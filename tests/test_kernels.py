"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle
(kernels/ref.py), sweeping shapes and dtypes (hypothesis + parametrize)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lowrank_mm import matmul_pallas
from repro.kernels.quant4 import quant4_pack_pallas, quant4_unpack_pallas


# ---------------------------------------------------------------------------
# quant4
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 5000), seed=st.integers(0, 20))
def test_quant4_pack_matches_ref(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 3.0
    p_ref, s_ref, _ = ref.quant4_pack_ref(x)
    p_pl, s_pl = quant4_pack_pallas(x)
    np.testing.assert_array_equal(np.asarray(p_pl), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                               rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 5000), seed=st.integers(0, 20))
def test_quant4_roundtrip_pallas(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 2.0
    p, s = quant4_pack_pallas(x)
    out = quant4_unpack_pallas(p, s, n)
    expect = ref.quant4_roundtrip_ref(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant4_dtypes(dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 5).astype(dtype)
    p, s = quant4_pack_pallas(x.astype(jnp.float32))
    out = quant4_unpack_pallas(p, s, 1024)
    err = np.abs(np.asarray(out) - np.asarray(x, np.float32))
    scale = np.abs(np.asarray(x, np.float32)).max() / 7
    assert err.max() <= scale / 2 + 1e-5


# ---------------------------------------------------------------------------
# tiled matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (100, 70, 36), (1, 512, 64),
                                   (333, 129, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(dtype)
    out = matmul_pallas(a, b)
    expect = ref.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
       seed=st.integers(0, 5))
def test_matmul_property(m, k, n, seed):
    a = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n))
    np.testing.assert_allclose(np.asarray(matmul_pallas(a, b, bm=64, bn=64,
                                                        bk=64)),
                               np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,d", [
    (1, 256, 4, 4, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA 2:1
    (1, 512, 8, 1, 32),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, H, KV, d, causal):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, H, d))
    k = jax.random.normal(kk, (B, S, KV, d))
    v = jax.random.normal(kv, (B, S, KV, d))
    out = flash_attention_pallas(q, k, v, causal=causal, bq=128, bk=128)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 2, 64)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64)).astype(dtype)
    out = flash_attention_pallas(q, k, v, bq=128, bk=128)
    expect = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_long_block_sweep():
    """Block-size sweep at longer sequence (the 32k-prefill configuration,
    scaled down)."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1024, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1024, 1, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 1024, 1, 64))
    expect = ref.flash_attention_ref(q, k, v)
    for bq, bk in [(128, 256), (256, 128), (512, 512)]:
        out = flash_attention_pallas(q, k, v, bq=bq, bk=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-3, atol=2e-3)
