"""Multi-process simulator backend (repro.sim.proc): token-bucket rate
limiter, frame codec, end-to-end process runs with crash -> membership-mask
recovery, and (slow) bit-for-bit equivalence with the in-process backend."""
import dataclasses
import os
import socket
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.core.adaptive import AdaptiveSpec
from repro.sim import (FaultSchedule, Join, Leave, LinkProfile, QuadraticSpec,
                       Scenario, Straggler, simulate)
from repro.sim.faults import LinkDegradation
from repro.sim.proc import (RateLimitedLink, TokenBucket, pack_frame,
                            recv_frame, run_proc, send_frame, unpack_frames)
from repro.sim.proc.equivalence import check_equivalence


def proc_scenario(**kw):
    base = dict(n_clusters=3, rounds=5, h_steps=2, t_step_s=0.02,
                link=LinkProfile(bytes_per_s=200_000), compressor="diloco_x",
                compressor_kw={"rank": 8, "min_dim_for_lowrank": 8}, rank=8,
                n_params=1e5, seed=0)
    base.update(kw)
    return Scenario(**base)


# ---------------------------------------------------------------------------
# token bucket: measured throughput tracks the configured rate
# ---------------------------------------------------------------------------

def test_token_bucket_throughput_within_10pct():
    rate = 200_000.0
    bucket = TokenBucket(rate, capacity_bytes=20_000)
    bucket.consume(bucket.capacity)        # drain the free initial burst
    total, chunk = 100_000, 5_000          # 0.5 s nominal, sustained
    t0 = time.monotonic()
    sent = 0
    while sent < total:
        bucket.consume(chunk)
        sent += chunk
    measured = total / (time.monotonic() - t0)
    assert 0.9 * rate <= measured <= 1.1 * rate


def test_token_bucket_burst_capacity_bounds_free_bytes():
    bucket = TokenBucket(1e6, capacity_bytes=1000)
    t0 = time.monotonic()
    bucket.consume(1000)                    # burst: free
    assert time.monotonic() - t0 < 0.05
    t0 = time.monotonic()
    bucket.consume(50_000)                  # must be paced: >= ~50 ms
    assert time.monotonic() - t0 >= 0.04


def test_rate_limited_link_charges_modeled_bytes():
    a, b = socket.socketpair()
    try:
        link = RateLimitedLink(a, rate_bytes_per_s=1e6)
        got = []
        rx = threading.Thread(target=lambda: got.append(recv_frame(b)),
                              daemon=True)
        rx.start()
        # tiny frame, charged as 100 KB of modeled wire -> ~0.1 s throttle
        dur = link.send({"round": 0, "hat": b"x"}, charge_bytes=100_000)
        rx.join(timeout=5.0)
        assert got and got[0]["round"] == 0
        assert 0.06 <= dur <= 0.6
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# frame codec
# ---------------------------------------------------------------------------

def test_frame_codec_roundtrip_arbitrary_chunking():
    msgs = [{"type": "round", "n": 1},
            {"arr": np.arange(17, dtype=np.float32).reshape(1, 17)},
            {"blob": b"\x00" * 1000, "s": "x" * 333}]
    stream = b"".join(pack_frame(m) for m in msgs)
    out, rest = [], b""
    for i in range(0, len(stream), 13):     # deliberately misaligned chunks
        got, rest = unpack_frames(rest + stream[i:i + 13])
        out.extend(got)
    assert rest == b""
    assert len(out) == len(msgs)
    assert out[0] == msgs[0]
    np.testing.assert_array_equal(out[1]["arr"], msgs[1]["arr"])
    assert out[2] == msgs[2]


def test_send_recv_frame_over_socket():
    a, b = socket.socketpair()
    try:
        send_frame(a, {"arr": np.ones((4, 4)), "id": 7})
        msg = recv_frame(b, timeout=5.0)
        assert msg["id"] == 7
        np.testing.assert_array_equal(msg["arr"], np.ones((4, 4)))
        a.close()                           # EOF must raise, not hang
        with pytest.raises((ConnectionError, OSError)):
            recv_frame(b, timeout=5.0)
    finally:
        b.close()


def test_frame_codec_rejects_corrupt_length():
    with pytest.raises(ValueError):
        unpack_frames(b"\xff\xff\xff\xff" + b"junk")


# ---------------------------------------------------------------------------
# end-to-end over real processes (timing-only workers: no jax, fast spawn)
# ---------------------------------------------------------------------------

def test_worker_crash_recovers_membership_mask():
    """Kill worker 2 mid-run (os._exit at round 2, before its delta): the
    coordinator must mask it out of that round's collective and finish the
    remaining rounds with the survivors."""
    sc = proc_scenario()
    tl = run_proc(sc, None, crash_at={2: 2})
    assert len(tl.events) == sc.rounds
    assert [e.alive for e in tl.events] == [
        (0, 1, 2), (0, 1, 2), (0, 1), (0, 1), (0, 1)]
    assert any("crash(c2)" in f for f in tl.events[2].faults)
    # masked membership shows up in the token accounting too
    np.testing.assert_allclose(tl.events[2].tokens,
                               tl.events[1].tokens * 2 / 3, rtol=1e-12)


def test_leave_join_kills_and_respawns_processes():
    sc = proc_scenario(rounds=5, faults=FaultSchedule((Leave(1, 1),
                                                       Join(1, 3))))
    tl = run_proc(sc, None)
    assert [e.alive for e in tl.events] == [
        (0, 1, 2), (0, 2), (0, 2), (0, 1, 2), (0, 1, 2)]
    assert tl.events[3].rejoined == (1,)


def test_timing_only_equivalence_with_model():
    """Measured proc timeline (straggler enforced by actual sleep, link by
    the token bucket) agrees with the in-process clock model; structural
    fingerprints match exactly."""
    sc = proc_scenario(rounds=4, h_steps=3, t_step_s=0.03,
                       faults=FaultSchedule((Straggler(1, 1, 3, 3.0),)))
    rep = check_equivalence(sc, None)
    assert rep["structural_match"]
    assert rep["timing_ok"], rep
    assert rep["proc_fingerprint"] == rep["model_fingerprint"]
    # the straggler rounds must actually be ~3x slower on the wall clock
    tl = rep["timelines"]["proc"]
    assert tl.events[1].t_compute_s > 2.0 * tl.events[0].t_compute_s


def test_sync_timing_only_equivalence_with_model():
    """delay=False (synchronous DiLoCo) end-to-end on the proc backend:
    train first, then ship — the full comm time is exposed, and measured
    rounds agree with the model."""
    sc = proc_scenario(rounds=4, h_steps=3, t_step_s=0.03, delay=False,
                       faults=FaultSchedule((Straggler(1, 1, 3, 3.0),)))
    rep = check_equivalence(sc, None)
    assert rep["structural_match"]
    assert rep["timing_ok"], rep
    assert rep["proc_fingerprint"] == rep["model_fingerprint"]
    # no overlap: the modeled round is compute + FULL comm
    tl = rep["timelines"]["model"]
    np.testing.assert_allclose(tl.events[0].exposed_comm_s,
                               tl.events[0].t_comm_s, rtol=1e-12)


def test_gossip_timing_only_equivalence_with_model():
    """Ring gossip: payloads move worker<->worker over PeerMesh links (the
    coordinator never sees them); measured timeline still matches the
    deg*wire/bw clock model and the structural fingerprint is identical."""
    sc = proc_scenario(n_clusters=4, rounds=4, h_steps=3, t_step_s=0.03,
                       topology="ring",
                       faults=FaultSchedule((Straggler(2, 1, 3, 2.5),)))
    rep = check_equivalence(sc, None)
    assert rep["structural_match"], rep
    assert rep["timing_ok"], rep
    assert rep["proc_fingerprint"] == rep["model_fingerprint"]
    # every cluster ships deg=2 payloads -> total = 2 * |E| * wire
    e = rep["timelines"]["proc"].events[0]
    assert e.wire_bytes_total == 8 * e.wire_bytes


def test_gossip_worker_crash_survivors_finish():
    """Hard-kill one ring member mid-run: its neighbors mix zeros for the
    silent peer that round (p2pmiss tags), the coordinator masks it, and
    the remaining rounds complete with the survivors."""
    sc = proc_scenario(n_clusters=4, rounds=5, topology="ring")
    tl = run_proc(sc, None, crash_at={2: 2}, p2p_timeout_s=2.0)
    assert len(tl.events) == sc.rounds
    assert tl.events[1].alive == (0, 1, 2, 3)
    assert 2 not in tl.events[2].alive
    assert tl.events[3].alive == (0, 1, 3)
    assert any("crash(c2)" in f for f in tl.events[2].faults)


def test_adaptive_bandwidth_timing_only_equivalence():
    """Bandwidth-aware adaptive compression with a degraded link, on real
    processes: the coordinator derives the per-round rank from the same
    modeled link state as the in-process simulator and broadcasts it in the
    round header — identical rank schedules, identical structural
    fingerprints, measured timing within tolerance."""
    sc = proc_scenario(
        rounds=5, h_steps=2, t_step_s=0.02,
        faults=FaultSchedule((LinkDegradation(1, 3, 0.1, cluster=1),)),
        adaptive=AdaptiveSpec(mode="bandwidth", r1=8, r_min=2))
    rep = check_equivalence(sc, None)
    assert rep["structural_match"], rep
    assert rep["rank_schedule_match"], rep["rank_schedule_proc"]
    assert rep["timing_ok"], rep
    assert rep["proc_fingerprint"] == rep["model_fingerprint"]
    sched = rep["rank_schedule_proc"]
    assert min(sched) < max(sched)          # the controller actually moved
    # degraded rounds compress harder
    assert sched[1] < sched[0] and sched[2] < sched[0]


def test_h_balance_timing_only_equivalence():
    """Heterogeneous local-step scheduling on real processes: the
    coordinator plans per-cluster H from the same modeled step times as
    the in-process simulator and broadcasts each worker's count in the
    round header — identical H schedules, identical structural
    fingerprints (which now cover h_by), measured timing within
    tolerance.  The straggler's shorter leg must show up on the wall
    clock."""
    from repro.core.adaptive import HSpec
    sc = proc_scenario(rounds=4, h_steps=4, t_step_s=0.03,
                       faults=FaultSchedule((Straggler(1, 1, 3, 4.0),)),
                       h_spec=HSpec(policy="balance"))
    rep = check_equivalence(sc, None)
    assert rep["structural_match"], rep
    assert rep["h_schedule_match"], rep["h_schedule_proc"]
    assert rep["timing_ok"], rep
    assert rep["proc_fingerprint"] == rep["model_fingerprint"]
    sched = rep["h_schedule_proc"]
    assert sched[0] == [4, 4, 4]            # clean round: uniform budget
    assert sched[1][1] == 1                 # 4x straggler: 1/4 of the steps
    assert sched[1][0] == sched[1][2] == 4
    # balance keeps the barrier near the healthy clusters' full budget:
    # the straggler round is NOT ~4x slower (it is under global H)
    tl = rep["timelines"]["proc"]
    assert tl.events[1].t_compute_s < 2.0 * tl.events[0].t_compute_s
    # per-cluster measured compute recorded; the straggler idles least
    assert len(tl.events[1].t_compute_by) == 3


def test_pp_timing_only_equivalence_with_model():
    """inner_engine="pp" scenario, timing-only: workers never import jax,
    so the pp tag only has to flow through the scenario meta — both
    backends must report engine "pp" and the new check_equivalence
    inner_engine fields must match (and gate ``ok``)."""
    sc = proc_scenario(rounds=4, h_steps=3, t_step_s=0.03,
                       inner_engine="pp",
                       faults=FaultSchedule((Straggler(1, 1, 3, 3.0),)))
    rep = check_equivalence(sc, None)
    assert rep["structural_match"], rep
    assert rep["timing_ok"], rep
    assert rep["inner_engine_proc"] == rep["inner_engine_model"] == "pp"
    assert rep["inner_engine_match"] and rep["ok"]
    assert rep["proc_fingerprint"] == rep["model_fingerprint"]


def test_engine_mismatch_rejected_on_both_backends():
    """A scalar problem under a pp scenario (or vice versa) must be
    refused up front on BOTH backends — comparing a pp hash against a
    scalar hash would make the equivalence gate vacuous."""
    sc = proc_scenario(n_clusters=2, inner_engine="pp")
    spec = QuadraticSpec(n_clusters=2, d=4, n_mats=1, h_steps=2, seed=0)
    with pytest.raises(ValueError, match="inner_engine"):
        simulate(sc, numeric=spec.problem())
    with pytest.raises(ValueError, match="inner_engine"):
        run_proc(sc, spec)


def test_structural_fingerprint_ignores_wall_clock():
    """Same scenario, different step time: measured/modeled seconds change,
    the structural fingerprint (participants/budgets/wire/hashes) doesn't."""
    sc_fast = proc_scenario(rounds=3)
    sc_slow = proc_scenario(rounds=3, t_step_s=0.1)
    a, b = simulate(sc_fast), simulate(sc_slow)
    assert a.fingerprint() != b.fingerprint()
    assert a.structural_fingerprint() == b.structural_fingerprint()


# ---------------------------------------------------------------------------
# the headline guarantee (slow: spawns jax workers; CI runs it in the
# dedicated sim-proc job and via the launch CLI --check-equivalence)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_proc_numeric_bitwise_equivalence_through_churn():
    sc = proc_scenario(
        n_clusters=2, rounds=6, h_steps=4, t_step_s=0.05,
        link=LinkProfile(bytes_per_s=50_000, jitter=0.1),
        faults=FaultSchedule((Straggler(1, 1, 3, 2.5), Leave(1, 3),
                              Join(1, 5))),
        n_params=2e5)
    spec = QuadraticSpec(n_clusters=2, d=8, n_mats=2, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    assert rep["hash_match"], rep           # bit-for-bit, incl. post-rejoin
    assert rep["structural_match"]
    assert rep["timing_ok"], rep
    assert rep["final_params_bitwise_equal"]
    losses = rep["timelines"]["proc"].losses()
    assert losses[-1] < losses[0]           # it actually trains


@pytest.mark.slow
def test_proc_sync_numeric_bitwise_equivalence():
    """Satellite: sync (delay=False) rounds end-to-end on the proc
    backend, bit-for-bit against the in-process simulator — including the
    carried error-feedback buffer, which only the sync arm exercises."""
    sc = proc_scenario(n_clusters=2, rounds=5, h_steps=4, t_step_s=0.04,
                       delay=False,
                       faults=FaultSchedule((Straggler(1, 1, 2, 2.0),)),
                       n_params=2e5)
    spec = QuadraticSpec(n_clusters=2, d=8, n_mats=2, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    assert rep["hash_match"], rep
    assert rep["structural_match"] and rep["timing_ok"], rep
    assert rep["final_params_bitwise_equal"]


@pytest.mark.slow
def test_proc_gossip_numeric_crash_survivors_finish():
    """A NUMERIC gossip worker hard-killed at round-msg receipt: its
    neighbors' p2p (re)connects and gathers are all bounded by
    p2p_timeout_s, they mix zeros for the silent peer, and training
    finishes with the survivors (regression: an unreachable peer used to
    stall set_peers for a hard-coded 30 s and then crash the survivor)."""
    sc = proc_scenario(n_clusters=3, rounds=4, topology="ring")
    spec = QuadraticSpec(n_clusters=3, d=8, n_mats=2, h_steps=2, seed=0)
    tl = run_proc(sc, spec, crash_at={2: 1}, p2p_timeout_s=2.0)
    assert len(tl.events) == sc.rounds
    assert 2 not in tl.events[1].alive or 2 not in tl.events[2].alive
    assert tl.events[-1].alive == (0, 1)
    assert any("crash(c2)" in f for e in tl.events for f in e.faults)
    assert tl.events[-1].loss is not None      # survivors kept training


@pytest.mark.slow
def test_proc_adaptive_hybrid_numeric_bitwise_equivalence():
    """Adaptive compression end-to-end on the proc backend: workers
    compress with the broadcast r_t, the coordinator folds the workers'
    reported pending deltas back into the Alg. 3 window, and BOTH the
    per-round param hashes and the rank schedule are bit-identical to the
    in-process simulator through a degraded-link window."""
    sc = proc_scenario(
        n_clusters=2, rounds=6, h_steps=4, t_step_s=0.05,
        link=LinkProfile(bytes_per_s=50_000, jitter=0.1),
        faults=FaultSchedule((LinkDegradation(2, 4, 0.25, cluster=1),)),
        n_params=2e5,
        adaptive=AdaptiveSpec(mode="hybrid", r1=8, r_min=2, window=3))
    spec = QuadraticSpec(n_clusters=2, d=8, n_mats=2, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    assert rep["hash_match"], rep
    assert rep["rank_schedule_match"], rep["rank_schedule_proc"]
    assert rep["structural_match"] and rep["timing_ok"], rep
    assert rep["final_params_bitwise_equal"]
    sched = rep["rank_schedule_proc"]
    assert min(sched) < max(sched)          # spectral + bandwidth both bit
    losses = rep["timelines"]["proc"].losses()
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_proc_gossip_adaptive_per_edge_bitwise_equivalence():
    """Per-EDGE adaptive ranks over real p2p links: only the degraded
    cluster's own sends drop rank (its neighbors keep shipping r1), and
    replica hashes + per-edge rank tuples match the in-process run
    bit-for-bit."""
    sc = proc_scenario(
        n_clusters=4, rounds=5, h_steps=4, t_step_s=0.05, topology="ring",
        link=LinkProfile(bytes_per_s=100_000),
        faults=FaultSchedule((LinkDegradation(1, 4, 0.1, cluster=2),)),
        n_params=1e5,
        adaptive=AdaptiveSpec(mode="bandwidth", r1=8, r_min=2, window=3))
    spec = QuadraticSpec(n_clusters=4, d=8, n_mats=2, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    assert rep["hash_match"], rep
    assert rep["rank_schedule_match"]
    assert rep["structural_match"] and rep["timing_ok"], rep
    events = rep["timelines"]["proc"].events
    for e in events:
        assert e.ranks is not None
        if 1 <= e.round < 4:
            assert e.ranks[2] < 8                        # degraded uplink
            assert all(e.ranks[c] == 8 for c in (0, 1, 3))   # its edges only
        else:
            assert e.ranks == (8, 8, 8, 8)


@pytest.mark.slow
def test_proc_h_balance_numeric_bitwise_equivalence():
    """Per-cluster H end-to-end on the proc backend: heterogeneous rounds
    run the masked fixed-length scan (H broadcast in the round header,
    traced into one compile), uniform rounds dispatch to the plain
    scalar-H program on BOTH backends, and per-round param hashes + the H
    schedule are bit-identical through a straggler window."""
    from repro.core.adaptive import HSpec
    sc = proc_scenario(
        n_clusters=3, rounds=6, h_steps=4, t_step_s=0.05,
        link=LinkProfile(bytes_per_s=100_000, jitter=0.1),
        faults=FaultSchedule((Straggler(1, 1, 4, 3.0),)),
        n_params=1e5, h_spec=HSpec(policy="balance"))
    spec = QuadraticSpec(n_clusters=3, d=8, n_mats=2, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    assert rep["hash_match"], rep
    assert rep["h_schedule_match"], rep["h_schedule_proc"]
    assert rep["structural_match"] and rep["timing_ok"], rep
    assert rep["final_params_bitwise_equal"]
    sched = rep["h_schedule_proc"]
    assert any(min(row) < max(row) for row in sched)   # heterogeneous rounds
    losses = rep["timelines"]["proc"].losses()
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_proc_ring_gossip_bitwise_equivalence_through_churn():
    """The tentpole guarantee: ring gossip over real p2p worker links —
    per-round combined replica hashes, consensus-mean rejoin bootstrap,
    and final per-replica params all bit-identical to the in-process
    stacked-state simulation."""
    sc = proc_scenario(
        n_clusters=4, rounds=6, h_steps=4, t_step_s=0.05, topology="ring",
        link=LinkProfile(bytes_per_s=100_000, jitter=0.1),
        faults=FaultSchedule((Straggler(1, 1, 3, 2.0), Leave(2, 3),
                              Join(2, 5))),
        n_params=1e5)
    spec = QuadraticSpec(n_clusters=4, d=8, n_mats=2, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    assert rep["hash_match"], rep
    assert rep["structural_match"], rep
    assert rep["timing_ok"], rep
    assert rep["final_params_bitwise_equal"]
    tl = rep["timelines"]["proc"]
    losses = tl.losses()
    assert losses[-1] < losses[0]
    # gossip rounds ship deg*wire per member, strictly under the
    # (n_alive-1)*wire gather charge
    full = [e for e in tl.events if len(e.alive) == 4]
    assert all(e.wire_bytes_total == 8 * e.wire_bytes for e in full)


PP_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    from repro.sim import LinkProfile, PPSpec, Scenario
    from repro.sim.proc.equivalence import check_equivalence, format_report

    spec = PPSpec(n_clusters=2, n_layers=2, vocab_size=64, seq_len=8,
                  local_batch=4, n_stages=2, n_micro=2, h_steps=2, seed=0)
    sc = Scenario(n_clusters=2, rounds=3, h_steps=2, t_step_s=0.25,
                  link=LinkProfile(bytes_per_s=200_000),
                  compressor="diloco_x",
                  compressor_kw={"rank": 8, "min_dim_for_lowrank": 8},
                  rank=8, n_params=1e5, seed=0, inner_engine="pp")
    rep = check_equivalence(sc, spec)
    print(format_report(rep))
    assert rep["inner_engine_proc"] == rep["inner_engine_model"] == "pp"
    assert rep["inner_engine_match"], rep
    assert rep["structural_match"], rep
    assert rep["hash_match"], rep
    assert rep["final_params_bitwise_equal"], rep
    # timing is NOT asserted here: unlike the quadratic problems, the pp
    # engine runs real shard_map compute and first-use XLA compiles inside
    # the measured rounds — wall clock the t_step model deliberately does
    # not price.  Timing equivalence for pp scenarios is covered by the
    # fast timing-only test above, where workers never import jax.
    losses = rep["timelines"]["proc"].losses()
    assert losses[-1] < losses[0]           # the pipeline actually trains
    print("PP-PROC-EQUIV-OK")
""")


@pytest.mark.slow
def test_proc_pp_numeric_bitwise_equivalence():
    """The PR's headline gate: a 2-cluster ``inner_engine="pp"`` scenario
    where each worker runs its H inner AdamW steps through the shard_map
    GPipe pipeline on its own 2-device unit mesh, bit-for-bit against the
    in-process simulator executing the identical per-cluster programs in
    a python unroll.  Runs in a subprocess: the coordinator-side
    ``simulate()`` leg needs the faked devices too, and the main pytest
    process must keep 1 device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", PP_EQUIV_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "PP-PROC-EQUIV-OK" in r.stdout


# ---------------------------------------------------------------------------
# pallas compressor backend: its own equivalence leg (the ref backend's
# bitwise gates above are untouched — backend selection rides the same
# compressor_kw JSON that already flows coordinator -> worker)
# ---------------------------------------------------------------------------

def test_inprocess_pallas_backend_deterministic():
    """Fast leg: the in-process simulator with backend="pallas" is
    run-to-run deterministic (same losses bitwise) and actually trains."""
    sc = proc_scenario(
        n_clusters=2, rounds=4, h_steps=3, t_step_s=0.02,
        compressor_kw={"rank": 8, "min_dim_for_lowrank": 8,
                       "backend": "pallas"})
    spec = QuadraticSpec(n_clusters=2, d=8, n_mats=2, h_steps=3, seed=0)
    tl1 = simulate(sc, numeric=spec.problem())
    tl2 = simulate(sc, numeric=spec.problem())
    l1, l2 = tl1.losses(), tl2.losses()
    assert l1 == l2                          # bitwise-identical trajectory
    assert l1[-1] < l1[0]                    # it actually trains


@pytest.mark.slow
def test_proc_pallas_backend_bitwise_equivalence():
    """Slow leg: proc workers running the fused pallas compressor match
    the in-process simulator bit-for-bit — the same guarantee the ref
    backend has, per backend (pallas vs pallas; cross-backend agreement
    is gated separately in tests/test_compression.py)."""
    sc = proc_scenario(
        n_clusters=2, rounds=5, h_steps=4, t_step_s=0.04,
        compressor_kw={"rank": 8, "min_dim_for_lowrank": 8,
                       "backend": "pallas"},
        n_params=2e5)
    spec = QuadraticSpec(n_clusters=2, d=8, n_mats=2, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    assert rep["hash_match"], rep
    assert rep["structural_match"] and rep["timing_ok"], rep
    assert rep["final_params_bitwise_equal"]
    losses = rep["timelines"]["proc"].losses()
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# per-round topology re-dial (satellite: dynamic topology on proc)
# ---------------------------------------------------------------------------

def test_dynamic_topology_timing_equivalence_with_model():
    """topology_seed_schedule on the PROC backend: each round the workers
    re-dial the freshly drawn k-regular graph through PeerMesh.set_peers,
    and the measured timeline matches the in-process clock model (which
    draws the identical graphs from the same seeds)."""
    sc = proc_scenario(n_clusters=5, rounds=5, h_steps=3, t_step_s=0.03,
                       topology="random", topology_degree=2,
                       topology_seed_schedule=(11, 12, 13),
                       faults=FaultSchedule((Straggler(1, 1, 3, 2.5),)))
    rep = check_equivalence(sc, None)
    assert rep["structural_match"], rep
    assert rep["timing_ok"], rep
    assert rep["proc_fingerprint"] == rep["model_fingerprint"]
    # the schedule genuinely varies the graph: rounds must not all ship
    # identical per-cluster byte totals in lockstep order
    tls = rep["timelines"]["model"]
    assert len({tuple(e.t_compute_by) for e in tls.events}) > 1


@pytest.mark.slow
def test_proc_dynamic_topology_numeric_bitwise_equivalence():
    sc = proc_scenario(n_clusters=4, rounds=5, h_steps=4, t_step_s=0.05,
                       topology="random", topology_degree=2,
                       topology_seed_schedule=(5, 9),
                       link=LinkProfile(bytes_per_s=100_000, jitter=0.1),
                       n_params=1e5)
    spec = QuadraticSpec(n_clusters=4, d=8, n_mats=2, h_steps=4, seed=0)
    rep = check_equivalence(sc, spec)
    assert rep["hash_match"], rep
    assert rep["structural_match"], rep
    assert rep["final_params_bitwise_equal"]
    losses = rep["timelines"]["proc"].losses()
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# bounded-stale async rounds on real processes (the tentpole, proc leg)
# ---------------------------------------------------------------------------

def async_proc_scenario(**kw):
    base = dict(n_clusters=3, rounds=5, h_steps=4, t_step_s=0.02, seed=3,
                sync="bounded_stale", max_staleness=2,
                link=LinkProfile(bytes_per_s=2e8, latency_s=0.01,
                                 jitter=0.1),
                compressor="diloco_x",
                compressor_kw={"rank": 4, "min_dim_for_lowrank": 8},
                rank=4, n_params=1e5,
                faults=FaultSchedule((Straggler(1, 1, 3, 3.0),)))
    base.update(kw)
    return Scenario(**base)


def test_proc_barrier_rejects_byzantine_like_in_process():
    """run_proc must reject Byzantine-under-barrier exactly like
    simulate(): the barrier round has no publish step to corrupt, and the
    proc path never calls byzantine_scale — silently ignoring the attack
    would diverge from the in-process backend's validation."""
    from repro.sim.faults import Byzantine
    sc = proc_scenario(faults=FaultSchedule((Byzantine(1, 0, 2),)))
    with pytest.raises(ValueError, match="bounded_stale"):
        run_proc(sc)
    with pytest.raises(ValueError, match="bounded_stale"):
        simulate(sc)


def test_proc_bounded_stale_timing_structural_drift_gate():
    """The CI drift gate's contract: two proc runs of the same async
    scenario produce the SAME structural fingerprint (commit order,
    staleness records, round clocks), and it equals the in-process
    engine's — modeled time drives both backends, wall clock never
    enters a structural field."""
    sc = async_proc_scenario()
    a, b = run_proc(sc), run_proc(sc)
    assert a.structural_fingerprint() == b.structural_fingerprint()
    assert (a.structural_fingerprint()
            == simulate(sc).structural_fingerprint())
    assert len(a.events) == 3 * 5
    for e in a.events:
        assert e.cluster is not None and e.t_start_s is not None
        for _, s in e.staleness:
            assert 0 <= s <= sc.max_staleness


@pytest.mark.slow
def test_proc_bounded_stale_numeric_bitwise_equivalence():
    """Async outer steps on real workers: every commit's param hash (and
    loss) is bit-identical to the in-process ``_AsyncNumeric`` executor —
    same jitted ops, same versioned delta store, same staleness-weighted
    mean."""
    sc = async_proc_scenario(rounds=6)
    mk = lambda: QuadraticSpec(n_clusters=3, d=8, n_mats=2, h_steps=4,
                               seed=1)
    tl_in = simulate(sc, numeric=mk().problem())
    tl_p = run_proc(sc, mk())
    assert (tl_p.structural_fingerprint()
            == tl_in.structural_fingerprint())
    assert ([(e.cluster, e.round, e.param_hash) for e in tl_p.events]
            == [(e.cluster, e.round, e.param_hash) for e in tl_in.events])
    assert tl_p.losses() == tl_in.losses()
    assert tl_p.losses()[-1] < tl_p.losses()[0]


@pytest.mark.slow
def test_proc_bounded_stale_churn_byzantine_trimmed_equivalence():
    """Leave/Join respawn + consensus bootstrap and the Byzantine
    corrupt-delta fault under trimmed-mean aggregation, proc vs
    in-process, bit for bit."""
    from repro.sim.faults import Byzantine
    faults = FaultSchedule((Byzantine(2, 1, 5, scale=-8.0),
                            Leave(1, 3), Join(1, 5)))
    sc = async_proc_scenario(n_clusters=4, rounds=6, max_staleness=1,
                             seed=11, faults=faults,
                             aggregation="trimmed_mean", trim_k=1)
    mk = lambda: QuadraticSpec(n_clusters=4, d=8, n_mats=2, h_steps=4,
                               seed=2)
    tl_in = simulate(sc, numeric=mk().problem())
    tl_p = run_proc(sc, mk())
    assert (tl_p.structural_fingerprint()
            == tl_in.structural_fingerprint())
    assert ([(e.cluster, e.round, e.param_hash) for e in tl_p.events]
            == [(e.cluster, e.round, e.param_hash) for e in tl_in.events])
    rejoined = [e for e in tl_p.events if e.rejoined == (1,)]
    assert len(rejoined) == 1
