"""Tier-1 test configuration.

Two jobs:
 1. Make ``hypothesis`` optional: when the real package is missing, install
    ``tests/_hypothesis_compat.py`` (a deterministic fixed-example fallback)
    into ``sys.modules`` *before* collection, so the property-test modules
    import cleanly and still run meaningful fixed-seed cases.
 2. Keep tier-1 fast: tests marked ``@pytest.mark.slow`` (multi-minute
    subprocess/integration runs) are skipped unless ``--runslow`` is given
    or an explicit ``-m slow`` selection asks for them.
"""
import importlib.util
import os
import sys

import pytest

# --- shave XLA compile time -----------------------------------------------
# Tier-1 is compile-bound (dozens of tiny-model jits); backend optimization
# level 0 cuts compile ~30% with no effect on what the tests assert.  Set
# REPRO_FULL_XLA_OPT=1 to opt out.  Must run before jax initializes.
if not os.environ.get("REPRO_FULL_XLA_OPT"):
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_backend_optimization_level" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_backend_optimization_level=0").strip()

# --- persistent jax compilation cache --------------------------------------
# The remaining tier-1 cost is the per-arch value_and_grad compiles; jax's
# persistent compilation cache (works on CPU in 0.4.x via env vars alone)
# makes re-runs skip them entirely.  Opt out with REPRO_NO_JAX_CACHE=1;
# point JAX_COMPILATION_CACHE_DIR elsewhere to relocate (CI caches this
# directory between runs in both tier-1 jobs).  Must be set before jax
# initializes, hence here and not in a fixture.
if not os.environ.get("REPRO_NO_JAX_CACHE"):
    os.environ.setdefault(
        "JAX_COMPILATION_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "repro-jax-cache"))
    # default min-compile-time is 1 s; at 0.5 s the mid-sized jits (sim
    # numeric rounds, compressor roundtrips) get cached too
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS",
                          "0.5")

# --- hypothesis fallback (must happen at import time, before collection) ---
if importlib.util.find_spec("hypothesis") is None:
    _here = os.path.dirname(__file__)
    if _here not in sys.path:
        sys.path.insert(0, _here)
    import _hypothesis_compat
    sys.modules["hypothesis"] = _hypothesis_compat
    sys.modules["hypothesis.strategies"] = _hypothesis_compat.strategies


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run tests marked slow (multi-minute integration)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute integration test; deselected from "
                   "tier-1 unless --runslow (or -m slow) is given")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    if "slow" in (config.getoption("-m") or ""):
        return      # explicit -m selection wins
    skip_slow = pytest.mark.skip(reason="slow: use --runslow or -m slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
