"""Virtual decentralized-cluster simulator (repro.sim): determinism,
fault-injection semantics, the §2.3 overlap rule, membership churn, and
agreement with the closed-form comm model / paper speedup ordering."""
import dataclasses

import numpy as np
import pytest

from repro.core import comm
from repro.sim import (FaultSchedule, Join, Leave, LinkDegradation,
                       LinkProfile, Scenario, Straggler, compare_methods,
                       make_quadratic_problem, simulate, synthetic_shapes)

GBPS = comm.GBPS


def clean_scenario(**kw):
    base = dict(n_clusters=4, rounds=6, h_steps=10, t_step_s=1.0,
                n_params=1e8, compressor="diloco_x",
                compressor_kw={"rank": 32}, seed=3)
    base.update(kw)
    return Scenario(**base)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_same_seed_identical_timeline():
    sc = clean_scenario(link=LinkProfile(jitter=0.1))
    a, b = simulate(sc), simulate(sc)
    assert a.fingerprint() == b.fingerprint()
    assert [e.t_round_s for e in a.events] == [e.t_round_s for e in b.events]


def test_different_seed_different_jitter():
    sc = clean_scenario(link=LinkProfile(jitter=0.1))
    sc2 = dataclasses.replace(sc, seed=sc.seed + 1)
    assert simulate(sc).fingerprint() != simulate(sc2).fingerprint()


def test_numeric_run_is_deterministic():
    faults = FaultSchedule((Straggler(1, 2, 4, 3.0), Leave(2, 3),
                            Join(2, 5)))
    sc = clean_scenario(rounds=6, h_steps=4, faults=faults,
                        compressor_kw={"rank": 4, "min_dim_for_lowrank": 8})
    fp = [simulate(sc, numeric=make_quadratic_problem(
        4, h_steps=4, seed=0)).fingerprint() for _ in range(2)]
    assert fp[0] == fp[1]


# ---------------------------------------------------------------------------
# timing semantics vs the closed-form model (core/comm.py)
# ---------------------------------------------------------------------------

def test_clean_run_matches_method_throughput():
    """Fault-free, jitter-free simulation must equal core.comm's closed-form
    method arithmetic exactly (same wire bytes, same overlap rule)."""
    from repro.core.compression import make_compressor

    sc = clean_scenario()
    compressor = make_compressor(sc.compressor, **sc.compressor_kw)
    wire = compressor.wire_bytes(sc.shapes())
    ref = comm.method_throughput(
        "x", param_bytes_fp32=4 * sc.n_params, wire_bytes=wire,
        h_steps=sc.h_steps, overlap=True,
        sc=comm.CommScenario(n_clusters=sc.n_clusters,
                             t_step_s=sc.t_step_s,
                             tokens_per_step=sc.tokens_per_step))
    tl = simulate(sc)
    e = tl.events[0]
    assert e.wire_bytes == wire
    np.testing.assert_allclose(e.t_comm_s, ref.comm_s_per_round, rtol=1e-12)
    np.testing.assert_allclose(e.exposed_comm_s, ref.exposed_comm_s,
                               rtol=1e-12)
    np.testing.assert_allclose(e.t_round_s, ref.t_round_s, rtol=1e-12)
    np.testing.assert_allclose(tl.tokens_per_s, ref.tokens_per_s, rtol=1e-9)


def test_overlap_rule_exposed_comm():
    """exposed = max(0, T_comm - H*T_step): shrink bandwidth until comm no
    longer hides behind compute and check the exact excess is exposed."""
    slow = clean_scenario(link=LinkProfile(bytes_per_s=GBPS / 500))
    e = simulate(slow).events[0]
    assert e.t_comm_s > e.t_compute_s
    np.testing.assert_allclose(e.exposed_comm_s,
                               e.t_comm_s - e.t_compute_s, rtol=1e-12)
    # and with overlap disabled the full comm time is exposed
    e2 = simulate(dataclasses.replace(slow, delay=False)).events[0]
    np.testing.assert_allclose(e2.exposed_comm_s, e2.t_comm_s, rtol=1e-12)
    # fast link: fully hidden
    assert simulate(clean_scenario()).events[0].exposed_comm_s == 0.0


# ---------------------------------------------------------------------------
# fault injection changes the timeline the way it should
# ---------------------------------------------------------------------------

def test_straggler_inflates_only_its_rounds():
    base = clean_scenario()
    strag = dataclasses.replace(
        base, faults=FaultSchedule((Straggler(2, 2, 4, slowdown=3.0),)))
    a, b = simulate(base), simulate(strag)
    assert a.fingerprint() != b.fingerprint()
    for r in range(base.rounds):
        ea, eb = a.events[r], b.events[r]
        if 2 <= r < 4:
            np.testing.assert_allclose(eb.t_compute_s, 3.0 * ea.t_compute_s,
                                       rtol=1e-12)
            assert eb.slowest_cluster == 2
            assert any("straggler" in f for f in eb.faults)
        else:
            np.testing.assert_allclose(eb.t_compute_s, ea.t_compute_s,
                                       rtol=1e-12)


def test_link_degradation_inflates_comm():
    base = clean_scenario(link=LinkProfile(bytes_per_s=GBPS / 100))
    deg = dataclasses.replace(
        base, faults=FaultSchedule((LinkDegradation(1, 2, factor=0.25),)))
    a, b = simulate(base), simulate(deg)
    np.testing.assert_allclose(b.events[1].t_comm_s,
                               4.0 * a.events[1].t_comm_s, rtol=1e-12)
    np.testing.assert_allclose(b.events[0].t_comm_s, a.events[0].t_comm_s,
                               rtol=1e-12)
    # per-cluster degradation: that cluster becomes the bottleneck link
    deg1 = dataclasses.replace(
        base, faults=FaultSchedule((LinkDegradation(1, 2, factor=0.25,
                                                    cluster=3),)))
    assert simulate(deg1).events[1].bottleneck_cluster == 3


def test_membership_churn_changes_participants_and_comm():
    faults = FaultSchedule((Leave(1, 2), Join(1, 4)))
    sc = clean_scenario(faults=faults)
    tl = simulate(sc)
    assert tl.events[1].alive == (0, 1, 2, 3)
    assert tl.events[2].alive == (0, 2, 3)          # after the leave
    assert tl.events[3].alive == (0, 2, 3)
    assert tl.events[4].alive == (0, 1, 2, 3)       # rejoined
    assert tl.events[4].rejoined == (1,)
    # gather over 3 clusters moves (3-1)/3 of what 4 clusters' (4-1)/4 does
    # per payload: t_comm scales as (c-1) at fixed payload
    np.testing.assert_allclose(tl.events[2].t_comm_s / tl.events[1].t_comm_s,
                               2.0 / 3.0, rtol=1e-12)
    # fewer clusters train fewer global tokens per round
    np.testing.assert_allclose(tl.events[2].tokens,
                               0.75 * tl.events[1].tokens, rtol=1e-12)


# ---------------------------------------------------------------------------
# numerics: the real round loop runs (and survives churn)
# ---------------------------------------------------------------------------

def test_numeric_quadratic_converges():
    prob = make_quadratic_problem(4, h_steps=6, seed=0)
    sc = clean_scenario(rounds=12, h_steps=6,
                        compressor_kw={"rank": 4, "min_dim_for_lowrank": 8})
    tl = simulate(sc, numeric=prob)
    losses = tl.losses()
    assert len(losses) == 12
    assert all(np.isfinite(losses))
    assert losses[-1] < 0.3 * losses[0]


def test_numeric_survives_straggler_and_churn():
    """A straggler plus a leave/rejoin cycle changes the round *timeline*
    (timing) deterministically but training still converges (numerics)."""
    faults = FaultSchedule((Straggler(1, 3, 6, slowdown=4.0),
                            Leave(2, 4), Join(2, 9)))
    sc = clean_scenario(rounds=14, h_steps=6, faults=faults,
                        link=LinkProfile(jitter=0.05),
                        compressor_kw={"rank": 4, "min_dim_for_lowrank": 8})
    mk = lambda: make_quadratic_problem(4, h_steps=6, seed=0)
    tl = simulate(sc, numeric=mk())
    # timeline: straggler rounds are ~4x slower than their neighbours
    assert tl.events[3].t_compute_s > 3.0 * tl.events[2].t_compute_s
    # churn visible on the timeline
    assert 2 not in tl.events[5].alive and 2 in tl.events[10].alive
    assert tl.events[9].rejoined == (2,)
    # numerics: still converges through all of it
    losses = tl.losses()
    assert all(np.isfinite(losses))
    assert losses[-1] < 0.3 * losses[0]
    # determinism of the full (timing + numeric) event stream
    assert simulate(sc, numeric=mk()).fingerprint() == tl.fingerprint()


def test_numeric_churn_vs_clean_losses_differ_only_after_leave():
    """Dropping a cluster changes the numeric trajectory only once the
    mask changes — before the Leave round both runs are identical."""
    mk = lambda: make_quadratic_problem(3, h_steps=4, seed=1)
    base = clean_scenario(n_clusters=3, rounds=8, h_steps=4,
                          compressor_kw={"rank": 4,
                                         "min_dim_for_lowrank": 8})
    churn = dataclasses.replace(base,
                                faults=FaultSchedule((Leave(0, 4),)))
    la = simulate(base, numeric=mk()).losses()
    lb = simulate(churn, numeric=mk()).losses()
    np.testing.assert_allclose(la[:4], lb[:4], rtol=1e-6)
    assert not np.allclose(la[4:], lb[4:], rtol=1e-6)


# ---------------------------------------------------------------------------
# the paper's speedup ordering, replayed through the simulator
# ---------------------------------------------------------------------------

def test_method_comparison_reproduces_paper_ordering():
    """At the 107B operating point (calibrated t_step like
    benchmarks/throughput.py) the simulator reproduces the §4.2.2
    ordering and the ~357x headline within modeling slack."""
    t_step = 6.0 * 107e9 * 36_000 / (160 * 312e12 * 0.045)
    sc = Scenario(n_clusters=2, rounds=3, h_steps=125, t_step_s=t_step,
                  n_params=107e9, tokens_per_step=36_000)
    cmp = compare_methods(sc, rank=2048)
    s = cmp["speedup_vs_allreduce"]
    assert s["diloco_x"] > s["cocktail"] > s["allreduce"] == 1.0
    assert s["diloco_x"] > s["opendiloco"]
    assert 250 < s["diloco_x"] < 450          # paper: 357x


def test_synthetic_shapes_total():
    shapes = synthetic_shapes(1e8)
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert abs(total - 1e8) / 1e8 < 0.01


# ---------------------------------------------------------------------------
# adaptive compression: controller wiring + rank-schedule replay
# ---------------------------------------------------------------------------

def _ada_scenario(**kw):
    from repro.core.adaptive import AdaptiveSpec
    base = dict(
        n_clusters=4, rounds=8, h_steps=4, t_step_s=0.05,
        link=LinkProfile(bytes_per_s=200_000),
        faults=FaultSchedule((LinkDegradation(3, 6, factor=0.05,
                                              cluster=1),)),
        compressor="diloco_x",
        compressor_kw={"rank": 8, "min_dim_for_lowrank": 8}, rank=8,
        n_params=2e5, seed=0,
        adaptive=AdaptiveSpec(mode="bandwidth", r1=8, r_min=2, window=3))
    base.update(kw)
    return Scenario(**base)


def test_adaptive_bandwidth_timing_only_recovers_round_time():
    """Bandwidth mode is pure link arithmetic: it runs timing-only (no
    numeric problem, no jax round), drops the rank exactly while the link
    is degraded, and the degraded rounds stay far cheaper than fixed-rank."""
    sc = _ada_scenario()
    tl = simulate(sc)
    sched = tl.rank_schedule()
    assert sched[:3] == [8, 8, 8] and sched[6:] == [8, 8]
    assert all(r < 8 for r in sched[3:6])
    # wire accounting follows the executed rank
    assert tl.events[3].wire_bytes < tl.events[0].wire_bytes
    fixed = simulate(dataclasses.replace(sc, adaptive=None))
    assert tl.events[4].t_round_s < 0.5 * fixed.events[4].t_round_s
    # deterministic: same scenario => identical timeline
    assert simulate(sc).fingerprint() == tl.fingerprint()


def test_adaptive_spectral_timing_only_raises():
    from repro.core.adaptive import AdaptiveSpec
    for mode in ("spectral", "hybrid"):
        sc = _ada_scenario(adaptive=AdaptiveSpec(mode=mode, r1=8))
        with pytest.raises(ValueError):
            simulate(sc)


def test_rank_schedule_replays_an_adaptive_run():
    """A recorded adaptive schedule replays timing-only: same rank column,
    same wire accounting, no controller/numeric required."""
    tl = simulate(_ada_scenario())
    sc_replay = _ada_scenario(adaptive=None)
    tl2 = simulate(sc_replay, rank_schedule=tl.rank_schedule())
    assert tl2.rank_schedule() == tl.rank_schedule()
    assert ([e.wire_bytes for e in tl2.events]
            == [e.wire_bytes for e in tl.events])
    with pytest.raises(ValueError):         # schedule shorter than the run
        simulate(sc_replay, rank_schedule=[8, 8])
    with pytest.raises(ValueError):         # schedule + controller conflict
        simulate(_ada_scenario(), rank_schedule=tl.rank_schedule())


def test_adaptive_hybrid_numeric_fuses_both_signals():
    """Hybrid = min(spectral, bandwidth): the degraded window is clamped by
    the link, afterwards the spectrum keeps the annealed (sub-r1) rank; the
    run still converges."""
    from repro.core.adaptive import AdaptiveSpec
    sc = _ada_scenario(rounds=10,
                       adaptive=AdaptiveSpec(mode="hybrid", r1=8, r_min=2,
                                             window=3))
    tl = simulate(sc, numeric=make_quadratic_problem(4, h_steps=4, seed=0))
    sched = tl.rank_schedule()
    assert sched[:3] == [8, 8, 8]           # spectral warm-up at r1
    assert all(r == 2 for r in sched[3:6])  # degraded link clamps to r_min
    assert all(2 <= r < 8 for r in sched[6:])   # spectrum annealed below r1
    losses = tl.losses()
    assert all(np.isfinite(losses)) and losses[-1] < 0.5 * losses[0]


def test_adaptive_gossip_per_edge_only_degraded_uplink_drops():
    from repro.core.adaptive import AdaptiveSpec
    sc = _ada_scenario(topology="ring",
                       adaptive=AdaptiveSpec(mode="bandwidth", r1=8,
                                             r_min=2, window=3))
    tl = simulate(sc, numeric=make_quadratic_problem(4, h_steps=4, seed=0))
    for e in tl.events:
        assert e.ranks is not None and len(e.ranks) == 4
        if 3 <= e.round < 6:
            assert e.ranks[1] < 8                       # degraded uplink
            assert all(e.ranks[c] == 8 for c in (0, 2, 3))   # its edges only
        else:
            assert e.ranks == (8, 8, 8, 8)
    # the headline rank field records the round max (healthy-edge rank),
    # while the schedule keeps the per-edge lists for faithful replay
    assert [e.rank for e in tl.events] == [8] * 8
    assert tl.rank_schedule()[3] == list(tl.events[3].ranks)
    # per-edge replay reproduces the per-sender wire accounting exactly
    tl2 = simulate(dataclasses.replace(sc, adaptive=None),
                   rank_schedule=tl.rank_schedule())
    assert ([e.wire_bytes_total for e in tl2.events]
            == [e.wire_bytes_total for e in tl.events])
    assert [e.ranks for e in tl2.events] == [e.ranks for e in tl.events]


def test_legacy_adagradcmp_cfg_still_accepted():
    """The historical simulate(sc, numeric=..., adaptive_cfg=
    AdaGradCmpConfig(...)) entry point keeps working as pure-spectral."""
    from repro.core.adaptive import AdaGradCmpConfig
    sc = _ada_scenario(adaptive=None, faults=FaultSchedule(()))
    cfg = AdaGradCmpConfig(window=2, r1=8, r_min=2)
    tl = simulate(sc, numeric=make_quadratic_problem(4, h_steps=4, seed=0),
                  adaptive_cfg=cfg)
    sched = tl.rank_schedule()
    assert sched[0] == 8                    # warm-up executes r1
    assert any(r < 8 for r in sched[2:])    # then the spectrum anneals
