"""Sharded pipeline-parallel inner engine (repro.parallel.inner_engine).

Fast: property tests that ``layers_per_stage`` partitions exactly and that
delta extraction round-trips the ``DiLoCoTrainState`` pytree (structure,
dtypes, the pinned ``active`` mask — values to the documented fp budget),
plus the ``dryrun --inner pp`` smoke shape-checking qwen1.5-107b through
the sharded engine with no real compute.

Slow: the differential harness.  Runs in a subprocess (the engine needs
n_stages faked devices; the main pytest process must keep 1 device) and
certifies, per round:

 - **pp is deterministic bitwise**: two independent executions of the
   jitted per-cluster pp inner loop produce identical param hashes — the
   "bitwise where XLA tiling permits" leg (same compiled program).
 - **pp ≡ scalar to a documented tolerance**: the same H AdamW steps on
   the same data through the sequential single-replica loss track the
   pipelined run within an explicit budget.  Bitwise equality is
   impossible here — the GPipe loss computes the same math through a
   different op schedule (ppermute ticks, chunked CE, sharded psums), so
   per-step grads differ by ~1e-3 max-abs (tests/test_pipeline.py) and
   AdamW's normalized update amplifies that toward ~lr per element when
   the second moment is still small.  The budget below is stated in units
   of lr per inner step and verified to be non-vacuous (drift stays well
   under the total distance travelled).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.parallel.pipeline import PipelineConfig, layers_per_stage


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return env


# ---------------------------------------------------------------------------
# layers_per_stage partitions exactly
# ---------------------------------------------------------------------------

@given(n_layers=st.integers(1, 64), n_stages=st.integers(1, 8))
@settings(max_examples=40)
def test_layers_per_stage_partitions_exactly(n_layers, n_stages):
    cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                              n_layers=n_layers)
    lps, pad = layers_per_stage(cfg, PipelineConfig(n_stages=n_stages,
                                                    n_micro=2))
    assert lps * n_stages - pad == n_layers     # exact partition, no loss
    assert 0 <= pad < n_stages                  # minimal padding
    assert lps >= 1


# ---------------------------------------------------------------------------
# delta extraction round-trips the DiLoCoTrainState pytree
# ---------------------------------------------------------------------------

def _tiny_state(seed: int):
    import jax
    from repro.parallel import inner_engine as IE

    cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                              n_layers=3, vocab_size=64)
    pcfg = PipelineConfig(n_stages=2, n_micro=2)
    # no mesh needed: state construction and delta arithmetic are
    # placement-free (shardings only matter once shard_map runs)
    return IE.init_train_state(cfg, pcfg, jax.random.PRNGKey(seed))


@given(seed=st.integers(0, 3), scale=st.sampled_from([1e-3, 1e-2, 1e-1]))
@settings(max_examples=6)
def test_delta_extraction_roundtrips_train_state(seed, scale):
    import jax
    import jax.numpy as jnp
    from repro.parallel import inner_engine as IE

    st0 = _tiny_state(seed)
    anchor = st0.params

    # local replica drifted from the anchor + a nonzero EF residual; the
    # active mask never moves (neither engine trains it)
    k = jax.random.PRNGKey(seed + 100)
    leaves, treedef = jax.tree.flatten(anchor)
    keys = jax.random.split(k, 2 * len(leaves))
    local = jax.tree.unflatten(treedef, [
        x + scale * jax.random.normal(kk, x.shape, jnp.float32).astype(
            x.dtype) for x, kk in zip(leaves, keys[:len(leaves)])])
    local = dict(local)
    local["active"] = anchor["active"]
    error = jax.tree.unflatten(treedef, [
        scale * jax.random.normal(kk, x.shape, jnp.float32)
        for x, kk in zip(leaves, keys[len(leaves):])])

    state = IE.DiLoCoTrainState(params=local, inner_opt=st0.inner_opt,
                                outer_opt=st0.outer_opt, error=error)
    delta = IE.extract_delta(anchor, state)

    # structural/dtype congruence with the params tree, all fp32
    assert jax.tree.structure(delta) == jax.tree.structure(anchor)
    assert all(x.dtype == jnp.float32 for x in jax.tree.leaves(delta))
    # the active mask is pinned to exactly zero (zero in -> zero out
    # through compression; its outer momentum never moves)
    assert not np.asarray(delta["active"]).any()

    # round trip: apply_delta(anchor, extract_delta(...)) == local.  NOT
    # bitwise — a - (a - p) re-rounds unless Sterbenz applies — so the
    # budget is a few ulps of the operand scale (fp32: ~1e-7 relative)
    local2 = IE.apply_delta(anchor, delta, error=error)
    assert jax.tree.structure(local2) == jax.tree.structure(local)
    for (pa, a), b in zip(jax.tree_util.tree_flatten_with_path(local2)[0],
                          jax.tree.leaves(local)):
        assert a.dtype == b.dtype, pa
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=1e-5, atol=1e-6, err_msg=str(pa))
    # the non-trainable mask round-trips bitwise (carried, not recomputed)
    assert np.array_equal(np.asarray(local2["active"]),
                          np.asarray(anchor["active"]))


# ---------------------------------------------------------------------------
# dryrun --inner pp: qwen1.5-107b shape-checks through the sharded engine
# (pure eval_shape on 512 faked devices — fast, no compute)
# ---------------------------------------------------------------------------

def test_dryrun_pp_inner_smoke_qwen107b():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--inner", "pp",
         "--arch", "qwen1.5-107b"],
        env=_env(), capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "PP-INNER-SMOKE-OK arch=qwen1.5-107b" in r.stdout
    assert "DRYRUN SUMMARY ok=1 skipped=0 fail=0" in r.stdout


# ---------------------------------------------------------------------------
# the differential harness (slow: compiles the shard_map pipeline)
# ---------------------------------------------------------------------------

DIFF_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.optim import adamw
    from repro.parallel import inner_engine as IE
    from repro.parallel import pipeline as PP
    from repro.sim.timeline import tree_hash

    H, ROUNDS, B, S = 3, 3, 8, 16
    LR = 1e-3
    # budget: per-step grads differ by the pipeline-equivalence tolerance
    # (<=1e-3 max-abs, tests/test_pipeline.py) through AdamW's normalized
    # update, compounding linearly over rounds.  Measured drift on this
    # config is ~7e-6 (jax 0.4.37 CPU); the cap below leaves ~75x headroom
    # for other XLA versions' tiling while staying ~20x under the distance
    # actually travelled — the run asserts non-vacuousness explicitly.
    BUDGET = lambda r: 0.5 * LR * (r + 1)

    # n_layers=5, n_stages=2 exercises the padded-slot path (lps=3, pad=1)
    cfg = dataclasses.replace(get_config('granite-3-8b').reduced(),
                              n_layers=5, vocab_size=128)
    pcfg = PP.PipelineConfig(n_stages=2, n_micro=4)
    mesh = IE.unit_mesh(pcfg)

    base = jax.random.PRNGKey(13)
    def batch_fn(c, i):
        key = jax.random.fold_in(jax.random.fold_in(base, c), i)
        return jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    one_cluster, _ = IE.make_pp_one_cluster(cfg, pcfg, mesh, inner_lr=LR,
                                            h_steps=H, batch_fn=batch_fn)
    pp_j = jax.jit(one_cluster)

    # scalar reference: same pp param tree, same data, same AdamW — only
    # the loss runs through the sequential single-replica model
    def ref_loss(params, tokens):
        sp = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                          params["stages"])
        sp = jax.tree.map(lambda x: x[:cfg.n_layers], sp)
        rp = {"embed": params["embed"], "final_norm": params["final_norm"],
              "segments": [sp]}
        if "head" in params:
            rp["head"] = params["head"]
        return M.loss_fn(rp, cfg, {"tokens": tokens}, remat=False)[0]

    def ref_one_cluster(params, opt, c):
        def body(carry, i):
            p, o = carry
            loss, g = jax.value_and_grad(ref_loss)(p, batch_fn(c, i))
            g = dict(g); g["active"] = jnp.zeros_like(g["active"])
            p2, o = adamw.update(g, o, p, lr=LR)
            p2 = dict(p2); p2["active"] = p["active"]
            return (p2, o), loss
        (params, opt), losses = jax.lax.scan(body, (params, opt),
                                             jnp.arange(H))
        return params, opt, losses

    ref_j = jax.jit(ref_one_cluster)

    params0 = PP.init_pp_params(cfg, jax.random.PRNGKey(0), pcfg)
    opt0 = adamw.init(params0)
    maxabs = lambda t: max(float(jnp.abs(x).max())
                           for x in jax.tree.leaves(t))
    diff = lambda a, b: jax.tree.map(lambda x, y: x - y, a, b)

    # leg 1: pp determinism — the jitted program re-run from the same
    # state is bitwise identical per round
    pA, oA = params0, opt0
    pB, oB = params0, opt0
    for r in range(ROUNDS):
        c = jnp.asarray(r, jnp.int32)
        pA, oA, lA = pp_j(pA, oA, c)
        pB, oB, lB = pp_j(pB, oB, c)
        assert tree_hash(pA) == tree_hash(pB), f"pp nondeterministic @r{r}"

    # leg 2: pp vs scalar per-round within the documented budget
    p_pp, o_pp = params0, opt0
    p_rf, o_rf = params0, opt0
    for r in range(ROUNDS):
        c = jnp.asarray(r, jnp.int32)
        p_pp, o_pp, loss_pp = pp_j(p_pp, o_pp, c)
        p_rf, o_rf, loss_rf = ref_j(p_rf, o_rf, c)
        d = maxabs(diff(p_pp, p_rf))
        dl = float(jnp.abs(loss_pp - loss_rf).max())
        moved = maxabs(diff(p_rf, params0))
        print(f"round {r}: max|pp-ref|={d:.2e} budget={BUDGET(r):.2e} "
              f"max|dloss|={dl:.2e} moved={moved:.2e}")
        assert d < BUDGET(r), (r, d, BUDGET(r))
        assert dl < 1e-2 * (r + 1), (r, dl)
        assert d < 0.5 * moved, (r, d, moved)     # budget is not vacuous
        # both engines see the identical token stream
        np.testing.assert_array_equal(np.asarray(batch_fn(c, 0)),
                                      np.asarray(batch_fn(r, 0)))
    print("INNER-ENGINE-DIFF-OK")
""")


@pytest.mark.slow
def test_pp_engine_differential_vs_scalar():
    r = subprocess.run([sys.executable, "-c", DIFF_SCRIPT], env=_env(),
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "INNER-ENGINE-DIFF-OK" in r.stdout
