"""Per-architecture smoke tests (spec deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same family
(2 layers, d_model<=128, <=4 experts) and runs one forward + one train step
on CPU, asserting output shapes and no NaNs. Decode-vs-forward consistency
covers the KV-cache / recurrent-state serving path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.data.synthetic import SyntheticLM, with_frontend
from repro.models import model as M
from repro.optim import adamw

ASSIGNED = [a for a in ARCH_IDS if a not in ("opt-1.3b", "qwen1.5-107b")]


def _batch(cfg, B=2, S=16, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                              cfg.vocab_size)
    return with_frontend({"tokens": toks}, cfg)


# Tier-1 wall time is dominated by XLA compiles of this matrix.  The
# forward graph of each arch used to be compiled twice (once here, once in
# the decode test, on different shapes): the module-scoped cache below
# compiles it ONCE per arch on one shared (B=2, S=16) batch and both tests
# reuse cfg/params/batch/logits.  MoE archs keep their DEFAULT reduced
# capacity here (the token-dropping routing path must stay under test);
# only the decode test raises capacity (dropping breaks step-by-step
# parity), paying a second forward compile for the few MoE archs.
_ARCH_CACHE = {}


def _arch_setup(arch, drop_free_moe=False):
    key = (arch, drop_free_moe and
           get_config(arch).reduced().moe is not None)
    if key not in _ARCH_CACHE:
        cfg = get_config(arch).reduced()
        if key[1]:                # avoid capacity drops in the tiny setting
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        # jit: one fused compile per phase beats per-op eager dispatch ~3x
        # on the bigger reduced archs (and matches how training runs)
        logits, _ = jax.jit(
            lambda p: M.forward(p, cfg, batch, remat=False))(params)
        _ARCH_CACHE[key] = dict(cfg=cfg, params=params, batch=batch,
                                logits=logits)
    return _ARCH_CACHE[key]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    s = _arch_setup(arch)
    cfg, params, batch, logits = (s["cfg"], s["params"], s["batch"],
                                  s["logits"])
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()

    # one full train step: loss + grads + AdamW update
    @jax.jit
    def train_step(p):
        (loss, _), grads = jax.value_and_grad(
            lambda q: M.loss_fn(q, cfg, batch), has_aux=True)(p)
        opt = adamw.init(p)
        new_p, opt2 = adamw.update(grads, opt, p, lr=1e-3)
        return loss, new_p

    loss, new_params = train_step(params)
    assert np.isfinite(float(loss))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert np.isfinite(np.asarray(b)).all()
    # params actually moved
    moved = sum(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert moved > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch):
    s = _arch_setup(arch, drop_free_moe=True)
    cfg, params, batch, logits = (s["cfg"], s["params"], s["batch"],
                                  s["logits"])
    B, S = batch["tokens"].shape[:2]
    state = M.init_decode_state(cfg, B, 32)
    if cfg.is_encdec:
        mem = M.prefill_encoder(params, cfg, batch["frontend"])
        state = M.fill_cross_caches(params, cfg, state, mem)
    errs = []
    toks = batch["tokens"]
    dec = jax.jit(lambda p, st, tk: M.decode_step(p, cfg, st, tk))
    dec_emb = jax.jit(lambda p, st, tk, em: M.decode_step(p, cfg, st, tk,
                                                          embeds=em))
    for t in range(S):
        if cfg.modality == "vlm" and t < cfg.n_frontend_tokens:
            lg, state = dec_emb(params, state, toks[:, t:t + 1],
                                batch["frontend"][:, t:t + 1])
        else:
            lg, state = dec(params, state, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg[:, 0] - logits[:, t]).max()))
    assert max(errs) < 5e-4, f"decode mismatch {max(errs)}"


@pytest.mark.parametrize("arch", ["gemma3-1b"])
def test_sliding_window_ring_cache(arch):
    """Ring-buffer decode on local layers must equal full-cache forward."""
    cfg = get_config(arch).reduced()      # window=8 after reduction
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 20                          # S > window exercises the ring
    batch = _batch(cfg, B, S)
    logits, _ = jax.jit(
        lambda p: M.forward(p, cfg, batch, remat=False))(params)
    state = M.init_decode_state(cfg, B, S)
    dec = jax.jit(lambda p, st, tk: M.decode_step(p, cfg, st, tk))
    for t in range(S):
        lg, state = dec(params, state, batch["tokens"][:, t:t + 1])
        assert float(jnp.abs(lg[:, 0] - logits[:, t]).max()) < 5e-4, t


def test_loss_decreases_tiny_lm():
    """End-to-end sanity: a tiny dense model learns the synthetic stream."""
    cfg = get_config("granite-3-8b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=64)
    data = SyntheticLM(cfg.vocab_size, seq_len=32, batch=8, seed=0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt = adamw.update(g, opt, params, lr=3e-3)
        return params, opt, loss

    losses = []
    for b in data.batches(60):
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, losses[::10]


def test_param_counts_full_scale():
    """Full configs count roughly at their nameplate scale (eval_shape only,
    no allocation)."""
    expect = {"granite-3-8b": (6e9, 13e9), "deepseek-v2-236b": (180e9, 300e9),
              "arctic-480b": (350e9, 560e9), "phi3-medium-14b": (10e9, 18e9),
              "stablelm-12b": (9e9, 16e9), "qwen2-vl-7b": (6e9, 10e9),
              "gemma3-1b": (0.7e9, 2e9), "xlstm-1.3b": (0.8e9, 2.5e9),
              "zamba2-1.2b": (0.8e9, 2e9), "qwen1.5-107b": (90e9, 125e9)}
    for arch, (lo, hi) in expect.items():
        n = M.count_params(get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_active_params_moe():
    cfg = get_config("deepseek-v2-236b")
    total = M.count_params(cfg)
    active = M.count_active_params(cfg)
    assert active < 0.2 * total   # 6/160 routed + shared + attn
