"""Fig. 3 in miniature: the convergence ORDERING the paper claims —
DiLoCoX ~= AllReduce, both beating the OpenDiLoCo-style (oversized H) and
CocktailSGD-style (aggressive per-step compression) baselines at matched
budgets. Small budgets keep this a test; benchmarks/convergence.py is the
full version."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.train import trainer as T


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_config("opt-1.3b").reduced(),
                               vocab_size=128)


BASE = dict(n_clusters=2, local_batch=8, seq_len=32, inner_lr=3e-3, seed=0)
ROUNDS, H = 12, 8


@pytest.mark.slow
def test_diloco_x_close_to_allreduce(cfg):
    ar = T.run_allreduce_training(cfg, T.TrainConfig(**BASE, h_steps=1),
                                  ROUNDS * H)
    tc = T.TrainConfig(**BASE, h_steps=H, compressor="diloco_x",
                       compressor_kw=dict(rank=32, bits=4),
                       outer_lr=0.5, outer_momentum=0.7)
    dlx = T.run_diloco_training(cfg, tc, ROUNDS)
    # the delay penalty at toy scale mirrors the paper's own Table 1
    # direction (w/o overlap converges better); margin reflects it
    assert dlx.eval_losses[-1] < ar.eval_losses[-1] + 1.3, (
        dlx.eval_losses[-1], ar.eval_losses[-1])
    # and it must actually have learned
    assert dlx.eval_losses[-1] < dlx.eval_losses[0] - 0.8


@pytest.mark.slow
def test_compression_does_not_hurt_sync(cfg):
    """Paper Table 1 structure: adding Alg.1 compression costs little loss."""
    tc_nc = T.TrainConfig(**BASE, h_steps=H, delay=False, compress=False,
                          outer_lr=0.7, outer_momentum=0.9)
    tc_c = dataclasses.replace(tc_nc, compress=True, compressor="diloco_x",
                               compressor_kw=dict(rank=32, bits=4))
    r_nc = T.run_diloco_training(cfg, tc_nc, ROUNDS)
    r_c = T.run_diloco_training(cfg, tc_c, ROUNDS)
    assert r_c.eval_losses[-1] < r_nc.eval_losses[-1] + 0.4, (
        r_c.eval_losses[-1], r_nc.eval_losses[-1])


@pytest.mark.slow
def test_cocktail_worse_than_diloco_x(cfg):
    tc = T.TrainConfig(**BASE, compressor="cocktail",
                       compressor_kw=dict(random_ratio=0.1, topk_ratio=0.08,
                                          bits=4))
    ck = T.run_compressed_ddp_training(cfg, tc, ROUNDS * H)
    tcd = T.TrainConfig(**BASE, h_steps=H, compressor="diloco_x",
                        compressor_kw=dict(rank=32, bits=4),
                        outer_lr=0.5, outer_momentum=0.7)
    dlx = T.run_diloco_training(cfg, tcd, ROUNDS)
    assert dlx.eval_losses[-1] < ck.eval_losses[-1] + 0.05, (
        dlx.eval_losses[-1], ck.eval_losses[-1])
