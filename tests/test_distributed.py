"""Integration tests for the mesh runtime: the train/serve drivers run end
to end on simulated multi-device meshes (subprocesses keep the main pytest
process at 1 device)."""
import os
import subprocess
import sys

import pytest


def _run(cmd, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m"] + cmd, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_driver_8dev_diloco():
    r = _run(["repro.launch.train", "--arch", "granite-3-8b", "--smoke",
              "--devices", "8", "--clusters", "2", "--data", "2",
              "--model", "2", "--rounds", "3", "--h-steps", "4",
              "--global-batch", "8", "--seq-len", "32"])
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "TRAIN-DRIVER-OK" in r.stdout
    # losses should be finite and logged per round
    assert r.stdout.count("round ") == 3


@pytest.mark.slow
def test_train_driver_adaptive():
    r = _run(["repro.launch.train", "--arch", "gemma3-1b", "--smoke",
              "--devices", "4", "--clusters", "2", "--data", "1",
              "--model", "2", "--rounds", "3", "--h-steps", "3",
              "--global-batch", "4", "--seq-len", "32", "--adaptive",
              "--rank", "8"])
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "TRAIN-DRIVER-OK" in r.stdout


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-1b", "zamba2-1.2b"])
def test_serve_driver(arch):
    r = _run(["repro.launch.serve", "--arch", arch, "--smoke",
              "--devices", "4", "--batch", "4", "--prompt-len", "8",
              "--gen-len", "8"])
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "SERVE-DRIVER-OK" in r.stdout
    # satellite: throughput is now reported per phase + the combined line
    assert "prefill: " in r.stdout and "decode: " in r.stdout
    assert "generated shape" in r.stdout


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-1b", "granite-3-8b"])
def test_serve_driver_paged(arch):
    r = _run(["repro.launch.serve", "--arch", arch, "--smoke",
              "--devices", "4", "--batch", "3", "--prompt-len", "8",
              "--gen-len", "8", "--paged", "--requests", "6"])
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "SERVE-DRIVER-OK" in r.stdout
    assert "paged engine: 6 requests" in r.stdout
    assert "admission fingerprint:" in r.stdout


@pytest.mark.slow
def test_serve_driver_paged_unsupported_family():
    # zamba2 is a hybrid SSM stack: the paged engine must refuse cleanly
    r = _run(["repro.launch.serve", "--arch", "zamba2-1.2b", "--smoke",
              "--devices", "4", "--batch", "2", "--prompt-len", "4",
              "--gen-len", "4", "--paged"])
    assert r.returncode == 2, r.stdout[-1500:] + r.stderr[-1500:]
    assert "SERVE-DRIVER-UNSUPPORTED" in r.stdout
