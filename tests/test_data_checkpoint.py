"""Data pipeline determinism/shardability + checkpoint round-trip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import checkpoint as ckpt
from repro.data.synthetic import SyntheticLM, make_markov_table


def test_data_deterministic():
    a = SyntheticLM(128, 32, 4, seed=3)
    b = SyntheticLM(128, 32, 4, seed=3)
    for _ in range(3):
        np.testing.assert_array_equal(np.asarray(a.next_batch()["tokens"]),
                                      np.asarray(b.next_batch()["tokens"]))


def test_data_shards_disjoint():
    a = SyntheticLM(128, 32, 4, seed=3, data_shard=0)
    b = SyntheticLM(128, 32, 4, seed=3, data_shard=1)
    ta = np.asarray(a.next_batch()["tokens"])
    tb = np.asarray(b.next_batch()["tokens"])
    assert not np.array_equal(ta, tb)


def test_data_follows_markov_table():
    """Generated successors are always rows of the transition table —
    the learnability guarantee behind the convergence experiments."""
    d = SyntheticLM(64, 64, 4, seed=0, branching=4)
    toks = np.asarray(d.next_batch()["tokens"])
    table = np.asarray(d.table)
    for row in toks:
        for t in range(len(row) - 1):
            assert row[t + 1] in table[row[t]], (t, row[t], row[t + 1])


@settings(max_examples=10, deadline=None)
@given(vocab=st.integers(8, 256), branching=st.integers(2, 8),
       seed=st.integers(0, 50))
def test_markov_table_shape(vocab, branching, seed):
    t = make_markov_table(vocab, branching, seed)
    assert t.shape == (vocab, branching)
    assert (0 <= t).all() and (t < vocab).all()


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32),
                       "c": jnp.zeros((2, 2), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "step_0007")
        ckpt.save(path, tree, step=7, meta={"arch": "t"})
        restored, step = ckpt.restore(path, jax.eval_shape(lambda: tree))
        assert step == 7
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
        assert ckpt.latest(d).endswith("step_0007")


def test_checkpoint_latest_picks_max_step():
    tree = {"w": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 9, 4):
            ckpt.save(os.path.join(d, f"r{s}"), tree, step=s)
        assert ckpt.latest(d).endswith("r9")
