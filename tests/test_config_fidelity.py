"""The assigned architecture configs must match the assignment table
EXACTLY (spec deliverable f: "write src/repro/configs/<id>.py with the
exact config above")."""
import pytest

from repro.configs.base import get_config

# (n_layers, d_model, n_heads, n_kv, d_ff, vocab) from the assignment
TABLE = {
    "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
    "deepseek-v2-236b": (60, 5120, 128, 128, None, 102400),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
}


@pytest.mark.parametrize("arch", sorted(TABLE))
def test_config_matches_assignment(arch):
    L, d, H, KV, ff, V = TABLE[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.vocab_size == V
    assert cfg.source, "every config must cite its source"


def test_moe_details():
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.n_experts == 160 and ds.moe.top_k == 6
    assert ds.moe.d_ff_expert == 1536 and ds.moe.n_shared_experts == 2
    assert ds.mla.kv_lora_rank == 512
    ar = get_config("arctic-480b")
    assert ar.moe.n_experts == 128 and ar.moe.top_k == 2
    assert ar.moe.dense_residual


def test_special_structure():
    g = get_config("gemma3-1b")
    assert g.sliding_window == 512 and g.global_every == 6   # 5:1 pattern
    z = get_config("zamba2-1.2b")
    assert z.ssm.kind == "mamba2" and z.ssm.d_state == 64
    assert z.hybrid.shared_attn_period == 6
    x = get_config("xlstm-1.3b")
    assert x.ssm.kind == "xlstm" and x.ssm.xlstm_unit == 8
    q = get_config("qwen2-vl-7b")
    assert q.mrope and sum(q.mrope_sections) == q.resolved_head_dim // 2
    s = get_config("seamless-m4t-large-v2")
    assert s.is_encdec and s.n_enc_layers == 24
