"""Telemetry layer (repro.obs): Chrome-trace export + schema validation,
structural trace fingerprints, metrics registry / Prometheus exposition,
overlap ledger math, structured logger stability, the benchmark
trajectory diff, and Timeline degenerate inputs.  Everything here is
read-only observability — no test touches the numeric path."""
import io
import json
import math

import pytest

from repro.obs import (MetricsRegistry, OverlapLedger, Tracer,
                       timeline_trace, trace_fingerprint,
                       validate_chrome_trace)
from repro.obs import ledger as ledger_mod
from repro.obs import log as log_mod
from repro.sim import LinkProfile, Scenario, Timeline, simulate
from repro.sim.timeline import RoundEvent


def scenario(**kw):
    base = dict(n_clusters=3, rounds=4, h_steps=10, t_step_s=1.0,
                n_params=1e8, compressor="diloco_x",
                compressor_kw={"rank": 32}, seed=3)
    base.update(kw)
    return Scenario(**base)


def event(r=0, **kw):
    base = dict(round=r, alive=(0, 1), rejoined=(), h_steps=4, rank=8,
                t_compute_s=4.0, t_comm_s=2.0, exposed_comm_s=0.5,
                t_round_s=4.5, wire_bytes=1000, slowest_cluster=0,
                bottleneck_cluster=-1, tokens=100.0)
    base.update(kw)
    return RoundEvent(**base)


# ---------------------------------------------------------------------------
# trace export: schema validity, nesting, structural determinism
# ---------------------------------------------------------------------------

def test_modeled_trace_valid_and_json_round_trips(tmp_path):
    tl = simulate(scenario(link=LinkProfile(jitter=0.1)))
    trace = timeline_trace(tl)
    assert validate_chrome_trace(trace) == []
    # survives a disk round-trip as plain JSON
    p = tmp_path / "trace.json"
    p.write_text(json.dumps(trace))
    loaded = json.loads(p.read_text())
    assert validate_chrome_trace(loaded) == []
    assert trace_fingerprint(loaded) == trace_fingerprint(trace)
    # every complete event carries the full Chrome-trace field set and a
    # round tag; the category says "modeled" on the in-process backend
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert xs, "no spans exported"
    for ev in xs:
        for k in ("name", "cat", "ph", "ts", "dur", "pid", "tid", "args"):
            assert k in ev
        assert ev["cat"] == "modeled"
        assert ev["dur"] >= 0
        assert isinstance(ev["args"]["round"], int)
    # modeled spans cover the expected taxonomy
    names = {e["name"] for e in xs}
    assert {"round", "inner", "idle", "wire"} <= names


def test_identical_seed_identical_structural_trace_fingerprint():
    sc = scenario(link=LinkProfile(jitter=0.2))
    fp = [trace_fingerprint(timeline_trace(simulate(sc)))
          for _ in range(2)]
    assert fp[0] == fp[1]
    sc2 = scenario(link=LinkProfile(jitter=0.2), seed=99)
    tr2 = timeline_trace(simulate(sc2))
    # same scenario shape, different jitter draw: the structural
    # fingerprint ignores ts/dur, so it still matches
    assert trace_fingerprint(tr2) == fp[0]


def test_trace_fingerprint_ignores_wall_clock():
    tl = simulate(scenario())
    trace = timeline_trace(tl)
    shifted = json.loads(json.dumps(trace))
    for ev in shifted["traceEvents"]:
        if ev["ph"] == "X":
            ev["ts"] += 123.0
            ev["dur"] *= 3.0
    assert trace_fingerprint(shifted) == trace_fingerprint(trace)


def test_validator_catches_bad_traces():
    assert validate_chrome_trace([1, 2]) != []
    assert validate_chrome_trace({"nope": 1}) != []
    missing = {"traceEvents": [{"ph": "X", "ts": 0.0, "dur": 1.0,
                                "pid": 0}]}          # no name/tid
    assert any("missing" in e for e in validate_chrome_trace(missing))
    negdur = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0,
                               "dur": -1.0, "pid": 0, "tid": 0}]}
    assert any("negative" in e for e in validate_chrome_trace(negdur))
    # partial overlap in one (pid, tid) row: [0, 10) vs [5, 15)
    overlap = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0, "pid": 0,
         "tid": 0},
        {"name": "b", "ph": "X", "ts": 5.0, "dur": 10.0, "pid": 0,
         "tid": 0}]}
    assert any("overlap" in e for e in validate_chrome_trace(overlap))
    # proper nesting on the same row is fine
    nested = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0, "pid": 0,
         "tid": 0},
        {"name": "b", "ph": "X", "ts": 2.0, "dur": 3.0, "pid": 0,
         "tid": 0}]}
    assert validate_chrome_trace(nested) == []


def test_spans_not_in_structural_timeline_fingerprint():
    """RoundEvent.spans is telemetry: two timelines that differ only in
    spans must share a structural fingerprint (the proc drift gate) while
    the full fingerprint legitimately differs."""
    e1 = event(spans=(("inner", 0, 0.0, 1.0),))
    e2 = event(spans=(("inner", 0, 0.0, 2.5), ("wire", 1, 0.0, 9.0)))
    a = Timeline(scenario={"n_clusters": 2}, events=[e1])
    b = Timeline(scenario={"n_clusters": 2}, events=[e2])
    assert "spans" not in Timeline.STRUCTURAL_FIELDS
    assert a.structural_fingerprint() == b.structural_fingerprint()
    assert a.fingerprint() != b.fingerprint()


def test_tracer_records_nested_spans(tmp_path):
    tr = Tracer("unit-test")
    with tr.span("round", round=0):
        with tr.span("inner", round=0):
            pass
    trace = tr.trace()
    assert validate_chrome_trace(trace) == []
    names = [e["name"] for e in trace["traceEvents"] if e["ph"] == "X"]
    assert sorted(names) == ["inner", "round"]
    p = tmp_path / "t.json"
    tr.write(str(p))
    assert validate_chrome_trace(json.loads(p.read_text())) == []


# ---------------------------------------------------------------------------
# metrics registry + exports
# ---------------------------------------------------------------------------

def test_metrics_fold_matches_timeline_aggregates(tmp_path):
    tl = simulate(scenario())
    reg = MetricsRegistry(run_meta={"backend": "model"})
    reg.observe_timeline(tl)
    snap = reg.snapshot()
    assert snap["repro_rounds_total"] == len(tl.events)
    assert snap["repro_wire_bytes_total"] == pytest.approx(
        sum(e.wire_bytes_total or e.wire_bytes for e in tl.events))
    assert snap["repro_hidden_comm_seconds_total"] == pytest.approx(
        tl.total_hidden_comm_s)
    assert snap["repro_exposed_comm_seconds_total"] == pytest.approx(
        sum(e.exposed_comm_s for e in tl.events))
    hist = snap["repro_round_seconds"]
    assert hist["count"] == len(tl.events)
    assert hist["sum"] == pytest.approx(tl.total_time_s)

    # JSONL: meta line first, then one record per round, stable keys
    p = tmp_path / "m.jsonl"
    reg.write_jsonl(str(p))
    lines = [json.loads(l) for l in p.read_text().splitlines()]
    assert lines[0] == {"meta": {"backend": "model"}}
    assert len(lines) - 1 == len(tl.events)
    for rec, e in zip(lines[1:], tl.events):
        assert rec["round"] == e.round
        assert rec["t_round_s"] == pytest.approx(e.t_round_s, abs=1e-6)
        assert rec["hidden_comm_s"] == pytest.approx(
            max(0.0, e.t_comm_s - e.exposed_comm_s), abs=1e-6)


def test_prometheus_text_exposition_format():
    reg = MetricsRegistry()
    reg.counter("repro_rounds_total", "rounds").inc(3)
    reg.gauge("repro_loss", "loss").set(1.5)
    h = reg.histogram("repro_round_seconds", "round s", buckets=(1.0, 5.0))
    for v in (0.5, 2.0, 99.0):
        h.observe(v)
    text = reg.prometheus_text()
    assert "# HELP repro_rounds_total rounds" in text
    assert "# TYPE repro_rounds_total counter" in text
    assert "repro_rounds_total 3" in text
    assert "repro_loss 1.5" in text
    # histogram buckets are cumulative and end at +Inf == _count
    assert 'repro_round_seconds_bucket{le="1"} 1' in text
    assert 'repro_round_seconds_bucket{le="5"} 2' in text
    assert 'repro_round_seconds_bucket{le="+Inf"} 3' in text
    assert "repro_round_seconds_count 3" in text
    assert "repro_round_seconds_sum 101.5" in text
    # every non-comment line is "name[{labels}] value"
    for line in text.strip().splitlines():
        if not line.startswith("#"):
            name, value = line.rsplit(" ", 1)
            assert name and float(value) == float(value)


def test_metric_kind_mismatch_and_counter_decrease_rejected():
    reg = MetricsRegistry()
    reg.counter("x").inc()
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.counter("x").inc(-1)


# ---------------------------------------------------------------------------
# overlap ledger
# ---------------------------------------------------------------------------

def test_ledger_identity_and_efficiency():
    tl = simulate(scenario())
    led = OverlapLedger.from_timeline(tl)
    for row, e in zip(led.rows, tl.events):
        # the ledger identity: hidden + exposed == t_comm (modeled clock,
        # exposed can never exceed t_comm in-process)
        assert row.hidden_comm_s + row.exposed_comm_s == pytest.approx(
            e.t_comm_s, abs=1e-9)
        assert 0.0 <= row.overlap_frac <= 1.0
    assert led.overlap_efficiency == pytest.approx(
        tl.overlap_efficiency, abs=1e-9)
    assert "overlap ledger: comm" in led.summary()
    d = led.to_dict()
    assert d["summary"]["comm_s"] == pytest.approx(led.comm_s, abs=1e-6)
    assert len(d["rows"]) == len(tl.events)


def test_ledger_clamps_measured_noise():
    # proc noise can push measured exposed past t_comm: hidden clamps at 0
    e = event(t_comm_s=1.0, exposed_comm_s=1.4)
    led = OverlapLedger.from_timeline(
        Timeline(scenario={}, events=[e]))
    assert led.rows[0].hidden_comm_s == 0.0
    assert led.overlap_efficiency == 0.0


def test_drift_measured_vs_modeled():
    modeled = Timeline(scenario={}, events=[event(r, t_round_s=2.0)
                                            for r in range(3)])
    measured = Timeline(scenario={}, events=[event(r, t_round_s=2.5)
                                             for r in range(3)])
    d = ledger_mod.drift(measured, modeled)
    assert d["per_round_s"] == [0.5, 0.5, 0.5]
    assert d["cumulative_s"] == [0.5, 1.0, 1.5]
    assert d["final_drift_s"] == 1.5
    assert d["final_drift_frac"] == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# structured logger
# ---------------------------------------------------------------------------

@pytest.fixture
def restore_log_config():
    yield
    log_mod.configure(stream=None, json_stream=None, level="info")


def test_logger_human_line_is_exactly_msg(restore_log_config):
    human, js = io.StringIO(), io.StringIO()
    log_mod.configure(stream=human, json_stream=js)
    log = log_mod.get_logger("t")
    log.info("round 0: loss=1.0", round=0, loss=1.0)
    # byte-stable: the human line is the message alone — fields only ever
    # appear on the JSON stream (CLI output is grepped by tests/CI)
    assert human.getvalue() == "round 0: loss=1.0\n"
    rec = json.loads(js.getvalue())
    assert rec["msg"] == "round 0: loss=1.0"
    assert rec["round"] == 0 and rec["loss"] == 1.0
    assert rec["logger"] == "t" and rec["level"] == "info"


def test_logger_levels_and_prefixes(restore_log_config):
    human = io.StringIO()
    log_mod.configure(stream=human, level="info")
    log = log_mod.get_logger("t2")
    log.debug("hidden")
    log.warning("careful")
    log.error("boom")
    assert human.getvalue() == "WARNING: careful\nERROR: boom\n"
    with pytest.raises(ValueError):
        log_mod.configure(level="loud")


# ---------------------------------------------------------------------------
# benchmark trajectory diff
# ---------------------------------------------------------------------------

def test_trajectory_flatten_and_regression_detection():
    from benchmarks.trajectory import compare, flatten
    cur = flatten({"a": {"b": 10.0, "ok": True}, "list": [1, 2]})
    assert cur == {"a.b": 10.0, "list.0": 1.0, "list.1": 2.0}
    prev = {"a.b": 4.0, "list.0": 1.0, "list.1": 2.0, "gone": 5.0}
    diff = compare(cur, prev, threshold=2.0)
    regressed = [r[0] for r in diff["regressions"]]
    assert regressed == ["a.b"]          # 2.5x move, either direction
    assert diff["only_previous"] == ["gone"]
    # direction-agnostic: a 2.5x *improvement* trips the same wire
    diff2 = compare({"a.b": 4.0}, {"a.b": 10.0}, threshold=2.0)
    assert len(diff2["regressions"]) == 1


def test_trajectory_cli_exit_codes(tmp_path):
    from benchmarks.trajectory import main
    cur = tmp_path / "cur.json"
    prev = tmp_path / "prev.json"
    cur.write_text(json.dumps({"sections": {"k": {"v": 10.0}}}))
    prev.write_text(json.dumps({"k": {"v": 1.0}}))   # schema-tolerant
    with pytest.raises(SystemExit) as ex:
        main([str(cur), str(prev)])
    assert ex.value.code == 1
    with pytest.raises(SystemExit) as ex:
        main([str(cur), str(prev), "--warn-only"])
    assert ex.value.code in (0, None)
    # a missing baseline is the cold-start case, never an error
    with pytest.raises(SystemExit) as ex:
        main([str(cur), str(tmp_path / "nope.json")])
    assert ex.value.code in (0, None)
    # within-threshold success returns normally (exit status 0)
    main([str(cur), str(prev), "--threshold", "20"])


# ---------------------------------------------------------------------------
# Timeline degenerate inputs
# ---------------------------------------------------------------------------

def test_empty_timeline_degenerate():
    tl = Timeline(scenario={"n_clusters": 0})
    assert tl.total_time_s == 0.0
    assert tl.tokens_per_s == 0.0
    assert tl.exposed_comm_frac == 0.0
    assert tl.total_hidden_comm_s == 0.0
    assert tl.overlap_efficiency == 1.0     # nothing needed hiding
    assert tl.barrier_idle_frac == 0.0
    assert tl.h_schedule() == [] and tl.rank_schedule() == []
    assert isinstance(tl.fingerprint(), str)
    assert isinstance(tl.structural_fingerprint(), str)
    assert "total 0.00s" in tl.table()
    d = tl.to_dict()
    assert d["events"] == []
    trace = timeline_trace(tl)
    assert validate_chrome_trace(trace) == []
    led = OverlapLedger.from_timeline(tl)
    assert led.rows == [] and led.overlap_efficiency == 1.0


def test_all_dead_rounds_timeline():
    dead = [event(r, alive=(), t_compute_s=0.0, t_comm_s=0.0,
                  exposed_comm_s=0.0, t_round_s=0.0, wire_bytes=0,
                  tokens=0.0, faults=("all dead",), rank=None,
                  slowest_cluster=-1, spans=None)
            for r in range(2)]
    tl = Timeline(scenario={"n_clusters": 2}, events=dead)
    assert tl.tokens_per_s == 0.0
    assert tl.overlap_efficiency == 1.0
    assert "all dead" in tl.table()
    assert validate_chrome_trace(timeline_trace(tl)) == []
    reg = MetricsRegistry()
    reg.observe_timeline(tl)
    assert reg.snapshot()["repro_alive_clusters"] == 0


def test_h_by_none_mixed_with_schedule_rounds():
    evs = [event(0, h_by=None),
           event(1, h_by=(4, 2)),
           event(2, h_by=None)]
    tl = Timeline(scenario={"n_clusters": 2}, events=evs)
    assert tl.h_schedule() == [4, [4, 2], 4]
    assert isinstance(tl.structural_fingerprint(), str)
    assert validate_chrome_trace(timeline_trace(tl)) == []
    reg = MetricsRegistry()
    reg.observe_timeline(tl)
    recs = reg.round_records
    assert [r["h_steps"] for r in recs] == [4, 4, 4]


# ---------------------------------------------------------------------------
# proc backend: measured spans (slow — spawns real worker processes)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_proc_trace_valid_and_sums_to_round_accounting(tmp_path):
    """2-cluster timing-only proc run: the exported trace must be valid
    Chrome-trace JSON whose per-round hidden+exposed comm accounting sums
    to the RoundEvent's measured t_comm within the equivalence-style
    tolerance (measured independently, so noise-bounded, not exact)."""
    from repro.sim.proc import run_proc

    sc = Scenario(n_clusters=2, rounds=3, h_steps=2, t_step_s=0.02,
                  link=LinkProfile(bytes_per_s=200_000),
                  compressor="diloco_x",
                  compressor_kw={"rank": 8, "min_dim_for_lowrank": 8},
                  rank=8, n_params=1e5, seed=0)
    tl = run_proc(sc, None)
    assert len(tl.events) == 3
    # every round shipped measured spans from both workers
    for e in tl.events:
        assert e.spans, f"round {e.round} has no spans"
        clusters = {s[1] for s in e.spans if s[1] >= 0}
        assert clusters == {0, 1}
        names = {s[0] for s in e.spans}
        # timing-only mode: no numeric phases, so compress/outer are
        # absent — the compute/idle/wire/mix skeleton must still be there
        assert {"inner", "idle", "wire", "mix"} <= names

    trace = timeline_trace(tl)
    assert validate_chrome_trace(trace) == []
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert all(e["cat"] == "measured" for e in xs)

    # per-round envelope accounting: hidden + exposed vs t_comm, within
    # the same tolerance shape the proc equivalence gate uses
    envelopes = [e for e in xs if e["name"] == "round"]
    assert len(envelopes) == 3
    for env, e in zip(envelopes, tl.events):
        a = env["args"]
        assert a["round"] == e.round
        total = a["hidden_comm_s"] + a["exposed_comm_s"]
        tol = 0.3 + 0.5 * e.t_comm_s
        assert abs(total - e.t_comm_s) <= tol, (
            f"round {e.round}: hidden+exposed {total:.3f}s vs "
            f"t_comm {e.t_comm_s:.3f}s (tol {tol:.3f}s)")
    tf = trace_fingerprint(trace)
    assert tf == trace_fingerprint(json.loads(json.dumps(trace)))
