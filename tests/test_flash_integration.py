"""Flash-attention kernel integration: the model's attention path with
REPRO_USE_PALLAS=1 matches the default XLA path (subprocess so the env var
is set before kernels import)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os, dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config
    from repro.models import model as M

    cfg = dataclasses.replace(get_config('granite-3-8b').reduced(),
                              vocab_size=128)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                              cfg.vocab_size)
    os.environ['REPRO_USE_PALLAS'] = '0'
    base, _ = M.forward(params, cfg, {'tokens': toks}, remat=False)
    os.environ['REPRO_USE_PALLAS'] = '1'
    flash, _ = jax.jit(lambda p, t: M.forward(p, cfg, {'tokens': t},
                                              remat=False))(params, toks)
    err = float(jnp.abs(base - flash).max())
    assert err < 5e-3, err
    print('FLASH-INTEGRATION-OK', err)
""")


@pytest.mark.slow
def test_flash_path_matches_xla():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "FLASH-INTEGRATION-OK" in r.stdout
