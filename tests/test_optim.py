"""Optimizer unit/property tests (inner AdamW + schedules)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import adamw, schedules


def test_adamw_descends_quadratic():
    x = {"w": jnp.ones((8,)) * 3.0}
    st_ = adamw.init(x)
    for _ in range(200):
        g = {"w": x["w"]}
        x, st_ = adamw.update(g, st_, x, lr=5e-2, weight_decay=0.0)
    assert float(jnp.abs(x["w"]).max()) < 0.2


def test_adamw_grad_clip():
    """Huge gradients get norm-clipped: one step moves <= lr * (1 + eps)."""
    x = {"w": jnp.zeros((4,))}
    st_ = adamw.init(x)
    g = {"w": jnp.full((4,), 1e9)}
    x2, _ = adamw.update(g, st_, x, lr=1e-3, weight_decay=0.0, grad_clip=1.0)
    assert float(jnp.abs(x2["w"]).max()) <= 1.1e-3


@settings(max_examples=20, deadline=None)
@given(peak=st.floats(1e-5, 1e-2), warm=st.integers(1, 100),
       total=st.integers(101, 1000))
def test_warmup_cosine_bounds(peak, warm, total):
    lr = schedules.warmup_cosine(peak, warm, total)
    vals = [float(lr(jnp.asarray(s))) for s in
            [0, warm // 2, warm, (warm + total) // 2, total, total + 10]]
    assert all(0 <= v <= peak * (1 + 1e-6) for v in vals)
    assert vals[2] >= vals[1]                    # warmup rises
    assert vals[-1] <= vals[3] + 1e-9            # cosine decays


def test_adamw_state_sharding_structure():
    """m/v mirror param structure exactly (the Dual Optimizer Policy's
    'balanced VRAM' requires state to shard like params)."""
    p = {"a": jnp.zeros((4, 8)), "b": {"c": jnp.zeros((3,))}}
    st_ = adamw.init(p)
    assert jax.tree.structure(st_.m) == jax.tree.structure(p)
    for x, y in zip(jax.tree.leaves(p), jax.tree.leaves(st_.m)):
        assert x.shape == y.shape
