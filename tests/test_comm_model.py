"""core/comm.py analytic model: collective arithmetic, the §2.3 overlap
rule, and the paper's §4.2.2 speedup reproduction (Fig. 4 / 357x)."""
import numpy as np
import pytest

from repro.core import comm


def test_ring_allreduce_arithmetic():
    """Ring AllReduce moves 2(C-1)/C * bytes per link."""
    sc = comm.CommScenario(n_clusters=4, link_bytes_per_s=1e9)
    np.testing.assert_allclose(comm.ring_allreduce_time(8e9, sc),
                               2 * 3 / 4 * 8e9 / 1e9, rtol=1e-12)
    # C=2 degenerate ring: exactly one payload each way
    sc2 = comm.CommScenario(n_clusters=2, link_bytes_per_s=1e9)
    np.testing.assert_allclose(comm.ring_allreduce_time(8e9, sc2),
                               8.0, rtol=1e-12)


def test_gather_arithmetic():
    """Ring all-gather forwards the per-cluster payload C-1 times."""
    sc = comm.CommScenario(n_clusters=5, link_bytes_per_s=2e9)
    np.testing.assert_allclose(comm.gather_time(4e9, sc),
                               4 * 4e9 / 2e9, rtol=1e-12)
    # gather moves (C-1)*payload; allreduce 2(C-1)/C*total — for the same
    # total bytes the gather of a 1/C-share is cheaper by 2x exactly
    total = 10e9
    np.testing.assert_allclose(
        comm.gather_time(total / 5, sc) / comm.ring_allreduce_time(total, sc),
        0.5, rtol=1e-12)


@pytest.mark.parametrize("h,overlap", [(10, True), (10, False), (1, True)])
def test_overlap_rule(h, overlap):
    """exposed = max(0, T_comm - H*T_step) iff overlap."""
    sc = comm.CommScenario(n_clusters=3, link_bytes_per_s=1e8, t_step_s=2.0)
    wire = 5e9
    r = comm.method_throughput("m", param_bytes_fp32=1e9, wire_bytes=wire,
                               h_steps=h, overlap=overlap, sc=sc)
    t_comm = comm.gather_time(wire, sc)
    expect = max(0.0, t_comm - h * sc.t_step_s) if overlap else t_comm
    np.testing.assert_allclose(r.exposed_comm_s, expect, rtol=1e-12)
    np.testing.assert_allclose(r.t_round_s, h * sc.t_step_s + expect,
                               rtol=1e-12)
    np.testing.assert_allclose(r.tokens_per_s,
                               sc.tokens_per_step * h / r.t_round_s,
                               rtol=1e-12)


def test_fully_hidden_comm_costs_nothing():
    sc = comm.CommScenario(n_clusters=2, link_bytes_per_s=1e12, t_step_s=1.0)
    r = comm.method_throughput("m", param_bytes_fp32=1e9, wire_bytes=1e6,
                               h_steps=100, overlap=True, sc=sc)
    assert r.exposed_comm_s == 0.0
    np.testing.assert_allclose(r.t_round_s, 100.0, rtol=1e-12)


def test_allreduce_per_step_has_no_overlap():
    sc = comm.CommScenario(n_clusters=2, link_bytes_per_s=1e9, t_step_s=1.0)
    r = comm.method_throughput("ddp", param_bytes_fp32=4e9, wire_bytes=4e9,
                               h_steps=1, overlap=False, sc=sc,
                               allreduce_per_step=True)
    np.testing.assert_allclose(r.comm_s_per_round,
                               comm.ring_allreduce_time(4e9, sc), rtol=1e-12)
    np.testing.assert_allclose(r.t_round_s, 1.0 + r.comm_s_per_round,
                               rtol=1e-12)
    assert r.exposed_comm_s == r.comm_s_per_round


def test_paper_357x_speedup_reproduction():
    """benchmarks/throughput.py end-to-end: real parameter shapes, real
    compressor accounting, calibrated step time — the §4.2.2 speedups
    must come out at the paper's order of magnitude, in the paper's
    order."""
    from benchmarks import throughput

    r107 = throughput.run("qwen1.5-107b")
    s = r107["speedup_vs_allreduce"]
    assert s["diloco_x"] > s["cocktail"] > 1.0
    assert 250 < s["diloco_x"] < 450           # paper: 357x
    assert r107["diloco_x_vs_cocktail"] > 1.0  # paper: ~1.35x

    r13 = throughput.run("opt-1.3b")
    s13 = r13["speedup_vs_allreduce"]
    assert 20 < s13["diloco_x"] < 60           # paper: 32x
    # method ordering is scale-dependent only in magnitude, not in sign:
    # DiLoCoX beats vanilla AllReduce everywhere
    assert s13["diloco_x"] > 1.0
