"""Event-driven round engine (repro.sim.engine): the barrier policy is
exactly the historical loop, and bounded_stale gives deterministic
SSP-style async rounds — staleness bound honored through churn, eager
commits cutting barrier idle, and the staleness-weighted / trimmed-mean
aggregation converging (and surviving a Byzantine member) end to end."""
import numpy as np
import pytest

from repro.sim import (FaultSchedule, Join, Leave, LinkProfile, Scenario,
                       Straggler, simulate)
from repro.sim.engine import AsyncCommit, BoundedStaleEngine, run_barrier
from repro.sim.faults import Byzantine
from repro.sim.quadratic import QuadraticSpec


# ---------------------------------------------------------------------------
# engine kernel (no scenario, no jax)
# ---------------------------------------------------------------------------

def test_run_barrier_is_the_sequential_loop():
    seen = []
    run_barrier(5, seen.append)
    assert seen == [0, 1, 2, 3, 4]


def _drive(n=3, rounds=4, s=1, legs=None, leaves=(), joins=(), **kw):
    commits = []
    eng = BoundedStaleEngine(
        n_clusters=n, rounds=rounds, max_staleness=s,
        peers=[[p for p in range(n) if p != c] for c in range(n)],
        leg_seconds=legs or (lambda c, k: 1.0),
        send_seconds=lambda c, k: 0.1,
        commit=commits.append, leaves=leaves, joins=joins, **kw)
    eng.run()
    return commits


def test_engine_commits_every_leg_once_and_is_deterministic():
    a = _drive(legs=lambda c, k: 1.0 + 0.3 * c)
    b = _drive(legs=lambda c, k: 1.0 + 0.3 * c)
    assert a == b                         # exact dataclass equality
    per = {}
    for ev in a:
        assert isinstance(ev, AsyncCommit)
        per.setdefault(ev.cluster, []).append(ev.round)
    assert all(v == [0, 1, 2, 3] for v in per.values())


def test_engine_staleness_bound_holds_under_stragglers():
    slow = lambda c, k: 5.0 if c == 2 else 1.0
    for s in (0, 1, 2):
        for ev in _drive(rounds=6, s=s, legs=slow):
            for p, stale in ev.staleness:
                assert 0 <= stale <= s, (ev.cluster, ev.round, p, stale)


def test_engine_zero_staleness_is_barrier_cadence():
    # with s=0 nobody commits leg k before every peer has published leg k:
    # commit order collapses to whole-fleet waves, like the barrier loop
    commits = _drive(rounds=4, s=0, legs=lambda c, k: 1.0 + 0.5 * c)
    waves = [ev.round for ev in commits]
    assert waves == sorted(waves)
    for ev in commits:
        assert all(stale == 0 for _, stale in ev.staleness)
        # and every live peer's delta is incorporated, barrier-style
        assert len(ev.used) == 3


def test_engine_fast_clusters_run_ahead_within_bound():
    slow = lambda c, k: 4.0 if c == 2 else 1.0
    commits = _drive(rounds=6, s=2, legs=slow)
    clock = {c: [] for c in range(3)}
    for ev in commits:
        clock[ev.cluster].append(ev.t_commit)
    # the fast clusters finish their 6 legs well before the straggler
    assert max(clock[0][-1], clock[1][-1]) < clock[2][-1]
    # but never more than s+1 legs ahead (the gate would block them)
    for ev in commits:
        own = ev.round_clock[ev.cluster]
        others = [ev.round_clock[p] for p in range(3) if p != ev.cluster]
        assert own - min(others) <= 3


def test_engine_membership_leave_join_sequencing():
    hooks = []
    commits = _drive(
        rounds=6, s=1, leaves=[(2, 1)], joins=[(4, 1)],
        on_leave=lambda c, k, t: hooks.append(("leave", c, k)),
        on_join=lambda c, k, t: hooks.append(("join", c, k)))
    assert ("leave", 1, 2) in hooks and ("join", 1, 4) in hooks
    assert hooks.index(("leave", 1, 2)) < hooks.index(("join", 1, 4))
    # no commit from c1 for legs 2..3; it resumes at the fleet frontier
    c1 = [ev.round for ev in commits if ev.cluster == 1]
    assert 2 not in c1 and 3 not in c1 and c1 == sorted(c1)
    rejoined = [ev for ev in commits if ev.rejoined]
    assert rejoined and rejoined[0].cluster == 1
    # nobody ever incorporated c1's pre-leave delta after it went stale
    for ev in commits:
        for p, idx in ev.used:
            assert idx >= ev.round - 1, (ev.cluster, ev.round, p, idx)


def test_engine_rejects_bad_parameters():
    with pytest.raises(ValueError):
        _drive(rounds=0)
    with pytest.raises(ValueError):
        _drive(s=-1)


def test_engine_every_used_version_was_published_first():
    """Regression (publish/commit split): every ``used`` version must have
    fired ``on_publish`` BEFORE the commit that incorporates it — including
    versions whose publisher is itself still gate-blocked (used > the
    publisher's committed clock), the case that used to read as zeros in
    the numeric executors."""
    log = []
    eng = BoundedStaleEngine(
        n_clusters=3, rounds=6, max_staleness=2,
        peers=[[p for p in range(3) if p != c] for c in range(3)],
        leg_seconds=lambda c, k: 5.0 if c == 2 else 1.0,   # straggler c2
        send_seconds=lambda c, k: 0.1,
        commit=lambda ev: log.append(("commit", ev)),
        on_publish=lambda c, k, t: log.append(("publish", c, k)))
    eng.run()
    published = set()
    ahead_of_commit = 0
    for entry in log:
        if entry[0] == "publish":
            published.add((entry[1], entry[2]))
        else:
            ev = entry[1]
            for p, idx in ev.used:
                assert (p, idx) in published, (ev.cluster, ev.round, p, idx)
                if idx > ev.round_clock[p]:
                    ahead_of_commit += 1
    # the straggler regime really exercises published-but-uncommitted
    # versions (the regime the zeros bug hit) — otherwise this test
    # wouldn't prove anything
    assert ahead_of_commit > 0


def test_engine_rejoiner_pre_leave_publishes_are_retired():
    """A rejoiner is a fresh replica: its pre-leave publishes must never
    re-enter ``used`` (the numeric stores discarded them at the join
    bootstrap), even when a large staleness bound would still admit them."""
    log = []
    eng = BoundedStaleEngine(
        n_clusters=3, rounds=6, max_staleness=4,
        peers=[[p for p in range(3) if p != c] for c in range(3)],
        leg_seconds=lambda c, k: 1.0, send_seconds=lambda c, k: 0.1,
        commit=lambda ev: log.append(("commit", ev)),
        leaves=[(1, 1)], joins=[(2, 1)],
        on_join=lambda c, k, t: log.append(("join", c, k)))
    eng.run()
    rejoin_leg = None
    for entry in log:
        if entry[0] == "join":
            rejoin_leg = entry[2]
        elif rejoin_leg is not None:
            for p, idx in entry[1].used:
                if p == 1:
                    assert idx >= rejoin_leg, (entry[1].cluster,
                                               entry[1].round, idx)
    assert rejoin_leg is not None


# ---------------------------------------------------------------------------
# through the simulator: timelines, idle, numerics
# ---------------------------------------------------------------------------

def _async_sc(**kw):
    base = dict(n_clusters=4, rounds=6, h_steps=4, seed=3, t_step_s=0.02,
                sync="bounded_stale", max_staleness=2,
                link=LinkProfile(bytes_per_s=2e8, latency_s=0.01,
                                 jitter=0.1),
                faults=FaultSchedule((Straggler(1, 1, 4, 3.0),)))
    base.update(kw)
    return Scenario(**base)


def test_async_timeline_structure_and_makespan():
    tl = simulate(_async_sc())
    assert len(tl.events) == 4 * 6
    assert all(e.cluster is not None and e.round_clock is not None
               and e.t_start_s is not None for e in tl.events)
    # commits are recorded in event-time order, t_start monotone per cluster
    per = {}
    for e in tl.events:
        per.setdefault(e.cluster, []).append(e.t_start_s)
    for starts in per.values():
        assert starts == sorted(starts)
    # makespan semantics: total_time_s is the async makespan, strictly
    # below the barrier run's serial sum for the same faults
    tlb = simulate(Scenario(**{**_async_sc().__dict__,
                               "sync": "barrier", "faults":
                               _async_sc().faults}))
    assert tl.total_time_s < tlb.total_time_s
    # eager commits cut barrier idle (the headline async win)
    assert tl.total_barrier_idle_s < tlb.total_barrier_idle_s


def test_async_two_runs_bitwise_identical():
    a, b = simulate(_async_sc()), simulate(_async_sc())
    assert a.fingerprint() == b.fingerprint()
    assert a.structural_fingerprint() == b.structural_fingerprint()


def test_barrier_events_serialize_without_async_fields():
    tlb = simulate(Scenario(n_clusters=3, rounds=3, h_steps=4, seed=0,
                            link=LinkProfile(bytes_per_s=2e8)))
    for e in tlb.events:
        assert e.cluster is None and e.staleness is None
    # the None async fields are omitted from the serialized rows, so
    # pre-engine fingerprints are reproduced literally
    d = tlb.to_dict()
    assert all("cluster" not in row and "staleness" not in row
               and "round_clock" not in row and "t_start_s" not in row
               for row in d["events"])


def test_async_numeric_trains_and_matches_across_aggregations():
    mk = lambda: QuadraticSpec(n_clusters=4, d=8, h_steps=4,
                               seed=1).problem()
    for topo in ("star", "ring"):
        sc = _async_sc(topology=topo, faults=FaultSchedule(()),
                       compressor="diloco_x", compressor_kw={"rank": 4},
                       rank=4)
        tl = simulate(sc, numeric=mk())
        losses = tl.losses()
        assert losses[-1] < losses[0]
        assert all(e.param_hash for e in tl.events)
        tl2 = simulate(sc, numeric=mk())
        assert tl.fingerprint() == tl2.fingerprint()


def test_async_numeric_straggler_mixes_materialized_deltas():
    """Regression for the zeros-substitution bug: under a straggler, fast
    clusters commit against peers' published-but-UNcommitted deltas.  Those
    versions must be materialized at publish time — the executor now raises
    on a store miss instead of silently mixing a zero row with nonzero
    staleness weight — and the run stays bitwise reproducible."""
    mk = lambda: QuadraticSpec(n_clusters=3, d=8, h_steps=4,
                               seed=1).problem()
    sc = Scenario(n_clusters=3, rounds=8, h_steps=4, seed=3, t_step_s=0.02,
                  sync="bounded_stale", max_staleness=2, topology="star",
                  compressor="diloco_x", compressor_kw={"rank": 4}, rank=4,
                  link=LinkProfile(bytes_per_s=2e8, latency_s=0.01,
                                   jitter=0.1),
                  faults=FaultSchedule((Straggler(1, 1, 5, 3.0),)))
    # certify the scenario really exercises the blocked-publisher regime
    # by replaying the engine's (jax-free, numerics-identical) decision
    # sequence: some commit incorporates a version its publisher had not
    # committed yet — the case that used to read as zeros
    from repro.core.compression import make_compressor
    from repro.sim.simulator import async_modeled_times
    from repro.topology import async_mix_weights
    comp = make_compressor(sc.compressor, **sc.compressor_kw)
    wire = int(comp.wire_bytes(sc.shapes(), rank=sc.rank))
    topo = sc.topo()
    W = async_mix_weights(topo)
    peers = [tuple(p for p in range(3) if p != c and W[c, p] > 0.0)
             for c in range(3)]
    leg_s, send_s, _ = async_modeled_times(sc, wire, topo)
    commits = []
    BoundedStaleEngine(
        n_clusters=3, rounds=sc.rounds, max_staleness=sc.max_staleness,
        peers=peers, leg_seconds=leg_s, send_seconds=send_s,
        commit=commits.append).run()
    ahead = sum(1 for ev in commits for p, idx in ev.used
                if idx > ev.round_clock[p])
    assert ahead > 0

    tl = simulate(sc, numeric=mk())
    assert tl.losses()[-1] < tl.losses()[0]
    assert all(e.param_hash for e in tl.events)
    tl2 = simulate(sc, numeric=mk())
    assert tl.fingerprint() == tl2.fingerprint()


def test_async_churn_rejoin_consensus_bootstrap():
    sc = _async_sc(faults=FaultSchedule((Leave(0, 2), Join(0, 4))),
                   compressor="diloco_x", compressor_kw={"rank": 4},
                   rank=4)
    tl = simulate(sc, numeric=QuadraticSpec(n_clusters=4, d=8, h_steps=4,
                                            seed=1).problem())
    c0 = [e.round for e in tl.events if e.cluster == 0]
    assert 2 not in c0 and 3 not in c0
    rejoined = [e for e in tl.events if e.rejoined == (0,)]
    assert len(rejoined) == 1
    assert tl.losses()[-1] < tl.losses()[0]


def test_trimmed_mean_defends_against_byzantine_member():
    mk = lambda: QuadraticSpec(n_clusters=5, d=8, h_steps=4,
                               seed=3).problem()
    kw = dict(n_clusters=5, rounds=10, h_steps=4, seed=11, t_step_s=0.02,
              sync="bounded_stale", max_staleness=1,
              compressor="diloco_x", compressor_kw={"rank": 4}, rank=4,
              link=LinkProfile(bytes_per_s=2e8, latency_s=0.01,
                               jitter=0.05))
    byz = FaultSchedule((Byzantine(cluster=2, start_round=2, end_round=8,
                                   scale=-8.0),))
    tail = lambda tl: float(np.mean(tl.losses()[-3:]))
    honest = tail(simulate(Scenario(**kw), numeric=mk()))
    attacked = tail(simulate(Scenario(**kw, faults=byz), numeric=mk()))
    defended = tail(simulate(Scenario(**kw, faults=byz,
                                      aggregation="trimmed_mean",
                                      trim_k=1), numeric=mk()))
    # the scaled-delta attack visibly damages plain mean aggregation;
    # coordinate-wise trimming restores near-honest convergence
    assert attacked > 5 * honest
    assert abs(defended - honest) < 0.2 * abs(attacked - honest)


def test_scenario_validation_gates_async_knobs():
    with pytest.raises(ValueError):
        Scenario(n_clusters=3, rounds=3, sync="nope")
    with pytest.raises(ValueError):
        Scenario(n_clusters=3, rounds=3, sync="bounded_stale",
                 max_staleness=-1)
    with pytest.raises(ValueError):
        Scenario(n_clusters=3, rounds=3, aggregation="trimmed_mean")
    with pytest.raises(ValueError):  # barrier mode cannot take Byzantine
        simulate(Scenario(
            n_clusters=3, rounds=3,
            faults=FaultSchedule((Byzantine(1, 0, 2),))))
