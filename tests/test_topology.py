"""Topology & gossip-averaging subsystem (repro.topology): graph shapes,
mixing-matrix invariants (doubly stochastic, mask-respecting, spectral-gap
contraction), the bitwise row/stacked mix agreement, and gossip round
semantics (mean trajectory == gather, replicas legitimately diverge)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import membership
from repro.topology import (GOSSIP_KINDS, KINDS, MixingMatrix,
                            gossip_round_comm, make_topology,
                            round_wire_total)


# ---------------------------------------------------------------------------
# graphs
# ---------------------------------------------------------------------------

def test_graph_shapes_and_degrees():
    r = make_topology("ring", 6)
    assert all(r.degree(c) == 2 for c in range(6))
    t = make_topology("torus", 6)          # 2x3 grid
    assert all(t.degree(c) in (3, 4) for c in range(6))
    s = make_topology("star", 5)
    assert s.degree(0) == 4 and all(s.degree(c) == 1 for c in range(1, 5))
    f = make_topology("full", 5)
    assert all(f.degree(c) == 4 for c in range(5))
    g = make_topology("random", 8, degree=3)
    assert all(g.degree(c) == 3 for c in range(8))


@settings(max_examples=12, deadline=None)
@given(kind=st.sampled_from(list(KINDS)), n=st.integers(3, 12),
       seed=st.integers(0, 20))
def test_every_topology_connected(kind, n, seed):
    topo = make_topology(kind, n, seed=seed)
    assert topo.is_connected()
    # neighbors are symmetric and self-free
    for c in range(n):
        assert c not in topo.neighbors(c)
        for j in topo.neighbors(c):
            assert c in topo.neighbors(j)


def test_random_regular_deterministic_in_seed():
    a = make_topology("random", 10, degree=4, seed=7)
    b = make_topology("random", 10, degree=4, seed=7)
    c = make_topology("random", 10, degree=4, seed=8)
    assert a.edges == b.edges
    assert a.edges != c.edges


# ---------------------------------------------------------------------------
# mixing-matrix invariants (satellite: property tests via the shim)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(list(KINDS)), n=st.integers(3, 10),
       seed=st.integers(0, 10))
def test_mixing_matrix_doubly_stochastic(kind, n, seed):
    mm = MixingMatrix.metropolis(make_topology(kind, n, seed=seed))
    W = mm.W.astype(np.float64)
    assert mm.is_doubly_stochastic()
    np.testing.assert_allclose(W, W.T, atol=1e-6)      # symmetric too


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(list(KINDS)), n=st.integers(4, 10),
       dead=st.integers(0, 3), seed=st.integers(0, 10))
def test_masked_matrix_respects_alive_mask(kind, n, dead, seed):
    """Membership-masked row renormalization: dead rows become identity,
    alive rows place zero weight on dead columns, and the alive block
    stays doubly stochastic."""
    topo = make_topology(kind, n, seed=seed)
    rng = np.random.RandomState(seed)
    alive = np.ones(n, bool)
    alive[rng.choice(n, size=dead, replace=False)] = False
    W = np.asarray(membership.masked_mixing_matrix(
        MixingMatrix.metropolis(topo).W, alive), np.float64)
    for c in np.flatnonzero(~alive):
        np.testing.assert_allclose(W[c], np.eye(n)[c], atol=1e-6)
        np.testing.assert_allclose(W[np.flatnonzero(alive), c], 0.0,
                                   atol=1e-6)
    assert MixingMatrix(W.astype(np.float32)).is_doubly_stochastic()


@settings(max_examples=10, deadline=None)
@given(kind=st.sampled_from(list(KINDS)), n=st.integers(4, 10),
       seed=st.integers(0, 10))
def test_repeated_mixing_contracts_at_spectral_gap_rate(kind, n, seed):
    """x_{t+1} = W x_t must contract toward the mean at least as fast as
    (1-gap)^t — exact for symmetric doubly-stochastic W, so the spectral
    gap is a *certificate*, not a heuristic."""
    mm = MixingMatrix.metropolis(make_topology(kind, n, seed=seed))
    gap = mm.spectral_gap()
    W = mm.W.astype(np.float64)
    rng = np.random.RandomState(seed)
    x = rng.randn(n)
    err0 = np.linalg.norm(x - x.mean())
    for t in range(1, 8):
        x = W @ x
        err = np.linalg.norm(x - x.mean())
        assert err <= (1 - gap) ** t * err0 + 1e-9, (kind, t)
        # and the mean itself is invariant (doubly stochastic)
        np.testing.assert_allclose(x.mean(), (W @ x).mean(), atol=1e-9)


def test_gather_kinds_average_in_one_mix():
    for kind in ("star", "full"):
        mm = MixingMatrix.metropolis(make_topology(kind, 6))
        x = np.arange(6.0)
        np.testing.assert_allclose(mm.W.astype(np.float64) @ x,
                                   np.full(6, x.mean()), atol=1e-5)
        assert mm.spectral_gap() > 0.999


# ---------------------------------------------------------------------------
# wire accounting
# ---------------------------------------------------------------------------

def test_gossip_wire_strictly_below_gather():
    wire = 1000
    for kind in GOSSIP_KINDS:
        topo = make_topology(kind, 8)
        gc = gossip_round_comm(topo, np.ones(8, bool), wire,
                               np.full(8, 1e6), 0.0)
        assert gc.wire_bytes_total == 2 * len(topo.edges) * wire
        assert gc.wire_bytes_total < round_wire_total("gather", 8, wire)


def test_gossip_comm_time_tracks_degraded_link():
    topo = make_topology("ring", 4)
    bws = np.array([1e6, 1e5, 1e6, 1e6])   # cluster 1 degraded 10x
    gc = gossip_round_comm(topo, np.ones(4, bool), 50_000, bws, 0.0)
    assert gc.bottleneck_cluster == 1
    np.testing.assert_allclose(gc.t_comm_s, 2 * 50_000 / 1e5, rtol=1e-12)
    # masking a cluster removes its sends from the total
    alive = np.array([1, 0, 1, 1], bool)
    gc2 = gossip_round_comm(topo, alive, 50_000, bws, 0.0)
    assert gc2.sends == {0: 1, 2: 1, 3: 2}


# ---------------------------------------------------------------------------
# gossip rounds through core/diloco.py (jax)
# ---------------------------------------------------------------------------

def _const_inner_stacked(step_stacked):
    import jax

    def inner_fn(params, inner_opt, t):
        new = jax.tree.map(lambda p, s: p - s, params, step_stacked)
        return new, inner_opt, np.zeros(1)
    return inner_fn


def test_gossip_mean_trajectory_equals_gather():
    """With a doubly-stochastic mix and the (linear) Nesterov outer step,
    the cluster-MEAN of the gossip trajectory equals the gather trajectory
    exactly; the replicas themselves legitimately diverge."""
    import jax.numpy as jnp

    from repro.core import diloco
    from repro.core.compression import Identity
    from repro.topology import mixing as mx

    n = 4
    topo = make_topology("ring", n)
    steps = {"w": jnp.asarray(np.linspace(0.1, 0.4, n)[:, None]
                              * np.ones((n, 3)), jnp.float32)}
    params0 = {"w": jnp.zeros((3,))}
    comp = Identity()
    cfg = diloco.RoundConfig(outer_lr=0.7, outer_momentum=0.5,
                             compress=False, error_feedback=False)

    # gather reference: same constant displacements, global mean
    g_state = diloco.init_state(params0, None, n, comp)
    gather_inner = lambda p, o, t: ({"w": p["w"][None] - steps["w"]}, o,
                                    np.zeros(1))
    mean0 = lambda tree: {"w": tree["w"].mean(0)}

    # gossip: stacked params, ring mix
    s_state = diloco.init_state(diloco.stack_replicas(params0, n), None, n,
                                comp, stacked_params=True)
    op = mx.mixing_op(topo, np.ones(n, bool))
    assert op.returns_stacked

    for _ in range(5):
        g_state, _ = diloco.diloco_round(g_state, gather_inner, comp,
                                         mean0, cfg)
        s_state, _ = diloco.diloco_round(
            s_state, _const_inner_stacked(steps), comp, op, cfg)
        np.testing.assert_allclose(
            np.asarray(s_state.params["w"]).mean(0),
            np.asarray(g_state.params["w"]), rtol=0, atol=1e-6)
    # replicas saw different neighborhoods -> genuinely different rows
    rows = np.asarray(s_state.params["w"])
    assert np.abs(rows - rows.mean(0)).max() > 1e-4


def test_mix_row_matches_mix_stacked_bitwise():
    import jax.numpy as jnp

    from repro.core.diloco import take_row
    from repro.topology.mixing import mix_row, mix_stacked

    topo = make_topology("random", 6, degree=3, seed=1)
    W = jnp.asarray(MixingMatrix.metropolis(topo).W)
    rng = np.random.RandomState(0)
    tree = {"a": jnp.asarray(rng.randn(6, 5, 5), jnp.float32),
            "b": jnp.asarray(rng.randn(6, 7), jnp.float32)}
    full = mix_stacked(W, tree)
    parts = [take_row(tree, j) for j in range(6)]
    for c in range(6):
        row = mix_row(W[c], parts)
        for k in tree:
            assert np.array_equal(np.asarray(row[k]),
                                  np.asarray(take_row(full, c)[k])), (c, k)


def test_simulator_gossip_numeric_converges_and_is_deterministic():
    from repro.sim import LinkProfile, QuadraticSpec, Scenario, simulate

    spec = QuadraticSpec(n_clusters=4, d=8, n_mats=2, h_steps=4, seed=0)
    sc = Scenario(n_clusters=4, rounds=6, h_steps=4, t_step_s=0.05,
                  link=LinkProfile(bytes_per_s=200_000),
                  compressor="diloco_x",
                  compressor_kw={"rank": 4, "min_dim_for_lowrank": 8},
                  rank=4, n_params=1e5, topology="ring", seed=0)
    a = simulate(sc, numeric=spec.problem())
    b = simulate(sc, numeric=spec.problem())
    assert a.fingerprint() == b.fingerprint()
    losses = a.losses()
    assert losses[-1] < losses[0]
    assert all(e.disagreement is not None for e in a.events)
    # gossip ships strictly fewer bytes than the gather run of the same
    # scenario, every round
    import dataclasses
    tl_star = simulate(dataclasses.replace(sc, topology="star"))
    for eg, es in zip(a.events, tl_star.events):
        assert eg.wire_bytes_total < es.wire_bytes_total


# ---------------------------------------------------------------------------
# push-sum on directed/asymmetric uplinks (satellite: property tests)
# ---------------------------------------------------------------------------

def _digraph_for(shape, n):
    from repro.topology import as_digraph, directed_ring
    if shape == "directed_ring":
        return directed_ring(n)
    return as_digraph(make_topology(shape, n))


@settings(max_examples=15, deadline=None)
@given(shape=st.sampled_from(["directed_ring", "star", "ring", "full"]),
       n=st.integers(3, 8))
def test_push_sum_weights_column_stochastic(shape, n):
    from repro.topology import push_sum_weights
    W = push_sum_weights(_digraph_for(shape, n))
    assert W.shape == (n, n)
    assert np.all(W >= 0.0)
    # column stochasticity is EXACT (1/(d+1) splits), not approximate
    np.testing.assert_array_equal(W.sum(axis=0), np.ones(n))


def test_push_sum_handles_row_substochastic_digraph():
    """An irregular digraph (heterogeneous out-degrees) has NO doubly
    stochastic weights; push-sum only needs the columns to sum to 1."""
    from repro.topology import Digraph, push_sum_average, push_sum_weights
    g = Digraph(4, ((0, 1), (0, 2), (0, 3), (1, 0), (2, 0), (3, 0),
                    (1, 2), (2, 3), (3, 1)))
    W = push_sum_weights(g)
    np.testing.assert_array_equal(W.sum(axis=0), np.ones(4))
    assert not np.allclose(W.sum(axis=1), 1.0)      # rows are NOT stochastic
    x = np.arange(4, dtype=np.float64).reshape(4, 1)
    est = push_sum_average(g, x, iters=300)
    np.testing.assert_allclose(est, np.full((4, 1), 1.5), rtol=1e-8)


@settings(max_examples=15, deadline=None)
@given(shape=st.sampled_from(["directed_ring", "star", "ring", "full"]),
       n=st.integers(3, 8), seed=st.integers(0, 10))
def test_push_sum_conserves_mass_every_round(shape, n, seed):
    from repro.topology import push_sum_round, push_sum_weights
    rng = np.random.default_rng(seed)
    W = push_sum_weights(_digraph_for(shape, n))
    x = rng.normal(size=(n, 3))
    phi = np.ones(n)
    for _ in range(20):
        x2, phi2 = push_sum_round(W, x, phi)
        # column stochasticity conserves total mass and total weight
        np.testing.assert_allclose(x2.sum(axis=0), x.sum(axis=0),
                                   rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(phi2.sum(), phi.sum(),
                                   rtol=1e-12, atol=1e-12)
        x, phi = x2, phi2


@settings(max_examples=10, deadline=None)
@given(shape=st.sampled_from(["directed_ring", "star", "ring"]),
       n=st.integers(3, 8), seed=st.integers(0, 10))
def test_push_sum_debiased_estimates_converge_to_mean(shape, n, seed):
    from repro.topology import push_sum_average
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 2))
    est = push_sum_average(_digraph_for(shape, n), x, iters=300)
    target = np.broadcast_to(x.mean(axis=0), est.shape)
    # every node's x/phi ratio reaches the TRUE average — including on the
    # directed ring, where no doubly-stochastic matrix exists at all
    np.testing.assert_allclose(est, target, rtol=1e-8, atol=1e-8)


def test_push_sum_requires_strong_connectivity_flag():
    from repro.topology import Digraph, directed_ring
    assert directed_ring(5).is_strongly_connected()
    # a one-way chain cannot push mass back: not strongly connected
    chain = Digraph(4, ((0, 1), (1, 2), (2, 3)))
    assert not chain.is_strongly_connected()


def test_async_mix_weights_support_matches_topology():
    from repro.topology import async_mix_weights
    ring = make_topology("ring", 6)
    W = async_mix_weights(ring)
    for c in range(6):
        support = {p for p in range(6) if W[c, p] > 0 and p != c}
        assert support == set(ring.neighbors(c))
        # each peer contributes its own out-share 1/(deg+1)
        for p in support:
            assert W[c, p] == 1.0 / (ring.degree(p) + 1.0)
    star = async_mix_weights(make_topology("star", 5))
    np.testing.assert_array_equal(star, np.full((5, 5), 1.0 / 5))


# ---------------------------------------------------------------------------
# bounded staleness, certified from the Timeline itself
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(topology=st.sampled_from(["star", "ring"]), bound=st.integers(0, 3),
       seed=st.integers(0, 50), jitter=st.floats(0.0, 0.3))
def test_timeline_staleness_never_exceeds_bound(topology, bound, seed,
                                                jitter):
    from repro.sim import FaultSchedule, LinkProfile, Scenario, Straggler
    from repro.sim import simulate
    sc = Scenario(n_clusters=4, rounds=6, h_steps=4, seed=seed,
                  t_step_s=0.02, topology=topology,
                  sync="bounded_stale", max_staleness=bound,
                  link=LinkProfile(bytes_per_s=2e8, latency_s=0.01,
                                   jitter=jitter),
                  faults=FaultSchedule((Straggler(1, 1, 4, 3.0),)))
    tl = simulate(sc)
    for e in tl.events:
        assert e.cluster is not None and e.staleness is not None
        for p, s in e.staleness:
            assert 0 <= s <= bound, (e.round, e.cluster, p, s)
        # the committing cluster's own delta is always fresh
        assert dict(e.staleness)[e.cluster] == 0
    # every cluster commits every local leg exactly once
    per = {}
    for e in tl.events:
        per.setdefault(e.cluster, []).append(e.round)
    assert all(v == list(range(6)) for v in per.values())
