"""Cluster dropout/rejoin tolerance (core.membership)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import membership as mb


def test_masked_mean_matches_subset():
    x = {"w": jnp.arange(12.0).reshape(4, 3)}
    alive = jnp.array([1.0, 0.0, 1.0, 1.0])
    out = mb.masked_cluster_mean(x, alive)
    expect = (x["w"][0] + x["w"][2] + x["w"][3]) / 3
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(expect))


def test_masked_mean_all_dead_is_zero():
    x = {"w": jnp.ones((4, 3))}
    out = mb.masked_cluster_mean(x, jnp.zeros((4,)))
    assert float(jnp.abs(out["w"]).max()) < 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50), c=st.integers(2, 8))
def test_masked_mean_full_equals_plain_mean(seed, c):
    x = {"w": jax.random.normal(jax.random.PRNGKey(seed), (c, 5))}
    out = mb.masked_cluster_mean(x, jnp.ones((c,)))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(x["w"].mean(0)), rtol=1e-6)


def test_reset_rejoining_zeroes_only_rejoined():
    x = {"e": jnp.ones((3, 4))}
    out = mb.reset_rejoining(x, jnp.array([0, 1, 0]))
    np.testing.assert_array_equal(np.asarray(out["e"][1]), np.zeros(4))
    np.testing.assert_array_equal(np.asarray(out["e"][0]), np.ones(4))


def test_effective_batch_scale():
    assert abs(float(mb.effective_batch_scale(jnp.ones(4), 4)) - 1.0) < 1e-6
    assert abs(float(mb.effective_batch_scale(
        jnp.array([1.0, 0, 0, 0]), 4)) - 0.5) < 1e-6


@pytest.mark.slow
def test_dropout_training_still_converges():
    """DiLoCoX keeps learning when a cluster drops for some rounds: run the
    simulator with a masked cluster_mean.  slow: a real (reduced) LM trains
    for 8 rounds; tier-1 covers the same churn semantics cheaply via
    tests/test_sim.py numeric scenarios."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.core import diloco
    from repro.core.compression import make_compressor
    from repro.train import trainer as T

    cfg = dataclasses.replace(get_config("opt-1.3b").reduced(),
                              vocab_size=64)
    tcfg = T.TrainConfig(n_clusters=2, local_batch=8, seq_len=16,
                         inner_lr=3e-3, h_steps=4,
                         outer_lr=0.5, outer_momentum=0.7)
    from repro.data.synthetic import SyntheticLM, with_frontend
    from repro.models import model as M
    from repro.optim import adamw
    import jax.numpy as jnp

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    comp = make_compressor("diloco_x", rank=16, bits=4)
    inner0 = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (2,) + x.shape).copy(),
        adamw.init(params))
    state = diloco.init_state(params, inner0, 2, comp)
    rcfg = diloco.RoundConfig(outer_lr=0.5, outer_momentum=0.7)
    data = SyntheticLM(cfg.vocab_size, 16, 8, seed=0)
    inner_fn = T.make_inner_fn(cfg, tcfg, data.table)
    eval_b = SyntheticLM(cfg.vocab_size, 16, 16, seed=0,
                         data_shard=9999).next_batch()

    @jax.jit
    def round_fn(state, alive):
        cm = lambda t: mb.masked_cluster_mean(t, alive)
        return diloco.diloco_round(state, inner_fn, comp, cm, rcfg,
                                   jnp.asarray(16))

    eval_jit = jax.jit(lambda p: M.loss_fn(p, cfg, eval_b)[0])
    losses = []
    for r in range(8):
        alive = jnp.array([1.0, 0.0 if r in (3, 4) else 1.0])
        state, _ = round_fn(state, alive)
        losses.append(float(eval_jit(state.params)))
    assert losses[-1] < losses[0] - 0.4, losses
    assert all(np.isfinite(losses))
