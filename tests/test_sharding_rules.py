"""Property tests on the GSPMD sharding rules (pure logic — specs only,
no device allocation)."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.launch import steps
from repro.parallel import sharding as sh


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by the rule functions."""
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"clusters": 2, "data": 8, "model": 16})


@settings(max_examples=50, deadline=None)
@given(din=st.integers(1, 4096), dout=st.integers(1, 65536),
       n_scan=st.integers(0, 2))
def test_spec_dims_always_divide(din, dout, n_scan):
    shape = tuple([3] * n_scan + [din, dout])
    spec = sh.spec_for_param(["w"], shape, MESH, cluster_stacked=False,
                             n_scan_dims=n_scan)
    for dim_size, entry in zip(shape, tuple(spec)):
        if entry is not None:
            assert dim_size % MESH.shape[entry] == 0, (shape, spec)


@settings(max_examples=30, deadline=None)
@given(din=st.integers(16, 4096), dout=st.integers(16, 65536))
def test_spec_axes_never_repeat(din, dout):
    spec = sh.spec_for_param(["w"], (2, 4, din, dout), MESH,
                             cluster_stacked=True, n_scan_dims=2)
    used = [e for e in tuple(spec) if e is not None]
    assert len(used) == len(set(used)), spec


def test_expert_rule_expert_parallel():
    spec = sh.spec_for_param(["segments", "moe", "experts", "w_gate"],
                             (2, 59, 160, 5120, 1536), MESH,
                             cluster_stacked=True, n_scan_dims=2)
    assert tuple(spec) == ("clusters", None, "model", "data", None)


def test_fat_dim_gets_model_axis():
    # (d, ff): ff is fat -> model; (ff, d): din fat -> model
    s1 = sh.spec_for_param(["w"], (4096, 12800), MESH,
                           cluster_stacked=False, n_scan_dims=0)
    assert tuple(s1) == ("data", "model")
    s2 = sh.spec_for_param(["w"], (12800, 4096), MESH,
                           cluster_stacked=False, n_scan_dims=0)
    assert tuple(s2) == ("model", "data")


def test_params_specs_cover_all_archs():
    """Every assigned arch's full param tree gets a legal sharding spec
    (uses real jax Mesh on 1 device in abstract form via shape dict)."""
    from repro.configs.base import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        p = steps.params_specs(cfg, n_clusters=2)

        def check(path, leaf):
            names = [str(getattr(q, "key", getattr(q, "name", "")))
                     for q in path]
            n_scan = 1 + (1 if any("segments" in n for n in names) else 0)
            n_scan = min(n_scan, max(0, len(leaf.shape) - 1))
            spec = sh.spec_for_param(names, leaf.shape, MESH,
                                     cluster_stacked=True,
                                     n_scan_dims=n_scan)
            for dim_size, entry in zip(leaf.shape, tuple(spec)):
                if entry is not None:
                    assert dim_size % MESH.shape[entry] == 0, (
                        arch, names, leaf.shape, spec)

        jax.tree_util.tree_map_with_path(check, p)


def test_input_specs_shapes():
    from repro.configs.base import SHAPES
    cfg = get_config("granite-3-8b")
    b = steps.input_specs(cfg, SHAPES["train_4k"], n_clusters=2)
    assert b["tokens"].shape == (2, 128, 4096)
    b = steps.input_specs(cfg, SHAPES["prefill_32k"])
    assert b["tokens"].shape == (32, 32768)
    b = steps.input_specs(cfg, SHAPES["decode_32k"])
    assert b["tokens"].shape == (128, 1)
    vlm = get_config("qwen2-vl-7b")
    b = steps.input_specs(vlm, SHAPES["train_4k"], n_clusters=2)
    assert b["frontend"].shape == (2, 128, 256, 3584)


def test_decode_state_specs_no_alloc():
    from repro.configs.base import SHAPES
    for arch in ("gemma3-1b", "zamba2-1.2b", "deepseek-v2-236b"):
        cfg = get_config(arch)
        s = steps.decode_state_specs(cfg, SHAPES["decode_32k"])
        # structure exists and leaves are abstract
        assert all(hasattr(x, "shape") for x in jax.tree.leaves(s))
