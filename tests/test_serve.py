"""Serve subsystem: page-manager partition invariants (property test),
scheduler state machine / backpressure / determinism (stubbed step, no
jax), and the paged ≡ dense greedy-token equivalence gates."""
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.pages import PageManager
from repro.serve.scheduler import (DECODE, DONE, PREFILL, WAITING, Request,
                                   Scheduler)


# ---------------------------------------------------------------------------
# PageManager: free-list + in-use partitions the pool under any op sequence
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_pages=st.integers(4, 40),
       ps=st.integers(1, 8), max_seqs=st.integers(1, 6))
def test_page_manager_partition_invariant(seed, n_pages, ps, max_seqs):
    rng = random.Random(seed)
    max_pp = 6
    pm = PageManager(n_pages, ps, max_seqs, max_pp)
    live = {}          # slot -> [fed, total]
    for _ in range(300):
        op = rng.random()
        free_slots = [i for i in range(max_seqs) if i not in live]
        if op < 0.45 and free_slots:
            total = rng.randint(1, max_pp * ps)
            if pm.can_admit(total):
                slot = rng.choice(free_slots)
                pm.admit(slot, total)
                live[slot] = [0, total]
        elif op < 0.9 and live:
            slot = rng.choice(sorted(live))
            fed, total = live[slot]
            if fed < total:
                pm.ensure(slot, fed)
                live[slot][0] += 1
            else:
                pm.release(slot)
                del live[slot]
        elif live:          # early release (EOS before the length cap)
            slot = rng.choice(sorted(live))
            pm.release(slot)
            del live[slot]
        pm.check_partition()
    for slot in list(live):
        pm.release(slot)
    pm.check_partition()
    assert pm.used_pages == 0
    assert pm.free_pages == pm.n_pages
    assert pm.reserved_pages == 0


def test_page_manager_reservation_guarantees_growth():
    """Admission reserves the worst case, so ensure() can never run dry
    mid-decode even when the pool is exactly full."""
    pm = PageManager(n_pages=4, page_size=2, max_seqs=2,
                     max_pages_per_seq=2)
    pm.admit(0, 4)                       # reserves 2 pages
    pm.admit(1, 4)                       # reserves the other 2
    assert not pm.can_admit(1)           # pool fully reserved
    for pos in range(4):
        pm.ensure(0, pos)
        pm.ensure(1, pos)
    pm.check_partition()
    assert pm.free_pages == 0
    pm.release(0)
    assert pm.can_admit(4)


def test_page_manager_rejects_oversized_and_double_admit():
    pm = PageManager(n_pages=8, page_size=4, max_seqs=2,
                     max_pages_per_seq=2)
    assert not pm.can_admit(9)           # > max_pages_per_seq * ps
    pm.admit(0, 8)
    with pytest.raises(ValueError):
        pm.admit(0, 4)


# ---------------------------------------------------------------------------
# Scheduler: state machine on a stubbed device step (no jax)
# ---------------------------------------------------------------------------

def _drive(sched, next_token_fn, max_steps=2000):
    step = 0
    while sched.has_work():
        assert step < max_steps, "scheduler did not drain"
        sched.admit_ready(step)
        plan = sched.plan_step()
        if plan is not None:
            tokens, lengths, active = plan
            sched.commit(next_token_fn(tokens, lengths, active, step), step)
            sched.pages.check_partition()
        step += 1
    return step


def _mk(pages_kw=None, **kw):
    pages_kw = pages_kw or dict(n_pages=12, page_size=4, max_seqs=3,
                                max_pages_per_seq=4)
    pm = PageManager(**pages_kw)
    return Scheduler(pm, max_seqs=pages_kw["max_seqs"], **kw)


def _const(tok):
    return lambda tokens, lengths, active, step: np.full(len(tokens), tok)


def test_scheduler_runs_all_to_length_cap():
    sched = _mk()
    for rid in range(5):
        sched.submit(Request(rid, prompt=[1, 2, 3], max_new=4,
                             arrival=rid))
    _drive(sched, _const(7))
    assert len(sched.done) == 5
    for r in sched.done:
        assert r.state == DONE and r.finish_reason == "length"
        assert r.generated == [7, 7, 7, 7]
        assert r.first_token_step >= r.admit_step + len(r.prompt) - 1


def test_scheduler_eos_recycles_slot():
    sched = _mk(eos_id=9)

    def fn(tokens, lengths, active, step):
        # request 0 hits EOS on its second generated token
        out = np.full(len(tokens), 5)
        if step == 4:
            out[:] = 9
        return out

    sched.submit(Request(0, prompt=[1, 2, 3], max_new=10, arrival=0))
    sched.submit(Request(1, prompt=[1, 2], max_new=3, arrival=0))
    sched.submit(Request(2, prompt=[1], max_new=2, arrival=0))
    _drive(sched, fn)
    eos_done = [r for r in sched.done if r.finish_reason == "eos"]
    assert eos_done, "no request finished on EOS"
    for r in eos_done:
        assert r.generated[-1] == 9
        assert 9 not in r.generated[:-1]
    # all slots were recycled and the pool fully drained
    assert sched.pages.used_pages == 0


def test_scheduler_backpressure_defers_never_ooms():
    # pool of 2 pages, each request needs 2: strictly one at a time
    sched = _mk(pages_kw=dict(n_pages=2, page_size=2, max_seqs=3,
                              max_pages_per_seq=2))
    for rid in range(4):
        sched.submit(Request(rid, prompt=[1, 2], max_new=2, arrival=0))
    _drive(sched, _const(3))
    assert len(sched.done) == 4
    assert sched.deferred > 0                   # backpressure happened
    assert len(sched.admissions) == 4
    # serialized: at most one admission per step window of 4 tokens
    steps = [t for t, _, _ in sched.admissions]
    assert steps == sorted(steps)


def test_scheduler_static_policy_admits_in_waves():
    def run(policy):
        sched = _mk(policy=policy)
        for rid in range(6):
            sched.submit(Request(rid, prompt=[1, 2], max_new=2 + 4 * (rid % 2),
                                 arrival=0))
        n = _drive(sched, _const(3))
        return sched, n

    stat, n_stat = run("static")
    cont, n_cont = run("continuous")
    assert len(stat.done) == len(cont.done) == 6
    # static admits full waves: admission steps take <= 2 distinct values
    assert len({t for t, _, _ in stat.admissions}) == 2
    assert n_cont < n_stat                      # continuous drains faster


def test_scheduler_admission_fingerprint_deterministic():
    def run():
        sched = _mk()
        for rid in range(5):
            sched.submit(Request(rid, prompt=[1] * (2 + rid % 3),
                                 max_new=3, arrival=rid // 2))
        _drive(sched, _const(3))
        return sched.admission_fingerprint()

    assert run() == run()


# ---------------------------------------------------------------------------
# paged ≡ dense greedy equivalence (ref backend)
# ---------------------------------------------------------------------------

def _dense_greedy(cfg, params, prompts, gen_len, s_max):
    """Legacy dense loop (steps.make_serve_step), equal-length prompts."""
    import jax
    import jax.numpy as jnp

    from repro.launch import steps
    from repro.models import model as M

    B, P = prompts.shape
    state = M.init_decode_state(cfg, B, s_max)
    serve_step = jax.jit(steps.make_serve_step(cfg))
    for t in range(P):
        nxt, state = serve_step(params, state, jnp.asarray(prompts[:, t:t + 1]))
    outs = [np.asarray(nxt)]
    for _ in range(gen_len - 1):
        nxt, state = serve_step(params, state, nxt)
        outs.append(np.asarray(nxt))
    return np.concatenate(outs, axis=1)


def test_paged_equals_dense_greedy_lockstep():
    """Same checkpoint, same prompts, greedy tokens identical: with
    max_pages*page_size == s_max and all slots in lockstep the ref paged
    path is bitwise-identical to the dense cache (serve/README.md)."""
    import jax

    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-3-8b").reduced()    # plain GQA, no window
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    P, G, ps, maxP = 5, 7, 4, 3                   # maxP*ps == s_max == 12
    B = 2
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size))
    dense = _dense_greedy(cfg, params, prompts, G, s_max=maxP * ps)

    eng = ServeEngine(params, cfg, max_seqs=B, page_size=ps,
                      n_pages=B * maxP, max_pages_per_seq=maxP,
                      eos_id=None)
    for b in range(B):
        eng.submit(prompts[b].tolist(), G, arrival=0)
    eng.run()
    done = sorted(eng.sched.done, key=lambda r: r.rid)
    for b in range(B):
        assert done[b].generated == dense[b].tolist(), \
            f"row {b}: paged {done[b].generated} != dense {dense[b].tolist()}"


def test_paged_continuous_staggered_matches_per_seq_dense():
    """Staggered arrivals + unequal prompt lengths: each request's greedy
    tokens match a dedicated B=1 dense decode of the same prompt (the
    paged engine tracks true per-sequence positions)."""
    import jax

    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-3-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ps, maxP, G = 4, 3, 5
    rng = np.random.default_rng(3)
    reqs = [(0, rng.integers(0, cfg.vocab_size, 3).tolist()),
            (2, rng.integers(0, cfg.vocab_size, 6).tolist()),
            (4, rng.integers(0, cfg.vocab_size, 4).tolist())]

    eng = ServeEngine(params, cfg, max_seqs=2, page_size=ps,
                      n_pages=3 * maxP, max_pages_per_seq=maxP, eos_id=None)
    for arrival, prompt in reqs:
        eng.submit(prompt, G, arrival=arrival)
    eng.run()
    done = sorted(eng.sched.done, key=lambda r: r.rid)
    for (arrival, prompt), req in zip(reqs, done):
        dense = _dense_greedy(cfg, params,
                              np.asarray(prompt)[None, :], G,
                              s_max=maxP * ps)
        assert req.generated == dense[0].tolist(), \
            f"rid {req.rid}: {req.generated} != {dense[0].tolist()}"


def test_paged_engine_eos_and_backpressure_integration():
    """Tiny pool + EOS enabled: requests defer instead of OOMing, every
    request completes, no page leaks."""
    import jax

    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-3-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, max_seqs=3, page_size=4, n_pages=4,
                      max_pages_per_seq=2)       # pool < 3 full requests
    rng = np.random.default_rng(0)
    for r in range(5):
        eng.submit(rng.integers(0, cfg.vocab_size, 4).tolist(), 4,
                   arrival=0)
    st = eng.run()
    assert st["requests_done"] == 5
    assert eng.pages.used_pages == 0
    eng.pages.check_partition()
    for r in eng.sched.done:
        if r.finish_reason == "eos":
            assert r.generated[-1] == cfg.eos_id
            assert cfg.eos_id not in r.generated[:-1]
