"""Deterministic stand-in for ``hypothesis`` so tier-1 collects and runs
without the package installed.

When the real ``hypothesis`` is importable we simply re-export it and this
module is inert.  Otherwise ``tests/conftest.py`` installs this module into
``sys.modules["hypothesis"]`` before test collection, and the subset of the
API the suite uses (``given`` with keyword strategies, ``settings``,
``strategies.integers/floats/sampled_from``, ``assume``) is emulated with
*fixed-seed* example generation:

 - every strategy draws from a ``random.Random`` seeded by the test's
   qualified name (stable across runs and machines — no flakes);
 - the first two examples per strategy are the boundary values (lo/hi, or
   the first elements of a ``sampled_from`` list), so the classic edge
   cases are always exercised;
 - the example count is ``min(max_examples, REPRO_FALLBACK_EXAMPLES)``
   (default 5) — property tests become cheap fixed-case tests, which also
   helps the tier-1 wall-time budget (every distinct drawn shape is a
   fresh XLA compile).

This is NOT a property-testing engine (no shrinking, no coverage-guided
search); it exists so a missing optional dependency degrades to "fewer
examples", not "7 modules fail to collect".
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import *          # noqa: F401,F403
    from hypothesis import given, settings, assume, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import os
    import random
    import zlib

    _DEFAULT_EXAMPLES = int(os.environ.get("REPRO_FALLBACK_EXAMPLES", "5"))

    class _Strategy:
        """A draw callable (rng, example_index) -> value."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng, i):
            return self._draw(rng, i)

    class _StrategiesModule:
        """Mimics ``hypothesis.strategies`` for the subset the suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            def draw(rng, i):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return rng.randint(min_value, max_value)
            return _Strategy(draw)

        @staticmethod
        def floats(min_value, max_value):
            def draw(rng, i):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return rng.uniform(min_value, max_value)
            return _Strategy(draw)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)

            def draw(rng, i):
                if i < len(seq):
                    return seq[i]
                return rng.choice(seq)
            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _StrategiesModule.sampled_from([False, True])

        @staticmethod
        def just(value):
            return _Strategy(lambda rng, i: value)

    strategies = _StrategiesModule()

    class _Unsatisfied(Exception):
        pass

    def assume(condition):
        if not condition:
            raise _Unsatisfied()
        return True

    def given(*args, **strat_kw):
        if args:
            raise TypeError(
                "_hypothesis_compat given() supports keyword strategies only")

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*call_args, **call_kw):
                n = min(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES),
                        _DEFAULT_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode("utf-8"))
                for i in range(max(n, 1)):
                    rng = random.Random((seed, i))
                    drawn = {k: s.draw(rng, i) for k, s in strat_kw.items()}
                    try:
                        fn(*call_args, **dict(call_kw, **drawn))
                    except _Unsatisfied:
                        continue
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying fixed example #{i}: {drawn!r}"
                        ) from e
            # pytest plugins (anyio, hypothesis's own) probe
            # ``fn.hypothesis.inner_test`` — mimic that attribute shape.
            wrapper.hypothesis = type("_Hyp", (), {"inner_test": fn})()
            # pytest must NOT see the wrapped function's parameters (it
            # would demand fixtures for them): hide __wrapped__ and expose
            # only the non-strategy parameters (real fixtures, if any).
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items()
                    if name not in strat_kw]
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco

    def settings(*args, **kw):
        max_examples = kw.get("max_examples", _DEFAULT_EXAMPLES)

        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    class HealthCheck:                  # referenced by some settings() calls
        all = staticmethod(lambda: [])
        too_slow = data_too_large = filter_too_much = None
