"""Heterogeneous local-step scheduling (core.adaptive.HSpec / plan_h):
property tests for the planner and the gossip spectral-gap clamp, the
uniform-schedule == scalar-H bitwise guarantee through the numeric
simulator, the per-cluster compute/idle timeline split, the trainer's
masked inner scan, and the dynamic time-varying random topology."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.adaptive import HSpec, gap_h_floor, plan_h
from repro.sim import (FaultSchedule, LinkProfile, QuadraticSpec, Scenario,
                       Straggler, simulate)
from repro.topology import MixingMatrix, compute_leg, make_topology


def _scenario(**kw):
    base = dict(n_clusters=3, rounds=4, h_steps=4, t_step_s=0.05,
                link=LinkProfile(bytes_per_s=200_000), compressor="diloco_x",
                compressor_kw={"rank": 4, "min_dim_for_lowrank": 8}, rank=4,
                n_params=1e5, seed=0)
    base.update(kw)
    return Scenario(**base)


def _spec(n=3, h=4):
    return QuadraticSpec(n_clusters=n, d=8, n_mats=2, h_steps=h, seed=0)


# ---------------------------------------------------------------------------
# plan_h properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(h_base=st.integers(2, 64), n=st.integers(1, 8),
       t=st.floats(0.01, 10.0))
def test_plan_h_uniform_times_give_uniform_budget(h_base, n, t):
    """Equal step times => every cluster gets exactly h_base (the schedule
    the scalar path executes; bitwise equality is pinned below)."""
    h = plan_h(HSpec(policy="balance"), h_base, np.full(n, t),
               np.ones(n, bool))
    assert h == {c: h_base for c in range(n)}
    # the global policy is the identity regardless of the times
    hg = plan_h(HSpec(policy="global"), h_base,
                np.linspace(0.1, 5.0, n), np.ones(n, bool))
    assert hg == {c: h_base for c in range(n)}
    assert plan_h(None, h_base, np.full(n, t), np.ones(n, bool)) == hg


@settings(max_examples=30, deadline=None)
@given(h_base=st.integers(2, 48), n=st.integers(2, 8),
       seed=st.integers(0, 999), h_min=st.integers(1, 4))
def test_plan_h_balance_never_increases_barrier_waste(h_base, n, seed,
                                                      h_min):
    """Modeled barrier waste (sum of per-cluster idle seconds from the
    shared compute_leg accounting) under balance is <= the global-H
    schedule's, for arbitrary step-time vectors; and h_c stays in
    [h_min, h_base]."""
    rng = np.random.RandomState(seed)
    t_steps = rng.uniform(0.05, 5.0, size=n)
    alive = np.ones(n, bool)
    if n >= 3:                               # planner must ignore dead sites
        alive[rng.randint(n)] = False
        if not alive.any():
            alive[0] = True
    spec = HSpec(policy="balance", h_min=h_min)
    h_bal = plan_h(spec, h_base, t_steps, alive)
    h_glob = plan_h(None, h_base, t_steps, alive)
    assert set(h_bal) == {int(i) for i in np.flatnonzero(alive)}
    assert all(h_min <= h <= h_base for h in h_bal.values())
    waste_bal = sum(compute_leg(h_bal, t_steps, alive).idle_by.values())
    waste_glob = sum(compute_leg(h_glob, t_steps, alive).idle_by.values())
    assert waste_bal <= waste_glob + 1e-9
    # the fastest alive cluster always keeps its full budget
    ids = [int(i) for i in np.flatnonzero(alive)]
    fastest = min(ids, key=lambda c: t_steps[c])
    assert h_bal[fastest] == h_base


@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 8), h_base=st.integers(3, 32),
       kind=st.sampled_from(["ring", "torus", "random"]),
       seed=st.integers(0, 99))
def test_plan_h_gossip_clamp_respects_spectral_gap(n, h_base, kind, seed):
    """Under gossip, no cluster's H may fall below the spectral-gap floor
    ceil(h_base * (1 - gap)) no matter how slow its steps are — slow
    mixing must not silently buy disagreement."""
    topo = make_topology(kind, n, seed=seed)
    gap = MixingMatrix.metropolis(topo).spectral_gap()
    spec = HSpec(policy="balance", h_min=1)
    floor = gap_h_floor(spec, h_base, gap)
    assert 1 <= floor <= h_base
    t_steps = np.ones(n)
    t_steps[0] = 1000.0                      # extreme straggler
    h = plan_h(spec, h_base, t_steps, np.ones(n, bool), spectral_gap=gap)
    assert h[0] == floor
    assert all(v >= floor for v in h.values())
    # a full-mixing certificate (gap 1) removes the clamp entirely
    h_full = plan_h(spec, h_base, t_steps, np.ones(n, bool),
                    spectral_gap=1.0)
    assert h_full[0] == 1
    # gap_clamp=False opts out
    h_off = plan_h(HSpec(policy="balance", h_min=1, gap_clamp=False),
                   h_base, t_steps, np.ones(n, bool), spectral_gap=gap)
    assert h_off[0] == 1


def test_hspec_roundtrip_and_scenario_meta():
    spec = HSpec(policy="balance", h_min=2, gap_clamp=False)
    assert HSpec.from_dict(spec.to_dict()) == spec
    sc = _scenario(h_spec=spec)
    assert sc.meta()["h_spec"] == spec.to_dict()
    with pytest.raises(ValueError):
        HSpec(policy="nope")
    with pytest.raises(ValueError):
        HSpec(h_min=0)


# ---------------------------------------------------------------------------
# the uniform-vector == scalar-H bitwise guarantee (numeric simulator)
# ---------------------------------------------------------------------------

def test_uniform_h_vector_bitwise_equals_scalar_path():
    """A fault-free, jitter-free balance run plans the uniform h_base
    vector, and the masked-scan numeric leg must produce bit-identical
    per-round params to the scalar path — the same discipline as
    per_cluster_compress."""
    spec = _spec()
    a = simulate(_scenario(), numeric=spec.problem())
    b = simulate(_scenario(h_spec=HSpec(policy="balance")),
                 numeric=spec.problem())
    assert [e.h_by for e in b.events] == [(4, 4, 4)] * 4
    assert ([e.param_hash for e in a.events]
            == [e.param_hash for e in b.events])
    assert all(e.param_hash is not None for e in a.events)


def test_straggler_balance_runs_fewer_steps_and_still_trains():
    sc = _scenario(rounds=6,
                   faults=FaultSchedule((Straggler(1, 1, 5, 4.0),)),
                   h_spec=HSpec(policy="balance"))
    tl = simulate(sc, numeric=_spec().problem())
    # the straggler's H drops while the fault is active, others keep h_base
    for e in tl.events:
        if 1 <= e.round < 5:
            assert e.h_by[1] < 4 and e.h_by[0] == e.h_by[2] == 4
            assert e.h_steps == 4                  # the budget is unchanged
        else:
            assert e.h_by == (4, 4, 4)
    # tokens follow the executed schedule
    np.testing.assert_allclose(
        tl.events[1].tokens,
        sc.tokens_per_step * sum(tl.events[1].h_by) / sc.n_clusters)
    losses = tl.losses()
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]
    # determinism: same scenario => identical timeline (incl. h_by)
    assert simulate(sc, numeric=_spec().problem()).fingerprint() \
        == tl.fingerprint()


def test_h_schedule_recorded_and_gossip_clamped_in_sim():
    """Ring gossip + a 6x straggler: the executed schedule bottoms out at
    the spectral-gap floor, not at the proportional share."""
    sc = _scenario(n_clusters=4, topology="ring", rounds=4,
                   faults=FaultSchedule((Straggler(1, 1, 4, 6.0),)),
                   h_spec=HSpec(policy="balance"))
    tl = simulate(sc, numeric=_spec(n=4).problem())
    gap = MixingMatrix.metropolis(sc.topo()).spectral_gap()
    floor = gap_h_floor(sc.h_spec, sc.h_steps, gap)
    assert floor > 1                       # the clamp actually binds here
    for e in tl.events[1:]:
        assert e.h_by[1] == floor
        assert min(e.h_by) >= floor
    assert tl.h_schedule()[1] == list(tl.events[1].h_by)


# ---------------------------------------------------------------------------
# per-cluster compute/idle timeline split
# ---------------------------------------------------------------------------

def test_timeline_splits_compute_and_idle_per_cluster():
    sc = _scenario(faults=FaultSchedule((Straggler(1, 1, 3, 3.0),)))
    tl = simulate(sc)
    e = tl.events[1]
    assert len(e.t_compute_by) == len(e.alive) == 3
    # the barrier is the max own-compute; idle is the difference
    np.testing.assert_allclose(max(e.t_compute_by), e.t_compute_s)
    np.testing.assert_allclose(
        e.idle_by, [e.t_compute_s - t for t in e.t_compute_by])
    # straggler round: the two healthy clusters idle 2/3 of the barrier
    assert e.idle_by[0] > 0 and e.idle_by[1] == 0.0
    assert tl.total_barrier_idle_s > 0
    assert 0 < tl.barrier_idle_frac < 1
    # wall-clock seconds must stay OUT of the structural fingerprint
    slow = dataclasses.replace(sc, t_step_s=0.1)
    assert simulate(slow).structural_fingerprint() \
        == tl.structural_fingerprint()
    assert simulate(slow).fingerprint() != tl.fingerprint()


def test_structural_fingerprint_covers_h_schedule():
    """Two scenarios whose only difference is the H policy must have
    different structural fingerprints on a straggler round (the executed
    schedule is structure, not wall clock)."""
    sc = _scenario(faults=FaultSchedule((Straggler(1, 1, 3, 3.0),)))
    a = simulate(sc)
    b = simulate(dataclasses.replace(sc, h_spec=HSpec(policy="balance")))
    assert a.structural_fingerprint() != b.structural_fingerprint()


# ---------------------------------------------------------------------------
# dynamic time-varying random topology (NoLoCo-style fresh partners)
# ---------------------------------------------------------------------------

def test_dynamic_random_topology_redraws_per_round():
    # a dead member + a degraded uplink make the comm leg depend on WHICH
    # graph was drawn (the bottleneck cluster's alive-degree varies); a
    # clean full k-regular membership is legitimately indistinguishable
    # in timing-only mode (every graph has identical degrees)
    from repro.sim import LinkDegradation
    sc = _scenario(n_clusters=6, rounds=6, topology="random",
                   topology_seed_schedule=tuple(range(6)),
                   initial_alive=(True,) * 5 + (False,),
                   faults=FaultSchedule((LinkDegradation(0, 6, 0.05,
                                                         cluster=0),)))
    # the per-round graphs genuinely differ (fresh partners), and the
    # timeline is deterministic
    topos = [sc.topo(r) for r in range(6)]
    assert len({t.edges for t in topos}) > 1
    tl = simulate(sc)
    assert simulate(sc).fingerprint() == tl.fingerprint()
    # the schedule cycles: round r and r + len(schedule) share a graph
    sc2 = dataclasses.replace(sc, rounds=8)
    assert sc2.topo(1).edges == sc2.topo(7).edges
    # fresh partners show up in the accounting: the degraded cluster's
    # alive-degree (hence its serialized neighbor-send time) varies with
    # the drawn graph, while the fixed-seed run repeats one number
    fixed = simulate(dataclasses.replace(sc, topology_seed_schedule=None))
    assert len({round(e.t_comm_s, 9) for e in fixed.events}) == 1
    assert len({round(e.t_comm_s, 9) for e in tl.events}) > 1


def test_dynamic_topology_numeric_converges_and_rejects_misuse():
    sc = _scenario(n_clusters=4, rounds=6, topology="random",
                   topology_seed_schedule=(0, 1, 2))
    tl = simulate(sc, numeric=_spec(n=4).problem())
    losses = tl.losses()
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]
    assert all(e.disagreement is not None for e in tl.events)
    # only the random kind can redraw (the proc backend now re-dials the
    # PeerMesh per round — its own gates live in tests/test_sim_proc.py)
    with pytest.raises(ValueError):
        _scenario(topology="ring", topology_seed_schedule=(0, 1))


# ---------------------------------------------------------------------------
# trainer-level masked inner scan
# ---------------------------------------------------------------------------

def test_trainer_balance_uniform_times_bitwise_matches_global():
    """The LM trainer's h-masked inner scan with uniform step times (=>
    uniform schedule) reproduces the global path's losses exactly, and a
    heterogeneous schedule is recorded in RunResult."""
    from repro.configs.base import get_config
    from repro.train import trainer as T

    cfg = dataclasses.replace(get_config("opt-1.3b").reduced(),
                              vocab_size=64)
    base = dict(n_clusters=2, local_batch=2, seq_len=16, h_steps=2,
                compressor="diloco_x",
                compressor_kw=dict(rank=8, min_dim_for_lowrank=8), seed=0)
    g = T.run_diloco_training(cfg, T.TrainConfig(**base), n_rounds=2)
    b = T.run_diloco_training(
        cfg, T.TrainConfig(**base, h_policy="balance"), n_rounds=2)
    assert b.h_by_per_round == [(2, 2), (2, 2)]
    np.testing.assert_array_equal(g.eval_losses, b.eval_losses)
    # heterogeneous step times: the slow cluster runs fewer steps
    h = T.run_diloco_training(
        cfg, T.TrainConfig(**base, h_policy="balance",
                           step_times=(1.0, 2.0)), n_rounds=1)
    assert h.h_by_per_round == [(2, 1)]
    assert np.isfinite(h.losses[-1])
