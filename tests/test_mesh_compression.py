"""Wire-honest mesh compression (core.mesh_compression): numerics match
the simulator, the payload bytes match the analytic accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh_compression as mc
from repro.core.compression import LowRankQuant


def _tree(C=2):
    k = jax.random.PRNGKey(0)
    one = {"w": jax.random.normal(k, (3, 128, 96)) / 10,   # (units, m, n)
           "b": jax.random.normal(jax.random.fold_in(k, 1), (96,))}
    return jax.tree.map(
        lambda x: jnp.stack([x, x * 0.5]), one)            # stacked clusters


def test_compress_gather_mean_shapes_and_finiteness():
    cfg = mc.MeshCompressionConfig(rank=16, bits=4, min_dim_for_lowrank=64)
    tree = _tree()
    q = mc.init_q_state(jax.tree.map(lambda x: x[0], tree), cfg)
    q = jax.tree.map(lambda x: jnp.broadcast_to(x, (2,) + x.shape).copy(), q)
    Delta, q2 = mc.compress_gather_mean(tree, q, jnp.asarray(16), cfg)
    for a, b in zip(jax.tree.leaves(jax.tree.map(lambda x: x[0], tree)),
                    jax.tree.leaves(Delta)):
        assert a.shape == b.shape
        assert np.isfinite(np.asarray(b)).all()


def test_mesh_compression_reduces_error_with_warm_start():
    """Repeated compression of the same low-rank matrix converges (PowerSGD
    subspace iteration), matching the simulator's behaviour."""
    cfg = mc.MeshCompressionConfig(rank=8, min_dim_for_lowrank=8)
    u = jax.random.normal(jax.random.PRNGKey(1), (128, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (8, 96))
    M = (u @ v) / 8.0
    tree = {"w": jnp.stack([M, M])}          # 2 identical clusters
    q = mc.init_q_state({"w": M}, cfg)
    q = jax.tree.map(lambda x: jnp.broadcast_to(x, (2,) + x.shape).copy(), q)
    errs = []
    for _ in range(4):
        Delta, q = mc.compress_gather_mean(tree, q, None, cfg)
        errs.append(float(jnp.linalg.norm(Delta["w"] - M)
                          / jnp.linalg.norm(M)))
    # int4 factor quantization bounds the floor: |PQ^T - M| ~ 2 * (scale/2)
    # relative ~ 0.15-0.2 for Gaussian factors; the subspace itself locks on
    assert errs[-1] < 0.25, errs
    assert errs[-1] <= errs[0] + 0.02


def test_wire_bytes_scale_with_rank():
    cfg64 = mc.MeshCompressionConfig(rank=64)
    cfg16 = mc.MeshCompressionConfig(rank=16)
    p = {"w": jnp.zeros((4, 1024, 1024))}
    assert mc.wire_bytes_tree(p, cfg16) < mc.wire_bytes_tree(p, cfg64)
    # adaptive rank accounting
    assert mc.wire_bytes_tree(p, cfg64, rank=16) == \
        mc.wire_bytes_tree(p, cfg16, rank=16)
