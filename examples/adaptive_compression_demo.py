"""Adaptive Gradient Compression (Alg. 3) in action: watch r_t track the
effective rank of the pseudo-gradients as training progresses, and H_t
co-adapt (paper rule vs our overlap-matching correction — DESIGN.md §3).

  PYTHONPATH=src python examples/adaptive_compression_demo.py
"""
import dataclasses

from repro.configs.base import get_config
from repro.train import trainer as T


def main() -> None:
    cfg = dataclasses.replace(get_config("opt-1.3b").reduced(),
                              vocab_size=128)
    for mode in ("paper", "overlap"):
        tc = T.TrainConfig(n_clusters=2, local_batch=8, seq_len=32,
                           inner_lr=3e-3, h_steps=10,
                           compressor="diloco_x",
                           compressor_kw=dict(rank=32, bits=4),
                           outer_lr=0.5, outer_momentum=0.7,
                           adaptive=True, adaptive_mode=mode)
        res = T.run_diloco_training(cfg, tc, n_rounds=10)
        print(f"== mode={mode} ==")
        print(" round   r_t   H_t   wire_MB   eval_loss")
        for i, (r, h, w, e) in enumerate(zip(res.r_per_round,
                                             res.h_per_round,
                                             res.wire_bytes_per_round,
                                             res.eval_losses)):
            print(f"  {i:4d}  {r:4d}  {h:4d}  {w/1e6:8.3f}   {e:.3f}")


if __name__ == "__main__":
    main()
