"""In-process serving example on the paged continuous-batching engine:
staggered mixed-length requests share one page pool, and the resident KV
footprint is compared against the dense per-slot-max-length layout.

  PYTHONPATH=src python examples/serve_decode.py [--arch granite-3-8b]
  PYTHONPATH=src python examples/serve_decode.py --dense   # legacy driver

Unsupported families (SSM/MLA/enc-dec) fall back to the dense driver
subprocess, same as ``repro.launch.serve`` without ``--paged``.
"""
import argparse
import os
import subprocess
import sys


def _dense_fallback(arch: str) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", arch, "--smoke", "--devices", "4",
           "--batch", "4", "--prompt-len", "12", "--gen-len", "12"]
    print(" ".join(cmd))
    return subprocess.run(cmd, env=env).returncode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--dense", action="store_true",
                    help="run the legacy dense driver instead")
    args = ap.parse_args()

    if args.dense:
        sys.exit(_dense_fallback(args.arch))

    import jax
    import numpy as np

    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine, supports_paged

    cfg = get_config(args.arch).reduced()
    ok, why = supports_paged(cfg)
    if not ok:
        print(f"{args.arch}: {why} -> dense driver")
        sys.exit(_dense_fallback(args.arch))

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ps, max_pages, gen = 4, 4, 6
    engine = ServeEngine(params, cfg, max_seqs=3, page_size=ps,
                         n_pages=3 * max_pages, max_pages_per_seq=max_pages)

    # staggered arrivals, mixed prompt lengths — the continuous-batching
    # regime: slots recycle as short requests finish
    rng = np.random.default_rng(0)
    for r, (arrival, plen) in enumerate(
            [(0, 4), (0, 7), (1, 3), (3, 9), (5, 5), (6, 4)]):
        engine.submit(rng.integers(0, cfg.vocab_size, plen).tolist(), gen,
                      arrival=arrival)

    st = engine.run()
    for req in sorted(engine.sched.done, key=lambda r: r.rid):
        print(f"  req {req.rid}: arrive@{req.arrival} "
              f"admit@{req.admit_step} done@{req.done_step} "
              f"({req.finish_reason}) -> {req.generated}")
    print(f"{st['requests_done']} requests in {st['steps']} steps "
          f"(ttft p50 {st['ttft_steps_p50']:.0f} steps, "
          f"{st['decode_tok_per_step']:.2f} decode tok/step)")

    # paged-vs-dense resident KV: the pool holds peak_pages_used pages;
    # a dense cache holds max_seqs * s_max positions whether used or not
    pool, peak, dense = (st["kv_pool_bytes"], st["kv_peak_bytes"],
                         st["dense_equiv_bytes"])
    print(f"KV bytes: pool {pool} / peak resident {peak} "
          f"vs dense {dense} ({peak / dense:.0%} of dense)")
    print("SERVE-EXAMPLE-OK")


if __name__ == "__main__":
    main()
