"""Batched serving example: greedy decode with KV caches (dense) and
recurrent state (SSM) through the same serve_step the dry-run lowers.

  PYTHONPATH=src python examples/serve_decode.py [--arch gemma3-1b]
"""
import argparse
import os
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    args = ap.parse_args()
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", args.arch, "--smoke", "--devices", "4",
           "--batch", "4", "--prompt-len", "12", "--gen-len", "12"]
    print(" ".join(cmd))
    sys.exit(subprocess.run(cmd, env=env).returncode)


if __name__ == "__main__":
    main()
