"""End-to-end driver: pre-train a ~100M-param dense model with the FULL
DiLoCoX stack (mesh runtime, 8 simulated devices = 2 clusters x 2 data x
2 model, adaptive compression, checkpointing) for a few hundred steps.

  PYTHONPATH=src python examples/pretrain_diloco.py [--rounds 20]

This is the executable twin of the production dry-run: the same
launch/steps.py functions the 512-device dry-run lowers. NOTE: the full
default budget (20 rounds x 10 steps of a 116M model) is sized for a real
accelerator; on a 1-core CPU container use --rounds 2 --h-steps 2 to see
the mechanics (CI does).
"""
import argparse
import os
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--h-steps", type=int, default=10)
    args = ap.parse_args()

    # ~100M params: d=512, L=8, vocab 8192 -> 8*(4*512^2 + 3*512*2048) +
    # 2*8192*512 ~ 42M... bump d_ff/d for ~100M
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "hundred-m", "--devices", "8", "--clusters", "2",
        "--data", "2", "--model", "2",
        "--rounds", str(args.rounds), "--h-steps", str(args.h_steps),
        "--global-batch", "16", "--seq-len", "128",
        "--inner-lr", "1e-3", "--outer-lr", "0.5", "--outer-momentum", "0.7",
        "--rank", "32", "--adaptive",
        "--ckpt-dir", "/tmp/diloco_ckpt",
    ]
    print(" ".join(cmd))
    r = subprocess.run(cmd, env=env)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
