"""Quickstart: DiLoCoX vs vanilla AllReduce on a tiny LM, CPU-only.

Trains the same reduced dense model two ways over 2 simulated decentralized
clusters and prints the loss curves plus the communication bytes each method
put on the (1 Gbps) wire — the paper's whole point in miniature.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import numpy as np

from repro.configs.base import get_config
from repro.train import trainer as T


def main() -> None:
    cfg = dataclasses.replace(get_config("opt-1.3b").reduced(),
                              vocab_size=128)
    rounds, h = 8, 10
    base = dict(n_clusters=2, local_batch=8, seq_len=32, inner_lr=3e-3)

    print("== vanilla AllReduce (sync every step) ==")
    ar = T.run_allreduce_training(cfg, T.TrainConfig(**base, h_steps=1),
                                  rounds * h)
    print("eval loss:", [round(x, 2) for x in ar.eval_losses[::10]])

    print("== DiLoCoX (H=10 local steps, low-rank+int4, one-step delay) ==")
    tc = T.TrainConfig(**base, h_steps=h, compressor="diloco_x",
                       compressor_kw=dict(rank=16, bits=4),
                       outer_lr=0.5, outer_momentum=0.7)
    dlx = T.run_diloco_training(cfg, tc, rounds)
    print("eval loss:", [round(x, 2) for x in dlx.eval_losses])

    wire_ar = sum(ar.wire_bytes_per_round)
    wire_dlx = sum(dlx.wire_bytes_per_round)
    print(f"\nwire bytes  AllReduce: {wire_ar/1e6:9.1f} MB "
          f"(every step, fp32)")
    print(f"wire bytes  DiLoCoX : {wire_dlx/1e6:9.1f} MB "
          f"({wire_ar/max(wire_dlx,1):.0f}x less)")
    print(f"final loss  AllReduce={ar.eval_losses[-1]:.3f}  "
          f"DiLoCoX={dlx.eval_losses[-1]:.3f}")


if __name__ == "__main__":
    main()
