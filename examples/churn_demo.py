"""Membership-churn demo on the virtual decentralized cluster.

Four clusters pre-train over simulated 1 Gbps WAN links running the REAL
DiLoCoX round loop (core/diloco.py: compression, error feedback, one-step
delay) on a tiny problem while the fault injector misbehaves:

 - cluster 1 straggles 3x for rounds 4-8 (the outer barrier waits, but
   the overlap keeps comm hidden);
 - cluster 2 LEAVES at round 6: the outer average switches to the
   mask-weighted mean over the 3 survivors (core/membership.py);
 - cluster 2 REJOINS at round 12: its stale pending-delta/error buffers
   are reset and it restarts from the current global params.

Training keeps converging through all of it, and the event timeline shows
exactly what each round cost.  Run:

  PYTHONPATH=src python examples/churn_demo.py
"""
from repro.sim import (FaultSchedule, Join, Leave, LinkProfile, Scenario,
                       Straggler, make_quadratic_problem, simulate)


def main() -> None:
    n_clusters, rounds, h = 4, 16, 6
    faults = FaultSchedule((
        Straggler(cluster=1, start_round=4, end_round=8, slowdown=3.0),
        Leave(cluster=2, round=6),
        Join(cluster=2, round=12),
    ))
    sc = Scenario(
        n_clusters=n_clusters, rounds=rounds, h_steps=h,
        t_step_s=1.0, tokens_per_step=4096,
        link=LinkProfile(jitter=0.05),
        faults=faults,
        compressor="diloco_x",
        compressor_kw={"rank": 4, "min_dim_for_lowrank": 8},
        n_params=1e6, seed=0)
    problem = make_quadratic_problem(n_clusters, h_steps=h, seed=0)

    tl = simulate(sc, numeric=problem)
    print(tl.table())
    print()
    losses = tl.losses()
    print(f"loss: {losses[0]:.2f} (start) -> {losses[-1]:.2f} (final), "
          f"through a straggler + a leave/rejoin cycle")
    print(f"deterministic timeline fingerprint: {tl.fingerprint()[:16]}")

    # rerun => bit-identical timeline (same seed)
    assert simulate(sc, numeric=make_quadratic_problem(
        n_clusters, h_steps=h, seed=0)).fingerprint() == tl.fingerprint()
    print("rerun with the same seed: identical timeline ✓")


if __name__ == "__main__":
    main()
