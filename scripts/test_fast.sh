#!/usr/bin/env bash
# Tier-1 fast test runner — mirrors the ROADMAP tier-1 command.
#
# `slow`-marked tests (multi-minute subprocess/integration runs) are
# deselected by tests/conftest.py; pass --runslow to include them:
#   scripts/test_fast.sh            # tier-1 (fast) suite
#   scripts/test_fast.sh --runslow  # everything
set -euo pipefail
cd "$(dirname "$0")/.."

# absolute path: worker subprocesses (tests/test_sim_proc.py spawns real
# processes via repro.sim.proc) must resolve the package from any cwd;
# a pre-set PYTHONPATH is honored after ours
export PYTHONPATH="$PWD/src${PYTHONPATH:+:$PYTHONPATH}"

# pytest-xdist (optional) parallelizes the fast tier across cores; --runslow
# runs stay serial — each slow test already spawns worker subprocesses /
# multi-device jax jobs of its own and would oversubscribe the box
XDIST=()
if python -c "import xdist" >/dev/null 2>&1 \
    && [[ " $* " != *" --runslow "* ]]; then
  XDIST=(-n auto)
fi
exec python -m pytest -x -q ${XDIST[@]+"${XDIST[@]}"} "$@"
