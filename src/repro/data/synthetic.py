"""Deterministic synthetic LM data pipeline.

Offline container: no datasets. We generate a *learnable* token stream — a
mixture of (a) a first-order Markov chain with a sparse, seeded transition
structure and (b) exact-copy spans — so cross-entropy genuinely decreases
with training and different distributed algorithms produce distinguishable
loss curves (that is all the paper's Fig. 3 needs: loss *gaps/ordering*,
see DESIGN.md §3 faithfulness notes).

The pipeline is shardable: shard i of D draws from a disjoint counter
stream (`data_shard` folds into the PRNG), matching the paper's per-cluster
local data source D_i.
"""
from __future__ import annotations

from functools import partial
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_markov_table(vocab: int, branching: int = 4, seed: int = 0
                      ) -> np.ndarray:
    """(vocab, branching) int32 successor table — each token has `branching`
    plausible successors; the generator picks among them."""
    rng = np.random.RandomState(seed)
    return rng.randint(0, vocab, size=(vocab, branching)).astype(np.int32)


@partial(jax.jit, static_argnums=(1, 2, 3))
def _gen_batch(key, batch: int, seq: int, branching: int, table: jnp.ndarray,
               bias_logits=None):
    """bias_logits: optional (branching,) categorical logits — per-cluster
    successor preference (data heterogeneity, paper Assumption 3.3's
    xi^2 > 0; what makes oversized-H local training drift)."""
    k0, k1, k2 = jax.random.split(key, 3)
    first = jax.random.randint(k0, (batch,), 0, table.shape[0])
    if bias_logits is not None:
        choices = jax.random.categorical(k1, bias_logits, shape=(batch, seq))
    else:
        choices = jax.random.randint(k1, (batch, seq), 0, branching)

    def step(tok, choice):
        nxt = table[tok, choice]
        return nxt, nxt

    _, toks = jax.lax.scan(
        lambda c, ch: step(c, ch), first, choices.T)
    toks = jnp.concatenate([first[None], toks[:-1]], axis=0).T  # (B,S)
    return toks.astype(jnp.int32)


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, batch: int, *,
                 branching: int = 4, seed: int = 0, data_shard: int = 0,
                 hetero: float = 0.0):
        self.vocab = vocab
        self.seq = seq_len
        self.batch = batch
        self.branching = branching
        self.table = jnp.asarray(make_markov_table(vocab, branching, seed))
        self.base_key = jax.random.fold_in(jax.random.PRNGKey(seed + 1),
                                           data_shard)
        self.step = 0
        # heterogeneity: shard-specific successor preference (0 = IID)
        if hetero > 0:
            pref = data_shard % branching
            logits = jnp.full((branching,), 0.0)
            self.bias_logits = logits.at[pref].set(
                jnp.log(1.0 + hetero * branching / (1 - hetero + 1e-9)))
        else:
            self.bias_logits = None

    def next_batch(self) -> dict:
        key = jax.random.fold_in(self.base_key, self.step)
        self.step += 1
        toks = _gen_batch(key, self.batch, self.seq, self.branching,
                          self.table, self.bias_logits)
        return {"tokens": toks}

    def batches(self, n: int) -> Iterator[dict]:
        for _ in range(n):
            yield self.next_batch()

    def entropy_floor(self) -> float:
        """Best achievable NLL = log(branching) if choices are uniform."""
        return float(np.log(self.branching))


def with_frontend(batch: dict, cfg, key=None) -> dict:
    """Attach stub frontend embeddings (audio frames / vision patches) of the
    right shape, per the spec's modality carve-out."""
    if cfg.modality == "text":
        return batch
    B = batch["tokens"].shape[0]
    P = cfg.n_frontend_tokens
    key = key if key is not None else jax.random.PRNGKey(0)
    emb = jax.random.normal(key, (B, P, cfg.d_model), jnp.float32) * 0.02
    out = dict(batch)
    out["frontend"] = emb
    return out
