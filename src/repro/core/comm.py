"""Decentralized communication model (paper §2.4.1 arithmetic).

Reproduces the paper's throughput comparisons (Fig. 4, Table 1) from first
principles: wire bytes come from the actual parameter shapes + compressor
accounting (not hand-waved ratios), link speed is the paper's 1 Gbps, and
the local step time follows the paper's own assumption (§2.4.1: "the
duration of every local step is 1 second" for the 107B model; smaller
models scale by FLOPs).

Ring AllReduce moves 2(C-1)/C * bytes per link; the gather-based DiLoCoX
outer sync moves (C-1)/C * payload (DESIGN.md §3).

One-step-delay overlap (§2.3): communication of round t-1 hides behind the
H local steps of round t, so the exposed comm time per round is
max(0, T_comm - H * T_step) instead of T_comm.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

GBPS = 0.125e9          # 1 Gbps in bytes/s


@dataclass(frozen=True)
class CommScenario:
    n_clusters: int = 2
    link_bytes_per_s: float = GBPS
    t_step_s: float = 1.0          # local step time (paper §2.4.1)
    tokens_per_step: int = 4_194_304   # global batch x seq (e.g. 1024x4096)


def ring_allreduce_time(bytes_total: float, sc: CommScenario) -> float:
    c = sc.n_clusters
    return 2 * (c - 1) / c * bytes_total / sc.link_bytes_per_s


def gather_time(payload_bytes: float, sc: CommScenario) -> float:
    """Ring all-gather of a per-cluster payload: C-1 forwarding steps of
    payload-sized pieces per member."""
    c = sc.n_clusters
    return (c - 1) * payload_bytes / sc.link_bytes_per_s


@dataclass
class MethodThroughput:
    name: str
    tokens_per_s: float
    t_round_s: float
    comm_s_per_round: float
    exposed_comm_s: float
    wire_bytes: float


def method_throughput(name: str, *, param_bytes_fp32: float,
                      wire_bytes: float, h_steps: int, overlap: bool,
                      sc: CommScenario, allreduce_per_step: bool = False
                      ) -> MethodThroughput:
    """Throughput of one method.

    allreduce_per_step: vanilla AllReduce / CocktailSGD style — communicate
    every step (wire_bytes is the per-step payload). Otherwise local-SGD
    style: H local steps then one pseudo-gradient sync of wire_bytes.
    """
    if allreduce_per_step:
        comm = ring_allreduce_time(wire_bytes, sc)
        t_round = sc.t_step_s + comm       # no overlap in vanilla DDP
        tokens = sc.tokens_per_step
        return MethodThroughput(name, tokens / t_round, t_round, comm, comm,
                                wire_bytes)
    comm = gather_time(wire_bytes, sc)
    compute = h_steps * sc.t_step_s
    exposed = max(0.0, comm - compute) if overlap else comm
    t_round = compute + exposed
    tokens = sc.tokens_per_step * h_steps
    return MethodThroughput(name, tokens / t_round, t_round, comm, exposed,
                            wire_bytes)
