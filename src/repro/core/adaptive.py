"""Adaptive Gradient Compression (paper Alg. 3) + effective-rank estimation.

The paper's controller tracks the effective rank r'_t of the globally
averaged pseudo-gradient over a window c; r_t is the window mean, and the
local-step budget H_t is co-adapted via alpha = (r_1 - r_t)/r_1.

Faithfulness note (DESIGN.md §3): the paper's H_t = H_1 * alpha is degenerate
(alpha=0 while rank has not yet dropped => H_t=0) and *grows* H as
compression gets cheaper — the opposite of matching communication time to
local compute. ``mode="paper"`` implements it verbatim (guarded by h_min);
``mode="overlap"`` is our corrected rule H_t = max(h_min, H_1 * r_t/r_1),
which shrinks H as the wire volume shrinks so T_comm <= H*T_step stays
tight. Both are benchmarked (benchmarks/ablation.py).

The paper does not specify the rank estimator; we use the stable rank
||G||_F^2 / sigma_max^2 with a few power iterations (cheap, jittable).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import matrix_shape, to_matrix


def stable_rank(mat: jnp.ndarray, iters: int = 8) -> jnp.ndarray:
    """||M||_F^2 / sigma_max(M)^2 via power iteration; in [1, min(m,n)]."""
    M = to_matrix(mat).astype(jnp.float32)
    m, n = M.shape
    v = jnp.ones((n,), jnp.float32) / jnp.sqrt(n)

    def body(v, _):
        u = M @ v
        u = u / (jnp.linalg.norm(u) + 1e-12)
        v = M.T @ u
        s = jnp.linalg.norm(v)
        return v / (s + 1e-12), s

    v, sigmas = jax.lax.scan(body, v, None, length=iters)
    sigma_max = sigmas[-1]
    fro2 = jnp.sum(M * M)
    return fro2 / (sigma_max ** 2 + 1e-12)


def tree_effective_rank(tree, max_mats: int = 8) -> jnp.ndarray:
    """Mean stable rank over the largest 2-D params (representative set)."""
    leaves = [(np.prod(x.shape), x) for x in jax.tree.leaves(tree)
              if x.ndim >= 2 and min(matrix_shape(x.shape)) >= 8]
    leaves.sort(key=lambda t: -t[0])
    mats = [x for _, x in leaves[:max_mats]]
    if not mats:
        return jnp.ones(())
    return jnp.mean(jnp.stack([stable_rank(m) for m in mats]))


@dataclass
class AdaGradCmpConfig:
    window: int = 5                # c
    r1: int = 64                   # initial rank
    h1: int = 125                  # initial local steps
    h_min: int = 8
    r_min: int = 4
    mode: str = "paper"            # paper | overlap


@dataclass
class AdaGradCmpState:
    r_hist: List[float] = field(default_factory=list)
    t: int = 0
    r_t: int = 0
    h_t: int = 0

    @classmethod
    def create(cls, cfg: AdaGradCmpConfig):
        return cls(r_hist=[], t=0, r_t=cfg.r1, h_t=cfg.h1)


def adagradcmp_update(state: AdaGradCmpState, r_prime_t: float,
                      cfg: AdaGradCmpConfig) -> AdaGradCmpState:
    """One controller step (Alg. 3), host-side (runs once per outer step)."""
    hist = (state.r_hist + [float(r_prime_t)])[-cfg.window:]
    t = state.t + 1
    if t < cfg.window:
        r_t, h_t = cfg.r1, cfg.h1
    else:
        r_t = max(cfg.r_min, int(round(float(np.mean(hist)))))
        r_t = min(r_t, cfg.r1)
        if cfg.mode == "paper":
            alpha = (cfg.r1 - r_t) / cfg.r1            # Alg. 3 verbatim
            h_t = max(cfg.h_min, int(round(cfg.h1 * alpha)))
        else:                                          # "overlap" correction
            h_t = max(cfg.h_min, int(round(cfg.h1 * r_t / cfg.r1)))
    return AdaGradCmpState(r_hist=hist, t=t, r_t=r_t, h_t=h_t)
