"""Adaptive compression controller (paper Alg. 3 + bandwidth awareness).

The paper's controller (§2.4, Alg. 3) tracks the effective rank r'_t of the
globally averaged pseudo-gradient over a window c; r_t is the window mean,
and the local-step budget H_t is co-adapted via alpha = (r_1 - r_t)/r_1.
That signal is purely *spectral* — it never looks at the wire.  In the
OpenDiLoCo operational setting the binding constraint is usually the
*measured link*: a degraded uplink makes the same payload many times more
expensive, regardless of the gradient spectrum.

``AdaptiveController`` therefore fuses both signals:

 - **spectral** — Alg. 3 verbatim (``adagradcmp_update`` below): r_t is
   the windowed mean of the realized pseudo-gradient's effective rank;
 - **bandwidth** — pick the largest rank whose modeled outer-sync comm
   time still fits inside ``overlap_frac`` x this round's compute leg
   (the §2.3 overlap headroom: comm that fits under H·T_step is free);
 - **hybrid** — min of the two (never ship columns the spectrum says are
   empty, never ship columns the link cannot afford).

Under gossip topologies the controller emits a per-EDGE rank: every
directed edge (c -> j) carries cluster c's payload on cluster c's own
(possibly degraded) uplink, so a degraded link gets a lower rank *on that
link only* while healthy edges keep shipping full-rank factors.

All controller arithmetic is host-side python/numpy on deterministic
inputs (the modeled per-round bandwidths both simulator backends derive
from the same seeded jitter), which is what lets the proc backend broadcast
the decision in the round header and still match the in-process rank
schedule exactly.

Faithfulness note (DESIGN.md §3): the paper's H_t = H_1 * alpha is
degenerate (alpha=0 while rank has not yet dropped => H_t=0) and *grows* H
as compression gets cheaper — the opposite of matching communication time
to local compute. ``h_mode="paper"`` implements it verbatim (guarded by
h_min); ``h_mode="overlap"`` is our corrected rule
H_t = max(h_min, H_1 * r_t/r_1), which shrinks H as the wire volume
shrinks so T_comm <= H*T_step stays tight. Both are benchmarked
(benchmarks/ablation.py).

The paper does not specify the rank estimator; we use the stable rank
||G||_F^2 / sigma_max^2 with a few power iterations (cheap, jittable).

This module imports jax lazily (only the spectral estimators touch it), so
``repro.sim`` can embed an ``AdaptiveSpec`` in a ``Scenario`` and the proc
backend's timing-only paths stay jax-free.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


def stable_rank(mat, iters: int = 8):
    """||M||_F^2 / sigma_max(M)^2 via power iteration; in [1, min(m,n)]."""
    import jax
    import jax.numpy as jnp

    from repro.core.compression import to_matrix

    M = to_matrix(mat).astype(jnp.float32)
    m, n = M.shape
    v = jnp.ones((n,), jnp.float32) / jnp.sqrt(n)

    def body(v, _):
        u = M @ v
        u = u / (jnp.linalg.norm(u) + 1e-12)
        v = M.T @ u
        s = jnp.linalg.norm(v)
        return v / (s + 1e-12), s

    v, sigmas = jax.lax.scan(body, v, None, length=iters)
    sigma_max = sigmas[-1]
    fro2 = jnp.sum(M * M)
    return fro2 / (sigma_max ** 2 + 1e-12)


def tree_effective_rank(tree, max_mats: int = 8):
    """Mean stable rank over the largest 2-D params (representative set)."""
    import jax
    import jax.numpy as jnp

    from repro.core.compression import matrix_shape

    leaves = [(np.prod(x.shape), x) for x in jax.tree.leaves(tree)
              if x.ndim >= 2 and min(matrix_shape(x.shape)) >= 8]
    leaves.sort(key=lambda t: -t[0])
    mats = [x for _, x in leaves[:max_mats]]
    if not mats:
        return jnp.ones(())
    return jnp.mean(jnp.stack([stable_rank(m) for m in mats]))


@dataclass
class AdaGradCmpConfig:
    window: int = 5                # c
    r1: int = 64                   # initial rank
    h1: int = 125                  # initial local steps
    h_min: int = 8
    r_min: int = 4
    mode: str = "paper"            # paper | overlap


@dataclass
class AdaGradCmpState:
    r_hist: List[float] = field(default_factory=list)
    t: int = 0
    r_t: int = 0
    h_t: int = 0

    @classmethod
    def create(cls, cfg: AdaGradCmpConfig):
        return cls(r_hist=[], t=0, r_t=cfg.r1, h_t=cfg.h1)


def adagradcmp_update(state: AdaGradCmpState, r_prime_t: float,
                      cfg: AdaGradCmpConfig) -> AdaGradCmpState:
    """One controller step (Alg. 3), host-side (runs once per outer step)."""
    hist = (state.r_hist + [float(r_prime_t)])[-cfg.window:]
    t = state.t + 1
    if t < cfg.window:
        r_t, h_t = cfg.r1, cfg.h1
    else:
        r_t = max(cfg.r_min, int(round(float(np.mean(hist)))))
        r_t = min(r_t, cfg.r1)
        if cfg.mode == "paper":
            alpha = (cfg.r1 - r_t) / cfg.r1            # Alg. 3 verbatim
            h_t = max(cfg.h_min, int(round(cfg.h1 * alpha)))
        else:                                          # "overlap" correction
            h_t = max(cfg.h_min, int(round(cfg.h1 * r_t / cfg.r1)))
    return AdaGradCmpState(r_hist=hist, t=t, r_t=r_t, h_t=h_t)


def _quantized_rank(r_prime) -> float:
    """Host-side quantization of the r'_t float: a last-ulp difference
    between independently jitted producers must never flip the integer
    rank the controller rounds to."""
    return round(float(r_prime), 6)


def observe_mean_pseudo_grad(state: AdaGradCmpState, mean_pending,
                             cfg: AdaGradCmpConfig) -> AdaGradCmpState:
    """One Alg. 3 driver step from the realized averaged pseudo-gradient —
    the loop body shared by train/trainer.py, launch/train.py and
    ``AdaptiveController.observe`` (the trainers used to carry
    copy-pasted, independently-drifting versions of it).
    ``mean_pending`` is the (masked) cluster mean of the pending deltas;
    its effective rank is the r'_t signal."""
    return adagradcmp_update(
        state, _quantized_rank(tree_effective_rank(mean_pending)), cfg)


# ---------------------------------------------------------------------------
# the unified controller: spectral x measured-link fusion
# ---------------------------------------------------------------------------

ADAPTIVE_MODES = ("off", "spectral", "bandwidth", "hybrid")


@dataclass(frozen=True)
class AdaptiveSpec:
    """JSON-able controller description (embeddable in ``sim.Scenario`` and
    shippable to proc workers).  ``r1=None`` resolves to the compressor's
    configured rank at controller build time."""
    mode: str = "hybrid"           # spectral | bandwidth | hybrid
    window: int = 5                # Alg. 3 window c (spectral warm-up)
    r1: Optional[int] = None
    h1: int = 125
    h_min: int = 8
    r_min: int = 4
    h_mode: str = "overlap"        # Alg. 3 H co-adaptation: paper | overlap
    overlap_frac: float = 1.0      # comm budget = frac x compute leg

    def __post_init__(self):
        if self.mode not in ADAPTIVE_MODES:
            raise ValueError(f"adaptive mode {self.mode!r} not in "
                             f"{ADAPTIVE_MODES}")

    @property
    def needs_spectral(self) -> bool:
        return self.mode in ("spectral", "hybrid")

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "AdaptiveSpec":
        return AdaptiveSpec(**d)

    def controller(self, compressor=None) -> Optional["AdaptiveController"]:
        """Build the controller (None for mode='off').  ``r1`` resolution:
        the spec's own value, else the compressor's configured rank, else
        64 (a compressor with ``rank=None`` means "unbounded" there)."""
        if self.mode == "off":
            return None
        r1 = self.r1
        if r1 is None:
            r1 = getattr(compressor, "rank", None)
        if r1 is None:
            r1 = 64
        return AdaptiveController(self, int(r1))


class AdaptiveController:
    """Per-round rank controller fusing Alg. 3 with measured link state.

    Protocol per outer round r (identical on both simulator backends):

      1. ``executed()``/``rank_gather()``/``ranks_gossip()`` — decide the
         rank(s) for round r from the spectral state (through round r-1)
         and THIS round's modeled link/compute numbers;
      2. run the round, compressing with those rank(s); account wire bytes
         with the same rank(s);
      3. ``observe(mean_pending)`` — feed the realized averaged
         pseudo-gradient's effective rank back into the Alg. 3 window
         (spectral/hybrid modes only).

    Step 1 before step 3 is what fixes the historical off-by-one where the
    post-update controller state was logged as the round's wire cost.
    """

    def __init__(self, spec: AdaptiveSpec, r1: int):
        self.spec = spec
        self.cfg = AdaGradCmpConfig(window=spec.window, r1=int(r1),
                                    h1=spec.h1, h_min=spec.h_min,
                                    r_min=spec.r_min, mode=spec.h_mode)
        self.state = AdaGradCmpState.create(self.cfg)

    # ---- introspection ----------------------------------------------------
    @property
    def needs_spectral(self) -> bool:
        return self.spec.needs_spectral

    def executed(self) -> Tuple[int, int]:
        """(r_t, h_t) in force for the round about to run — the PRE-observe
        values, i.e. what the compressor will actually execute."""
        return self.state.r_t, self.state.h_t

    # ---- rank decisions ---------------------------------------------------
    def decide(self, compressor, shapes, topo, alive: np.ndarray,
               bws: Sequence[float], latency_s: float, t_compute_s: float,
               gossip: bool) -> Tuple[int, Optional[Dict[int, int]]]:
        """One round's full rank decision: ``(rank_t, ranks_map)`` where
        ``ranks_map`` is the per-cluster send-rank dict under gossip (None
        otherwise) and ``rank_t`` the round's headline rank (gossip: the
        max alive send rank — what a healthy edge runs at).

        This is the ONE implementation both simulator backends call with
        the same modeled inputs; the proc coordinator's broadcast schedule
        cannot drift from the in-process one by construction."""
        alive = np.asarray(alive, bool)
        alive_ids = [int(i) for i in np.flatnonzero(alive)]
        if not alive_ids:
            return self.executed()[0], None
        if gossip:
            ranks_map = self.ranks_gossip(compressor, shapes, topo, alive,
                                          bws, latency_s, t_compute_s)
            rank_t = (max(ranks_map.values()) if ranks_map
                      else self.executed()[0])
            return rank_t, ranks_map
        bw_bot = (float(min(bws[c] for c in alive_ids))
                  if len(alive_ids) >= 2 else 0.0)
        return self.rank_gather(compressor, shapes, len(alive_ids), bw_bot,
                                latency_s, t_compute_s), None

    def _max_rank_within(self, t_of_rank: Callable[[int], float],
                         budget_s: float) -> int:
        """Largest r in [r_min, r1] with t_of_rank(r) <= budget_s (t is
        monotone nondecreasing in r); clamped to r_min when even the floor
        does not fit — the controller never starves the subspace entirely."""
        lo, hi = self.cfg.r_min, self.cfg.r1
        if t_of_rank(hi) <= budget_s:
            return hi
        if t_of_rank(lo) > budget_s:
            return lo
        while hi - lo > 1:                 # invariant: t(lo)<=b < t(hi)
            mid = (lo + hi) // 2
            if t_of_rank(mid) <= budget_s:
                lo = mid
            else:
                hi = mid
        return lo

    def rank_gather(self, compressor, shapes, n_alive: int,
                    bw_bottleneck: float, latency_s: float,
                    t_compute_s: float) -> int:
        """Round rank for the hub/gather outer sync: spectral component
        clamped (bandwidth/hybrid) so the ring all-gather over the
        bottleneck link fits the overlap budget."""
        r_s = self.state.r_t
        if self.spec.mode == "spectral" or n_alive < 2 or bw_bottleneck <= 0:
            return r_s
        budget = self.spec.overlap_frac * t_compute_s

        def t_of(r: int) -> float:
            wire = compressor.wire_bytes(shapes, rank=r)
            return ((n_alive - 1) * wire / bw_bottleneck
                    + (n_alive - 1) * latency_s)

        r_b = self._max_rank_within(t_of, budget)
        return r_b if self.spec.mode == "bandwidth" else min(r_s, r_b)

    def ranks_gossip(self, compressor, shapes, topo, alive: np.ndarray,
                     bws: Sequence[float], latency_s: float,
                     t_compute_s: float) -> Dict[int, int]:
        """Per-EDGE ranks for a gossip round, keyed by *sending* cluster:
        every directed edge (c -> j) carries c's payload serialized on c's
        own uplink, so cluster c's send rank is the largest one whose
        ``deg_c`` neighbor sends still fit the overlap budget on ``bws[c]``.
        A degraded uplink therefore lowers the rank on its edges only."""
        alive = np.asarray(alive, bool)
        r_s = self.state.r_t
        budget = self.spec.overlap_frac * t_compute_s
        ranks: Dict[int, int] = {}
        for c in (int(i) for i in np.flatnonzero(alive)):
            deg = len(topo.alive_neighbors(c, alive))
            if deg == 0 or self.spec.mode == "spectral" or bws[c] <= 0:
                ranks[c] = r_s
                continue

            def t_of(r: int, c=c, deg=deg) -> float:
                wire = compressor.wire_bytes(shapes, rank=r)
                return deg * wire / float(bws[c]) + deg * latency_s

            r_b = self._max_rank_within(t_of, budget)
            ranks[c] = r_b if self.spec.mode == "bandwidth" else min(r_s, r_b)
        return ranks

    # ---- spectral feedback ------------------------------------------------
    def observe(self, mean_pending) -> None:
        """Advance Alg. 3 with the realized averaged pseudo-gradient (call
        AFTER logging the executed rank for the round)."""
        self.state = observe_mean_pseudo_grad(self.state, mean_pending,
                                              self.cfg)

    def observe_rank(self, r_prime: float) -> None:
        self.state = adagradcmp_update(self.state, _quantized_rank(r_prime),
                                       self.cfg)


# ---------------------------------------------------------------------------
# heterogeneous local-step scheduling: the per-cluster H leg
# ---------------------------------------------------------------------------

H_POLICIES = ("global", "balance")


@dataclass(frozen=True)
class HSpec:
    """Per-cluster local-step policy (JSON-able, embeddable in
    ``sim.Scenario``).

    The outer sync is a barrier on the slowest alive cluster, so a single
    global H makes every fast cluster idle for ``H*(t_slow - t_own)``
    seconds per round on heterogeneous hardware.  ``policy="balance"``
    sets each cluster's H from its *measured* step time so everyone lands
    near the barrier together: the fastest cluster keeps the full
    ``h_base`` budget and slower sites do proportionally fewer local
    steps (never more than ``h_base`` — the numeric legs mask a
    fixed-length scan, see ``core.diloco.masked_local_steps``).

    Under gossip topologies heterogeneous H is not free: a cluster that
    trains less drifts less per round, and the mixing graph only contracts
    the resulting disagreement at its spectral gap ``1 - |lambda_2|``.
    ``gap_clamp`` therefore floors every cluster's H at
    ``ceil(h_base * (1 - gap))`` — the slower the mixing, the closer the
    schedule must stay to uniform, so slow mixing cannot silently buy
    replica disagreement (the certificate is the masked mixing matrix's
    measured gap, quantized like the Alg. 3 rank signal).
    """
    policy: str = "balance"        # global | balance
    h_min: int = 1                 # hard floor (stragglers keep training)
    gap_clamp: bool = True         # gossip: clamp spread by spectral gap

    def __post_init__(self):
        if self.policy not in H_POLICIES:
            raise ValueError(f"h policy {self.policy!r} not in {H_POLICIES}")
        if self.h_min < 1:
            raise ValueError(f"h_min must be >= 1, got {self.h_min}")

    @property
    def active(self) -> bool:
        return self.policy != "global"

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "HSpec":
        return HSpec(**d)


def gap_h_floor(spec: Optional["HSpec"], h_base: int,
                spectral_gap: Optional[float]) -> int:
    """The gossip clamp: minimum per-cluster H allowed by the mixing
    matrix's spectral-gap certificate (``h_base`` itself when no gap is
    given, i.e. gather topologies realize the exact mean and never clamp).
    The gap is quantized before the arithmetic so a last-ulp difference
    between the two backends' eigensolves can never flip the floor."""
    floor = max(1, int(spec.h_min)) if spec is not None else 1
    if spec is not None and spec.gap_clamp and spectral_gap is not None:
        gap = min(1.0, max(0.0, round(float(spectral_gap), 6)))
        floor = max(floor, int(np.ceil(h_base * (1.0 - gap) - 1e-9)))
    return min(floor, int(h_base))


def plan_h(spec: Optional["HSpec"], h_base: int, t_steps: Sequence[float],
           alive: np.ndarray,
           spectral_gap: Optional[float] = None) -> Dict[int, int]:
    """One round's per-cluster local-step schedule: ``{cluster: h_c}`` over
    the alive set.

    ``balance`` anchors the round's compute target at the *fastest* alive
    cluster's full budget, ``T = h_base * min(t_c)``, and gives every
    cluster ``h_c = round(T / t_c)`` clamped to
    ``[max(h_min, gap floor), h_base]`` — slow sites do fewer local steps
    and the barrier tightens to ~T instead of ``h_base * max(t_c)``.
    Round-to-nearest (not floor) is what keeps the modeled barrier waste
    never above the global-H schedule's: a cluster whose ideal count
    rounds up to ``h_base`` simply reproduces the global schedule.

    Host-side python/numpy on the deterministic modeled step times — the
    ONE implementation both simulator backends call with identical inputs
    (same discipline as ``AdaptiveController.decide``), so the proc
    backend's broadcast H schedule cannot drift from the in-process one.
    Uniform step times produce the uniform ``h_base`` vector, which the
    numeric legs execute bit-for-bit identically to the scalar-H path.
    """
    alive = np.asarray(alive, bool)
    ids = [int(i) for i in np.flatnonzero(alive)]
    h_base = int(h_base)
    if spec is None or not spec.active or not ids:
        return {c: h_base for c in ids}
    floor = gap_h_floor(spec, h_base, spectral_gap)
    t_ref = min(float(t_steps[c]) for c in ids)
    target = h_base * t_ref
    out: Dict[int, int] = {}
    for c in ids:
        t_c = float(t_steps[c])
        h_c = h_base if t_c <= 0 else int(np.floor(target / t_c + 0.5))
        out[c] = max(floor, min(h_base, h_c))
    return out
