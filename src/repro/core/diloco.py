"""DiLoCoX round state machine (paper Alg. 2).

This module is the *algorithm*, independent of how clusters are realised:
``cluster_mean`` is injected (a stacked-axis mean in the single-host
simulation; an ``all_gather``+mean over the pod/data mesh axis in the
distributed runtime — see repro/train/trainer.py and launch/).

Semantics implemented (and their provenance):
 - Dual optimizer: inner AdamW for H local steps, outer Nesterov on averaged
   pseudo-gradients (§2.2). Inner state persists across rounds.
 - One-step-delay overlap (§2.3): round t averages delta^{t-1} (dataflow-
   independent of the H inner steps -> XLA can overlap the collective), and
   the outer update applied at the end of round t uses the DELAYED
   Delta^{t-1}:   theta^t = OuterOpt(theta^{t-1}, Delta^{t-1}).
   Local round-t progress reaches global params one round late, through the
   averaged pseudo-gradient — replicas restart from the outer-updated params
   every round, exactly as in DiLoCo.
 - Error feedback (Alg. 2 verbatim): e^t = delta^{t-1} - Delta^{t-1} (error
   vs the *global average*; ``error_vs_own=True`` switches to classic EF
   e = delta - C(delta), used in an ablation).
 - Compression: any ``core.compression.Compressor``; rank annealed by
   ``core.adaptive`` between rounds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compression import Compressor
from repro.optim import nesterov


class DiLoCoXState(NamedTuple):
    params: Any               # global params theta_t (post outer updates)
    inner_opt: Any            # per-cluster inner AdamW state (stacked)
    outer_opt: Any            # outer Nesterov state (fp32, param-shaped)
    delta_pending: Any        # per-cluster pseudo-grads awaiting averaging
    error: Any                # per-cluster error-feedback buffers
    comp_state: Any           # compressor warm starts (per cluster)
    t: jnp.ndarray            # outer step


def init_state(params, inner_opt_state, n_clusters: int,
               compressor: Compressor) -> DiLoCoXState:
    stack = lambda tree: jax.tree.map(
        lambda x: jnp.zeros((n_clusters,) + x.shape, jnp.float32), tree)
    comp0 = compressor.init_state(params)
    comp_stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_clusters,) + x.shape).copy()
        if hasattr(x, "shape") else x, comp0)
    return DiLoCoXState(
        params=params,
        inner_opt=inner_opt_state,
        outer_opt=nesterov.init(params),
        delta_pending=stack(params),
        error=stack(params),
        comp_state=comp_stacked,
        t=jnp.zeros((), jnp.int32),
    )


@dataclass
class RoundConfig:
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    delay: bool = True            # one-step-delay overlap (§2.3)
    compress: bool = True
    error_feedback: bool = True
    error_vs_own: bool = False    # classic EF instead of Alg. 2's variant


def per_cluster_compress(compressor: Compressor, stacked_tree, comp_state,
                         rank_scalar=None):
    """Compress each cluster's (cluster-stacked) tree with an unrolled
    per-cluster loop rather than ``jax.vmap``.

    A real cluster compresses its own delta with plain matmuls; vmap turns
    them into batched matmuls whose accumulation order differs by ~1 ulp in
    the PowerSGD warm-start Q.  Unrolling keeps the simulated stacked run
    bit-identical to N independent workers (the sim/proc equivalence gate),
    at the cost of C copies of the compressor in the HLO — C is the cluster
    count (2-8 everywhere in this repo), not a batch dimension.
    """
    n = jax.tree.leaves(stacked_tree)[0].shape[0]
    take = lambda tree, c: jax.tree.map(
        lambda x: x[c] if hasattr(x, "shape") and x.ndim >= 1 else x, tree)
    hats, states = [], []
    for c in range(n):
        hat, st = compressor.roundtrip(take(stacked_tree, c),
                                       take(comp_state, c), rank_scalar)
        hats.append(hat)
        states.append(st)
    stack = lambda trees: jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    return stack(hats), stack(states)


def diloco_round(state: DiLoCoXState,
                 inner_fn: Callable,          # (params, inner_opt, round_idx)
                                              #   -> (params_H, inner_opt')
                 compressor: Compressor,
                 cluster_mean: Callable,      # stacked tree -> mean tree
                 cfg: RoundConfig,
                 rank_scalar: Optional[jnp.ndarray] = None,
                 ):
    """One outer round (H inner steps + overlapped communication).
    Returns (new_state, aux) where aux comes from inner_fn (e.g. losses)."""
    anchor = state.params

    if cfg.delay:
        # ---- communication "thread": average LAST round's pseudo-grads.
        # Dataflow-independent of inner_fn below => overlappable by XLA.
        if cfg.compress:
            delta_hat, comp_state = per_cluster_compress(
                compressor, state.delta_pending, state.comp_state,
                rank_scalar)
        else:
            delta_hat, comp_state = state.delta_pending, state.comp_state
        Delta = cluster_mean(delta_hat)
        if cfg.error_feedback:
            if cfg.error_vs_own:
                err = jax.tree.map(lambda d, dh: d - dh,
                                   state.delta_pending, delta_hat)
            else:   # Alg. 2: e = delta^{t-1} - Delta^{t-1}
                err = jax.tree.map(lambda d, D: d - D[None],
                                   state.delta_pending, Delta)
        else:
            err = jax.tree.map(jnp.zeros_like, state.error)

        # ---- training "thread": H local steps from the current params.
        params_inner, inner_opt, aux = inner_fn(state.params,
                                                state.inner_opt, state.t)

        # ---- join: next round's pending pseudo-grads (+ error comp.)
        delta_new = jax.tree.map(
            lambda a, p, e: (a.astype(jnp.float32)[None]
                             - p.astype(jnp.float32)) + e,
            anchor, params_inner, err)

        # ---- delayed outer update on the ANCHOR (theta^{t-1})
        def outer_apply(params, outer_opt):
            return nesterov.update(Delta, outer_opt, params,
                                   lr=cfg.outer_lr,
                                   momentum=cfg.outer_momentum)

        # skip the very first round (no averaged Delta yet): Delta==0 anyway
        params_new, outer_opt = outer_apply(anchor, state.outer_opt)
    else:
        # ---- synchronous DiLoCo/OpenDiLoCo: train, then average THIS
        # round's pseudo-grads and apply immediately (no overlap).
        params_inner, inner_opt, aux = inner_fn(state.params,
                                                state.inner_opt, state.t)
        delta_raw = jax.tree.map(
            lambda a, p, e: (a.astype(jnp.float32)[None]
                             - p.astype(jnp.float32)) + e,
            anchor, params_inner, state.error)
        if cfg.compress:
            delta_hat, comp_state = per_cluster_compress(
                compressor, delta_raw, state.comp_state, rank_scalar)
        else:
            delta_hat, comp_state = delta_raw, state.comp_state
        Delta = cluster_mean(delta_hat)
        if cfg.error_feedback:
            if cfg.error_vs_own:
                err = jax.tree.map(lambda d, dh: d - dh, delta_raw, delta_hat)
            else:
                err = jax.tree.map(lambda d, D: d - D[None], delta_raw, Delta)
        else:
            err = jax.tree.map(jnp.zeros_like, state.error)
        delta_new = jax.tree.map(jnp.zeros_like, state.delta_pending)
        params_new, outer_opt = nesterov.update(
            Delta, state.outer_opt, anchor,
            lr=cfg.outer_lr, momentum=cfg.outer_momentum)
        # pending stays zero in sync mode; error carries to next round
        delta_new = delta_new if cfg.delay else delta_new

    return DiLoCoXState(
        params=params_new, inner_opt=inner_opt, outer_opt=outer_opt,
        delta_pending=(delta_new if cfg.delay else
                       jax.tree.map(jnp.zeros_like, state.delta_pending)),
        error=err, comp_state=comp_state, t=state.t + 1), aux
