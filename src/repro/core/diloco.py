"""DiLoCoX round state machine (paper Alg. 2).

This module is the *algorithm*, independent of how clusters are realised:
``cluster_mean`` is injected (a stacked-axis mean in the single-host
simulation; an ``all_gather``+mean over the pod/data mesh axis in the
distributed runtime — see repro/train/trainer.py and launch/; a
neighbor-gossip mix from ``repro.topology.mixing`` in the decentralized
non-hub setting).

Semantics implemented (and their provenance):
 - Dual optimizer: inner AdamW for H local steps, outer Nesterov on averaged
   pseudo-gradients (§2.2). Inner state persists across rounds.
 - One-step-delay overlap (§2.3): round t averages delta^{t-1} (dataflow-
   independent of the H inner steps -> XLA can overlap the collective), and
   the outer update applied at the end of round t uses the DELAYED
   Delta^{t-1}:   theta^t = OuterOpt(theta^{t-1}, Delta^{t-1}).
   Local round-t progress reaches global params one round late, through the
   averaged pseudo-gradient — replicas restart from the outer-updated params
   every round, exactly as in DiLoCo.
 - Error feedback (Alg. 2 verbatim): e^t = delta^{t-1} - Delta^{t-1} (error
   vs the *global average*; ``error_vs_own=True`` switches to classic EF
   e = delta - C(delta), used in an ablation).
 - Compression: any ``core.compression.Compressor``; rank annealed by
   ``core.adaptive`` between rounds.
 - Gossip topologies: when the injected averaging op is tagged
   ``returns_stacked=True`` (see ``repro.topology.mixing.mixing_op``) the
   round runs in *gossip mode*: ``state.params`` carries one row per
   cluster, each cluster averages compressed pseudo-gradients over its
   graph neighborhood only, and the outer Nesterov update applies
   row-wise.  Per-cluster params are no longer identical after the outer
   step — consensus lives in the (membership-masked) row mean, which
   evolves exactly like the gather trajectory because the mixing matrix
   is doubly stochastic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compression import Compressor
from repro.optim import nesterov


class DiLoCoXState(NamedTuple):
    params: Any               # global params theta_t (post outer updates);
                              # gossip mode: one row per cluster (stacked)
    inner_opt: Any            # per-cluster inner AdamW state (stacked)
    outer_opt: Any            # outer Nesterov state (fp32, param-shaped;
                              # gossip mode: stacked like params)
    delta_pending: Any        # per-cluster pseudo-grads awaiting averaging
    error: Any                # per-cluster error-feedback buffers
    comp_state: Any           # compressor warm starts (per cluster)
    t: jnp.ndarray            # outer step


def take_row(tree: Any, c: int) -> Any:
    """Cluster c's slice of a cluster-stacked pytree (non-arrays pass
    through)."""
    return jax.tree.map(
        lambda x: x[c] if hasattr(x, "shape") and x.ndim >= 1 else x, tree)


def stack_replicas(tree: Any, n_clusters: int) -> Any:
    """Broadcast an unstacked tree to one identical row per cluster (the
    gossip-mode initial state: every cluster starts from the same params)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_clusters,) + x.shape).copy(), tree)


def init_state(params, inner_opt_state, n_clusters: int,
               compressor: Compressor, *,
               stacked_params: bool = False) -> DiLoCoXState:
    """Round-0 state.  ``stacked_params=True`` is gossip mode: ``params``
    already carries the (n_clusters, ...) leading axis (see
    ``stack_replicas``) and the outer optimizer state is stacked with it."""
    if stacked_params:
        lead = jax.tree.leaves(params)[0].shape[0]
        if lead != n_clusters:
            raise ValueError(f"stacked params lead dim {lead} != "
                             f"n_clusters {n_clusters}")
        buf = lambda: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        comp0 = compressor.init_state(take_row(params, 0))
    else:
        buf = lambda: jax.tree.map(
            lambda x: jnp.zeros((n_clusters,) + x.shape, jnp.float32),
            params)
        comp0 = compressor.init_state(params)
    comp_stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_clusters,) + x.shape).copy()
        if hasattr(x, "shape") else x, comp0)
    return DiLoCoXState(
        params=params,
        inner_opt=inner_opt_state,
        outer_opt=nesterov.init(params),
        delta_pending=buf(),
        error=buf(),
        comp_state=comp_stacked,
        t=jnp.zeros((), jnp.int32),
    )


@dataclass
class RoundConfig:
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    delay: bool = True            # one-step-delay overlap (§2.3)
    compress: bool = True
    error_feedback: bool = True
    error_vs_own: bool = False    # classic EF instead of Alg. 2's variant


def per_cluster_compress(compressor: Compressor, stacked_tree, comp_state,
                         rank_scalar=None):
    """Compress each cluster's (cluster-stacked) tree with an unrolled
    per-cluster loop rather than ``jax.vmap``.

    A real cluster compresses its own delta with plain matmuls; vmap turns
    them into batched matmuls whose accumulation order differs by ~1 ulp in
    the PowerSGD warm-start Q.  Unrolling keeps the simulated stacked run
    bit-identical to N independent workers (the sim/proc equivalence gate),
    at the cost of C copies of the compressor in the HLO — C is the cluster
    count (2-8 everywhere in this repo), not a batch dimension.

    ``rank_scalar`` may be a scalar (one adaptive rank for everyone) or a
    (n_clusters,) vector of per-cluster send ranks — the bandwidth-aware
    controller's per-EDGE annealing under gossip topologies, where a
    degraded uplink compresses harder on its own edges only.
    """
    n = jax.tree.leaves(stacked_tree)[0].shape[0]
    per_cluster_rank = (rank_scalar is not None
                        and getattr(rank_scalar, "ndim", 0) >= 1)
    hats, states = [], []
    for c in range(n):
        r_c = rank_scalar[c] if per_cluster_rank else rank_scalar
        hat, st = compressor.roundtrip(take_row(stacked_tree, c),
                                       take_row(comp_state, c), r_c)
        hats.append(hat)
        states.append(st)
    stack = lambda trees: jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    return stack(hats), stack(states)


def masked_local_steps(step_fn, carry, h_max: int, h):
    """Run the first ``h`` (traced) of ``h_max`` (static) local steps.

    ``step_fn(carry, i) -> (carry', loss)`` is the usual inner-loop scan
    body; steps ``i >= h`` still trace but their carry is discarded by a
    ``select`` whose true branch returns the computed value *bitwise* —
    with ``h == h_max`` every step is taken and the result is bit-for-bit
    identical to the plain unmasked scan (the uniform-schedule guarantee
    the per-cluster-H tests pin, same discipline as
    ``per_cluster_compress``).  A proc worker calling this with its own
    scalar ``h`` and the in-process simulator vmapping it over an
    ``h_vec`` execute the identical op sequence per cluster.

    Returns ``(carry, mean_loss)`` where the mean is over the ``h`` steps
    actually applied.
    """
    h = jnp.asarray(h, jnp.int32)

    def body(carry, i):
        new, loss = step_fn(carry, i)
        take = i < h
        keep = jax.tree.map(lambda n, o: jnp.where(take, n, o), new, carry)
        return keep, jnp.where(take, loss, 0.0).astype(jnp.float32)

    carry, losses = jax.lax.scan(body, carry, jnp.arange(h_max))
    mean = losses.sum() / jnp.maximum(h.astype(jnp.float32), 1.0)
    return carry, mean


def _per_cluster_view(Delta, gossip: bool):
    """Delta as one row per cluster: gossip mixes already return stacked
    rows; the gather mean broadcasts (bitwise identical to the historical
    ``D[None]`` arithmetic)."""
    if gossip:
        return Delta
    return jax.tree.map(lambda D: D[None], Delta)


def _error_feedback(cfg: "RoundConfig", delta_ref, delta_hat, Delta_rows,
                    error_like, gossip: bool):
    """Alg. 2 EF ``e = delta - Delta`` (vs the average actually applied),
    or classic ``e = delta - C(delta)`` with ``error_vs_own`` — one
    implementation for the delay and sync arms.

    Gossip mode ALWAYS uses the classic compressor-local form: Alg. 2's
    ``delta - Delta`` telescopes only when Delta is the global mean; under
    partial neighborhood mixing it re-injects the ``(I - W) delta``
    deviation every round, and ``I - W`` has spectral radius > 1 on
    bipartite-ish graphs (ring), which blows the replicas apart
    exponentially.  Classic EF compensates exactly the compression
    residual and stays bounded.
    """
    if not cfg.error_feedback:
        return jax.tree.map(jnp.zeros_like, error_like)
    if cfg.error_vs_own or gossip:
        return jax.tree.map(lambda d, dh: d - dh, delta_ref, delta_hat)
    return jax.tree.map(lambda d, D: d - D, delta_ref, Delta_rows)


def pseudo_grad(anchor, params_local, error=None):
    """Single-cluster pseudo-gradient delta = (theta_anchor - theta_local)
    + e, fp32, no leading cluster axis — the delta-extraction arithmetic
    shared by the proc worker's EF leg and the sharded pipeline-parallel
    inner engine (``parallel.inner_engine.extract_delta``).  One
    implementation keeps the two engines' deltas definitionally identical;
    the stacked round loop uses the ``_pseudo_grad`` variants below."""
    if error is None:
        error = jax.tree.map(
            lambda a: jnp.zeros(a.shape, jnp.float32), anchor)
    return jax.tree.map(
        lambda a, p, e: (a.astype(jnp.float32)
                         - p.astype(jnp.float32)) + e,
        anchor, params_local, error)


def staleness_weights(base_w, staleness, max_staleness: int):
    """Alg. 2 outer-step mixing weights under bounded staleness.

    ``base_w``: (C,) float push-sum / neighborhood weights for the
    committing cluster (``topology.mixing.async_mix_weights`` row);
    ``staleness``: (C,) int rounds-stale of each peer's freshest published
    delta (<0 = no usable delta).  A delta ``s`` rounds old is discounted
    by ``1/(1+s)`` — the SSP-style linear decay — and anything beyond the
    ``max_staleness`` bound (or unpublished, or outside the neighborhood)
    gets weight exactly 0.  The result feeds
    ``membership.masked_cluster_mean`` as a *float* mask, whose
    sum-normalization is precisely the push-sum ``x/phi`` debiasing: the
    weighted mean stays unbiased no matter how asymmetric the incorporated
    set is.

    Host-side numpy (like ``adaptive.plan_h``): both sim backends compute
    the same float64 weights from the same engine-provided staleness
    vector, so the jitted aggregation consumes bit-identical inputs.
    """
    import numpy as np

    w = np.asarray(base_w, np.float64).copy()
    s = np.asarray(staleness, np.int64)
    ok = (s >= 0) & (s <= int(max_staleness))
    w = np.where(ok, w / (1.0 + np.maximum(s, 0)), 0.0)
    return w.astype(np.float32)


def _pseudo_grad(anchor, params_inner, err, gossip: bool):
    """delta = (theta_anchor - theta_local) + e, per cluster."""
    if gossip:
        return jax.tree.map(
            lambda a, p, e: (a.astype(jnp.float32)
                             - p.astype(jnp.float32)) + e,
            anchor, params_inner, err)
    return jax.tree.map(
        lambda a, p, e: (a.astype(jnp.float32)[None]
                         - p.astype(jnp.float32)) + e,
        anchor, params_inner, err)


def diloco_round(state: DiLoCoXState,
                 inner_fn: Callable,          # (params, inner_opt, round_idx)
                                              #   -> (params_H, inner_opt')
                 compressor: Compressor,
                 cluster_mean: Callable,      # stacked tree -> mean tree, or
                                              # (returns_stacked=True) a
                                              # stacked gossip mix
                 cfg: RoundConfig,
                 rank_scalar: Optional[jnp.ndarray] = None,
                 ):
    """One outer round (H inner steps + overlapped communication).
    Returns (new_state, aux) where aux comes from inner_fn (e.g. losses).

    ``cluster_mean`` decides the communication pattern: a plain callable is
    the global (possibly membership-masked) mean — the hub/gather outer
    step; a callable tagged ``returns_stacked=True`` (from
    ``repro.topology.mixing.mixing_op``) is a neighbor gossip mix and the
    state must have been built with ``init_state(..., stacked_params=True)``.
    """
    anchor = state.params
    gossip = bool(getattr(cluster_mean, "returns_stacked", False))

    if cfg.delay:
        # ---- communication "thread": average LAST round's pseudo-grads.
        # Dataflow-independent of inner_fn below => overlappable by XLA.
        if cfg.compress:
            delta_hat, comp_state = per_cluster_compress(
                compressor, state.delta_pending, state.comp_state,
                rank_scalar)
        else:
            delta_hat, comp_state = state.delta_pending, state.comp_state
        Delta = cluster_mean(delta_hat)
        Delta_rows = _per_cluster_view(Delta, gossip)
        err = _error_feedback(cfg, state.delta_pending, delta_hat,
                              Delta_rows, state.error, gossip)

        # ---- training "thread": H local steps from the current params.
        params_inner, inner_opt, aux = inner_fn(state.params,
                                                state.inner_opt, state.t)

        # ---- join: next round's pending pseudo-grads (+ error comp.)
        delta_new = _pseudo_grad(anchor, params_inner, err, gossip)

        # ---- delayed outer update on the ANCHOR (theta^{t-1}); round 0
        # applies Delta==0 (no pending delta yet), i.e. a no-op step.
        params_new, outer_opt = nesterov.update(
            Delta, state.outer_opt, anchor,
            lr=cfg.outer_lr, momentum=cfg.outer_momentum)
    else:
        # ---- synchronous DiLoCo/OpenDiLoCo: train, then average THIS
        # round's pseudo-grads and apply immediately (no overlap).
        params_inner, inner_opt, aux = inner_fn(state.params,
                                                state.inner_opt, state.t)
        delta_raw = _pseudo_grad(anchor, params_inner, state.error, gossip)
        if cfg.compress:
            delta_hat, comp_state = per_cluster_compress(
                compressor, delta_raw, state.comp_state, rank_scalar)
        else:
            delta_hat, comp_state = delta_raw, state.comp_state
        Delta = cluster_mean(delta_hat)
        Delta_rows = _per_cluster_view(Delta, gossip)
        err = _error_feedback(cfg, delta_raw, delta_hat, Delta_rows,
                              state.error, gossip)
        delta_new = None          # pending stays zero in sync mode; error
                                  # carries to next round
        params_new, outer_opt = nesterov.update(
            Delta, state.outer_opt, anchor,
            lr=cfg.outer_lr, momentum=cfg.outer_momentum)

    return DiLoCoXState(
        params=params_new, inner_opt=inner_opt, outer_opt=outer_opt,
        delta_pending=(delta_new if delta_new is not None else
                       jax.tree.map(jnp.zeros_like, state.delta_pending)),
        error=err, comp_state=comp_state, t=state.t + 1), aux


def diloco_round_h(state: DiLoCoXState,
                   inner_fn_h: Callable,      # (params, inner_opt, round_idx,
                                              #   h_vec) -> (params_H, opt',
                                              #   aux)
                   compressor: Compressor,
                   cluster_mean: Callable,
                   cfg: RoundConfig,
                   h_vec,                     # (n_clusters,) int32 local-step
                                              # counts, one per cluster row
                   rank_scalar: Optional[jnp.ndarray] = None,
                   ):
    """Per-cluster-H round entry point: identical to ``diloco_round`` except
    the inner function receives a per-cluster local-step vector (each
    cluster runs its own ``h_vec[c]`` steps of a shared fixed-length
    masked scan — see ``masked_local_steps``).  A uniform ``h_vec`` is
    bit-for-bit identical to the scalar-H path through the same
    ``inner_fn_h``; the schedule itself comes from
    ``core.adaptive.plan_h``.
    """
    inner = lambda params, inner_opt, t: inner_fn_h(params, inner_opt, t,
                                                    h_vec)
    return diloco_round(state, inner, compressor, cluster_mean, cfg,
                        rank_scalar)
