"""Gradient/pseudo-gradient compressors (paper §2.4).

The DiLoCoX compressor (Alg. 1) is ``Quantize_q ∘ LowRank_r``:
PowerSGD-style single-iteration subspace projection with a persistent
warm-start Q per 2-D-reshaped parameter, followed by block-wise symmetric
int-q quantization of the two factors. It is gather-compatible (the wire
payload is the packed factors), which is how the outer collective stays at
compressed size in the compiled HLO (DESIGN.md §3).

Baselines from the paper's comparison are here too: Top-K, random
sparsification, CocktailSGD (random ∘ top-k ∘ quant), fp16/no-op
(OpenDiLoCo).

Adaptive rank: to stay jit-shape-stable while Alg. 3 anneals r_t, factors
are allocated at ``r_max`` and columns >= r_t are zero-masked at runtime;
wire-byte accounting uses r_t. Semantics match a true rank-r_t compressor.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# quantization (simulation numerics; kernels/quant4.py is the wire format)
# ---------------------------------------------------------------------------

def quantize_sim(x: jnp.ndarray, bits: int, block: int = 256) -> jnp.ndarray:
    """Symmetric per-block quantize->dequantize (value-faithful simulation of
    the packed wire format; kernels/ops.quant_dequant matches this)."""
    if bits >= 32:
        return x
    if bits == 16:
        return x.astype(jnp.bfloat16).astype(x.dtype)
    orig_shape = x.shape
    n = x.size
    pad = (-n) % block
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, block)
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xf / scale), -qmax - 1, qmax)
    out = (q * scale).reshape(-1)[:n].reshape(orig_shape)
    return out.astype(x.dtype)


def quant_wire_bytes(n_elems: int, bits: int, block: int = 256) -> int:
    payload = math.ceil(n_elems * bits / 8)
    scales = math.ceil(n_elems / block) * 2          # bf16 scales
    return payload + scales


# ---------------------------------------------------------------------------
# 2-D reshape helpers (PowerSGD operates per-matrix)
# ---------------------------------------------------------------------------

def to_matrix(x: jnp.ndarray) -> jnp.ndarray:
    if x.ndim <= 1:
        return x.reshape(1, -1)
    # merge all leading dims; keep last dim as columns (weights are (in, out))
    return x.reshape(-1, x.shape[-1])


def matrix_shape(shape: Tuple[int, ...]) -> Tuple[int, int]:
    if len(shape) <= 1:
        return (1, math.prod(shape) if shape else 1)
    m = 1
    for s in shape[:-1]:
        m *= s
    return (m, shape[-1])


def _orthonormalize(P: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Cholesky-QR: G = P^T P + eps_rel*I, P <- P L^{-T}. All-matmul (MXU
    friendly) and GSPMD-shardable, unlike Householder QR which gathers the
    tall matrix; zero (rank-masked) columns stay zero.

    eps is RELATIVE to mean(diag(G)): pseudo-gradients are ~1e-2 scale, so
    an absolute 1e-6 ridge dominated P^T P and mangled the reconstruction
    (DiLoCoX training silently stalled — caught by the convergence-ordering
    integration tests)."""
    Pf = P.astype(jnp.float32)
    r = Pf.shape[-1]
    G = Pf.T @ Pf
    scale = jnp.trace(G) / r
    ridge = eps * jnp.maximum(scale, 1e-30) + 1e-30
    L = jnp.linalg.cholesky(G + ridge * jnp.eye(r, dtype=jnp.float32))
    Linv = jax.scipy.linalg.solve_triangular(
        L, jnp.eye(r, dtype=jnp.float32), lower=True)
    out = Pf @ Linv.T
    return jnp.where(jnp.isfinite(out), out, 0.0)


# ---------------------------------------------------------------------------
# compressor base protocol
# ---------------------------------------------------------------------------

class Compressor:
    """compress(tree, state) -> (payload_tree, state); decompress(payload) ->
    tree. ``roundtrip`` fuses both (what the convergence sim uses).
    ``wire_bytes(tree_shapes)`` is the analytic on-the-wire size."""

    name = "identity"

    def init_state(self, params) -> Any:
        return jnp.zeros((), jnp.int32)

    def roundtrip(self, tree, state, rank_scalar=None):
        return tree, state

    def wire_bytes(self, shapes: Dict[str, Tuple[int, ...]],
                   rank: Optional[int] = None) -> int:
        return sum(math.prod(s) * 4 for s in shapes.values())

    def wire_bytes_per_edge(self, shapes: Dict[str, Tuple[int, ...]],
                            ranks: Dict[int, int]) -> Dict[int, int]:
        """Per-sender payload sizes under per-edge adaptive ranks: ``ranks``
        maps cluster id -> the rank that cluster compresses at for its own
        uplink (the bandwidth-aware controller's gossip decision — every
        directed edge carries the sender's payload)."""
        return {c: int(self.wire_bytes(shapes, rank=r))
                for c, r in ranks.items()}


def tree_shapes(tree) -> Dict[str, Tuple[int, ...]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): tuple(x.shape) for p, x in flat}


@dataclass
class Identity(Compressor):
    name: str = "allreduce_fp32"


@dataclass
class FP16(Compressor):
    """OpenDiLoCo's FP16 pseudo-gradient compression."""
    name: str = "fp16"

    def roundtrip(self, tree, state, rank_scalar=None):
        return jax.tree.map(
            lambda x: x.astype(jnp.bfloat16).astype(x.dtype), tree), state

    def wire_bytes(self, shapes, rank=None):
        return sum(math.prod(s) * 2 for s in shapes.values())


@dataclass
class QuantOnly(Compressor):
    bits: int = 4
    block: int = 256
    name: str = "quant"

    def roundtrip(self, tree, state, rank_scalar=None):
        return jax.tree.map(
            lambda x: quantize_sim(x, self.bits, self.block), tree), state

    def wire_bytes(self, shapes, rank=None):
        return sum(quant_wire_bytes(math.prod(s), self.bits,
                                    self.block) for s in shapes.values())


# ---------------------------------------------------------------------------
# DiLoCoX: LowRank r ∘ Quantize q  (Alg. 1)
# ---------------------------------------------------------------------------

@dataclass
class LowRankQuant(Compressor):
    rank: int = 64                 # r_max (adaptive r_t <= rank)
    bits: int = 4
    block: int = 256
    min_dim_for_lowrank: int = 64  # small tensors skip the low-rank stage
    name: str = "diloco_x"
    # "ref": the unfused jnp op-chain below.  "pallas": the fused
    # compress+EF kernel pipeline (kernels/fused_compress.py, interpret
    # mode on CPU) — same wire format bit-for-bit, reconstruction within a
    # documented reorder-ulp bound of the ref chain, identical adaptive-
    # rank masking contract.  Threaded through ``per_cluster_compress``
    # unchanged (the backend only changes what ``roundtrip`` dispatches
    # to); the proc/in-process equivalence gates stay bitwise per backend.
    backend: str = "ref"

    def __post_init__(self):
        if self.backend not in ("ref", "pallas"):
            raise ValueError(f"backend must be 'ref' or 'pallas', "
                             f"got {self.backend!r}")
        if self.backend == "pallas" and self.bits != 4:
            raise ValueError("the pallas backend implements the int4 wire "
                             f"format (bits=4); got bits={self.bits}")

    def init_state(self, params) -> Any:
        """Warm-start Q per matrix-shaped param (PowerSGD memory)."""
        def mk(x):
            m, n = matrix_shape(x.shape)
            if min(m, n) < self.min_dim_for_lowrank:
                return jnp.zeros((0,), jnp.float32)
            r = min(self.rank, m, n)
            key = jax.random.PRNGKey(zlib.crc32(str(x.shape).encode()) % (2 ** 31))
            return jax.random.normal(key, (n, r), jnp.float32)
        return jax.tree.map(mk, params)

    def _quant_only_pallas(self, x):
        """quantize_sim via the quant4 pallas kernels. Same elementwise f32
        op sequence; under jit both paths are bitwise equal. (Eagerly,
        quantize_sim's `amax / 7.0` is an exact IEEE divide while the
        interpreted kernel — always jitted — gets XLA's divide-by-constant
        → reciprocal-multiply rewrite, so scales can differ by 1 ulp.)"""
        from repro.kernels.quant4 import (quant4_pack_pallas,
                                          quant4_unpack_pallas)
        flat = x.reshape(-1).astype(jnp.float32)
        rows = -(-flat.size // self.block)
        p, s = quant4_pack_pallas(flat, self.block,
                                  rows_per_tile=min(rows, 1024))
        out = quant4_unpack_pallas(p, s, flat.size, self.block,
                                   rows_per_tile=min(rows, 1024))
        return out.reshape(x.shape).astype(x.dtype)

    def _one(self, x, q_prev, rank_scalar):
        m, n = matrix_shape(x.shape)
        if q_prev.size == 0:     # quant-only path for small/1-D tensors
            if self.backend == "pallas":
                return self._quant_only_pallas(x), q_prev
            return quantize_sim(x, self.bits, self.block), q_prev
        if self.backend == "pallas":
            from repro.kernels.fused_compress import fused_compress_ef
            M = to_matrix(x).astype(jnp.float32)
            hat, _, q_new, _ = fused_compress_ef(
                M, None, q_prev, rank_scalar, block=self.block,
                compute_error=False)
            return hat.reshape(x.shape).astype(x.dtype), q_new
        M = to_matrix(x).astype(jnp.float32)
        r = q_prev.shape[1]
        # rank mask: columns >= r_t contribute nothing (adaptive rank)
        if rank_scalar is not None:
            col_mask = (jnp.arange(r) < rank_scalar).astype(jnp.float32)
        else:
            col_mask = jnp.ones((r,), jnp.float32)
        P = M @ (q_prev * col_mask)                  # (m, r)
        P = _orthonormalize(P) * col_mask
        Q = M.T @ P                                  # (n, r)
        Pq = quantize_sim(P, self.bits, self.block)
        Qq = quantize_sim(Q, self.bits, self.block)
        out = (Pq @ Qq.T).reshape(x.shape).astype(x.dtype)
        # zero-input guard: with the one-step delay the FIRST pending delta
        # is all-zero; M.T P == 0 would zero the warm start and the
        # compressor never recovers (P = M @ 0 forever). Keep q_prev then.
        q_new = jnp.where(jnp.sum(Q * Q) > 0, Q, q_prev * col_mask)
        return out, q_new        # warm start with *unquantized* Q
    def roundtrip(self, tree, state, rank_scalar=None):
        flat, treedef = jax.tree.flatten(tree)
        flat_q = jax.tree.leaves(state)
        outs, new_q = [], []
        for x, q in zip(flat, flat_q):
            o, nq = self._one(x, q, rank_scalar)
            outs.append(o)
            new_q.append(nq)
        return treedef.unflatten(outs), treedef.unflatten(new_q)

    def wire_bytes(self, shapes, rank=None):
        r_eff = rank if rank is not None else self.rank
        total = 0
        for s in shapes.values():
            m, n = matrix_shape(s)
            if min(m, n) < self.min_dim_for_lowrank:
                total += quant_wire_bytes(m * n, self.bits, self.block)
            else:
                r = min(r_eff, self.rank, m, n)
                total += quant_wire_bytes((m + n) * r, self.bits, self.block)
        return total


# ---------------------------------------------------------------------------
# baselines: top-k / random / CocktailSGD
# ---------------------------------------------------------------------------

@dataclass
class TopK(Compressor):
    ratio: float = 0.01
    name: str = "topk"

    def roundtrip(self, tree, state, rank_scalar=None):
        def one(x):
            flat = x.reshape(-1)
            k = max(1, int(flat.size * self.ratio))
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            mask = jnp.zeros_like(flat).at[idx].set(1.0)
            return (flat * mask).reshape(x.shape)
        return jax.tree.map(one, tree), state

    def wire_bytes(self, shapes, rank=None):
        total = 0
        for s in shapes.values():
            n = math.prod(s)
            k = max(1, int(n * self.ratio))
            total += k * 4 + k * 4          # values + int32 indices
        return total


@dataclass
class RandomSparse(Compressor):
    ratio: float = 0.1
    seed: int = 0
    name: str = "random_sparse"

    def roundtrip(self, tree, state, rank_scalar=None):
        step = state

        def one(path, x):
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
                zlib.crc32(jax.tree_util.keystr(path).encode()) % (2 ** 31))
            mask = (jax.random.uniform(key, x.shape) < self.ratio)
            return jnp.where(mask, x / self.ratio, 0.0).astype(x.dtype)

        out = jax.tree_util.tree_map_with_path(one, tree)
        return out, step + 1

    def wire_bytes(self, shapes, rank=None):
        # seed is free; values are ratio * n
        return sum(int(math.prod(s) * self.ratio) * 4
                   for s in shapes.values())


@dataclass
class CocktailSGD(Compressor):
    """Random sparsify -> Top-K within the sample -> quantize (Wang et al.
    2023). Ratios per the paper's §4.1.3 hyperparameters."""
    random_ratio: float = 0.1
    topk_ratio: float = 0.08
    bits: int = 4
    seed: int = 0
    name: str = "cocktail"

    def roundtrip(self, tree, state, rank_scalar=None):
        step = state

        def one(path, x):
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
                zlib.crc32(jax.tree_util.keystr(path).encode()) % (2 ** 31))
            flat = x.reshape(-1)
            rmask = (jax.random.uniform(key, flat.shape) < self.random_ratio)
            sampled = jnp.where(rmask, flat, 0.0)
            k = max(1, int(flat.size * self.random_ratio * self.topk_ratio))
            _, idx = jax.lax.top_k(jnp.abs(sampled), k)
            tmask = jnp.zeros_like(flat).at[idx].set(1.0)
            kept = sampled * tmask
            return quantize_sim(kept, self.bits).reshape(x.shape)

        out = jax.tree_util.tree_map_with_path(one, tree)
        return out, step + 1

    def wire_bytes(self, shapes, rank=None):
        total = 0
        for s in shapes.values():
            n = math.prod(s)
            k = max(1, int(n * self.random_ratio * self.topk_ratio))
            total += quant_wire_bytes(k, self.bits) + k * 4   # + indices
        return total


def make_compressor(name: str, **kw) -> Compressor:
    table = {"identity": Identity, "allreduce_fp32": Identity, "fp16": FP16,
             "quant": QuantOnly, "diloco_x": LowRankQuant,
             "lowrank_quant": LowRankQuant, "topk": TopK,
             "random_sparse": RandomSparse, "cocktail": CocktailSGD}
    return table[name](**kw)
