"""Wire-honest mesh compression for the outer step.

The single-host simulator (core.compression) round-trips values; here the
compiled HLO itself must carry only *compressed* bytes across the cluster
axis, so the roofline parser reads honest numbers. Per 2-D parameter matrix
(per scan unit, per cluster):

    P = M Q_warm ; P <- CholeskyQR(P) ; Q = M^T P          (PowerSGD step)
    payload = (pack_int4(P), scales_P, pack_int4(Q), scales_Q)
    Delta   = mean_over_clusters( unpack(payload) )        <- the only op
                                                              crossing the
                                                              slow axis

The mean over the cluster-stacked payload forces GSPMD to move the uint8
payload (or at worst the same bytes in f32 — verified in the dry-run HLO by
the collective parser). 1-D/small leaves are quantized without low-rank.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import (_orthonormalize, matrix_shape,
                                    quant_wire_bytes)
from repro.kernels import ops as kops


@dataclass(frozen=True)
class MeshCompressionConfig:
    rank: int = 128
    bits: int = 4      # wire format is int4 (kernels/quant4) — Alg. 1's q=4;
                       # `bits` is used by the analytic accounting only
    block: int = 256
    min_dim_for_lowrank: int = 64


def _leaf_matrix_dims(shape: Tuple[int, ...]) -> Tuple[int, int, int]:
    """(n_lead, m, n): leading stacked dims (cluster/scan) are vmapped; the
    trailing 2 dims are the PowerSGD matrix."""
    if len(shape) <= 1:
        return (1, 1, shape[0] if shape else 1)
    m, n = shape[-2], shape[-1]
    lead = math.prod(shape[:-2]) if len(shape) > 2 else 1
    return lead, m, n


def init_q_state(params, cfg: MeshCompressionConfig):
    """Warm-start Q per leaf: (lead..., n, r) or empty for quant-only."""
    import zlib

    def mk(path, x):
        lead, m, n = _leaf_matrix_dims(x.shape)
        if min(m, n) < cfg.min_dim_for_lowrank:
            return jnp.zeros((0,), jnp.float32)
        r = min(cfg.rank, m, n)
        key = jax.random.PRNGKey(
            zlib.crc32(str((x.shape, "q")).encode()) % (2 ** 31))
        q = jax.random.normal(key, (n, r), jnp.float32)
        return jnp.broadcast_to(q, x.shape[:-2] + (n, r)).copy()

    return jax.tree_util.tree_map_with_path(mk, params)


def _compress_leaf_matrix(M, q_prev, rank_scalar, cfg: MeshCompressionConfig):
    """M: (m,n) f32; q_prev: (n,r). Returns (Delta_contrib_payload, Q_new)
    where payload = packed factors."""
    r = q_prev.shape[-1]
    if rank_scalar is not None:
        col_mask = (jnp.arange(r) < rank_scalar).astype(jnp.float32)
    else:
        col_mask = jnp.ones((r,), jnp.float32)
    P = kops.matmul(M, q_prev * col_mask)
    P = _orthonormalize(P) * col_mask
    Q = kops.matmul(M.T, P)
    pP, sP = kops.quant4_pack(P.reshape(-1), cfg.block)
    pQ, sQ = kops.quant4_pack(Q.reshape(-1), cfg.block)
    # zero-input guard (first delayed round): never zero the warm start
    q_new = jnp.where(jnp.sum(Q * Q) > 0, Q, q_prev * col_mask)
    return (pP, sP, pQ, sQ), q_new


def _decompress_leaf_matrix(payload, m, n, r, cfg: MeshCompressionConfig):
    pP, sP, pQ, sQ = payload
    P = kops.quant4_unpack(pP, sP, m * r, cfg.block).reshape(m, r)
    Q = kops.quant4_unpack(pQ, sQ, n * r, cfg.block).reshape(n, r)
    return kops.matmul(P, Q.T)


def compress_gather_mean(delta_stacked, q_state, rank_scalar,
                         cfg: MeshCompressionConfig):
    """delta_stacked: cluster-stacked pytree (C, ...). Returns
    (Delta mean tree (...), new q_state). The cross-cluster data movement is
    the packed payload (uint8 + scales)."""

    def one(path, d, q):
        C = d.shape[0]
        lead, m, n = _leaf_matrix_dims(d.shape[1:])
        if q.size == 0:
            # quant-only: pack per cluster, unpack all, mean
            flat = d.reshape(C, -1).astype(jnp.float32)
            pk, sc = jax.vmap(lambda v: kops.quant4_pack(v, cfg.block))(flat)
            vals = jax.vmap(
                lambda p, s: kops.quant4_unpack(p, s, flat.shape[1],
                                                cfg.block))(pk, sc)
            return vals.mean(0).reshape(d.shape[1:]).astype(d.dtype), q

        r = q.shape[-1]
        dm = d.reshape(C * lead, m, n).astype(jnp.float32)
        qm = q.reshape(C * lead, n, r)
        comp = jax.vmap(
            lambda M, qp: _compress_leaf_matrix(M, qp, rank_scalar, cfg))
        payload, q_new = comp(dm, qm)
        dec = jax.vmap(
            lambda pl: _decompress_leaf_matrix(pl, m, n, r, cfg))(payload)
        Delta = dec.reshape(C, lead, m, n).mean(0).reshape(d.shape[1:])
        return Delta.astype(d.dtype), q_new.reshape(q.shape)

    flat_d, treedef = jax.tree_util.tree_flatten_with_path(delta_stacked)
    flat_q = jax.tree.leaves(q_state)
    outs = [one(p, dd, qq) for (p, dd), qq in zip(flat_d, flat_q)]
    Delta = jax.tree.unflatten(treedef, [o[0] for o in outs])
    q_new = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return Delta, q_new


def wire_bytes_tree(params, cfg: MeshCompressionConfig,
                    rank: Optional[int] = None) -> int:
    """Analytic per-cluster payload bytes (for the comm model)."""
    total = 0
    for x in jax.tree.leaves(params):
        lead, m, n = _leaf_matrix_dims(x.shape)
        if min(m, n) < cfg.min_dim_for_lowrank:
            total += quant_wire_bytes(lead * m * n, cfg.bits, cfg.block)
        else:
            r = min(rank if rank is not None else cfg.rank, m, n)
            total += lead * (quant_wire_bytes(m * r, cfg.bits, cfg.block)
                             + quant_wire_bytes(n * r, cfg.bits, cfg.block))
    return total
