"""Cluster membership / dropout tolerance for the decentralized outer step.

Decentralized clusters (the paper's setting: independent sites over WAN
links) drop out and rejoin. The outer average must stay correct under a
changing participant set: Delta = sum_c m_c * C(delta_c) / sum_c m_c with
a liveness mask m — and a rejoining cluster must restart from the current
global params (it missed outer updates), which the Alg. 2 state machine
already provides (replicas restart from theta_t every round).

This module is pure algorithm (mask-weighted means + state resets) so it
composes with both the single-host simulator and the mesh runtime.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def masked_cluster_mean(stacked_tree: Any, alive: jnp.ndarray) -> Any:
    """Mean over the cluster axis counting only alive clusters.
    alive: (C,) float/bool mask. Falls back to a zero update if no cluster
    reported (sum mass 0) — the outer optimizer then applies momentum only.
    """
    mass = jnp.maximum(alive.astype(jnp.float32).sum(), 1e-9)

    def one(x):
        m = alive.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
        return (x * m).sum(axis=0) / mass.astype(x.dtype)

    return jax.tree.map(one, stacked_tree)


def trimmed_cluster_mean(stacked_tree: Any, alive: jnp.ndarray,
                         trim: int = 1) -> Any:
    """Coordinate-wise trimmed mean over the alive cluster rows: per
    coordinate, drop the ``trim`` largest and ``trim`` smallest values
    among the alive candidates and average the rest.

    This is the classic robust-aggregation defense against a Byzantine
    cluster publishing corrupted deltas (``sim.faults.Byzantine``): as
    long as at most ``trim`` rows are adversarial and ``2*trim <
    n_alive``, every surviving coordinate lies within the range of honest
    values, so the corrupted magnitude cannot enter the outer step.
    Robustness replaces weighting — callers pass a 0/1 mask (staleness
    discounts are ignored on purpose: a trimmed mean of re-weighted rows
    would lose the order statistics the defense relies on).

    Dead rows are pushed past the top of the sort with ``+inf`` so the
    alive candidates occupy the first ``n_alive`` slots; degenerate masks
    (``n_alive <= 2*trim``) fall back to a zero update, like the empty-
    mass case of ``masked_cluster_mean``.
    """
    m = jnp.asarray(alive, jnp.float32) > 0
    n_alive = m.sum().astype(jnp.int32)
    lo = jnp.asarray(trim, jnp.int32)
    hi = n_alive - trim

    def one(x):
        x32 = x.astype(jnp.float32)
        mb = m.reshape((-1,) + (1,) * (x.ndim - 1))
        ranked = jnp.sort(jnp.where(mb, x32, jnp.inf), axis=0)
        idx = jnp.arange(x.shape[0], dtype=jnp.int32).reshape(
            (-1,) + (1,) * (x.ndim - 1))
        inc = (idx >= lo) & (idx < hi)
        cnt = jnp.maximum(hi - lo, 1).astype(jnp.float32)
        return (jnp.where(inc, ranked, 0.0).sum(axis=0) / cnt).astype(
            x.dtype)

    return jax.tree.map(one, stacked_tree)


def masked_mixing_matrix(W: jnp.ndarray, alive: jnp.ndarray) -> jnp.ndarray:
    """Membership-masked row renormalization of a mixing matrix.

    Zeroes every row/column of a dead cluster and folds the lost off-
    diagonal mass back into each alive row's *self*-weight, so the alive
    block keeps rows summing to 1 while staying symmetric whenever ``W``
    is — i.e. it remains doubly stochastic over the alive set, which is
    what makes gossip still contract to the (alive) mean under churn.
    Dead rows become identity rows: a dead cluster's state passes through
    a mix untouched (it is masked out of every alive row anyway).

    Works on numpy or jax inputs (returns a jax array); the simulator and
    the proc coordinator both derive the per-round matrix through this one
    function so the two backends can never disagree on the weights.
    """
    W = jnp.asarray(W, jnp.float32)
    n = W.shape[0]
    m = jnp.asarray(alive, jnp.float32).reshape(n)
    eye = jnp.eye(n, dtype=jnp.float32)
    off = W * (1.0 - eye) * m[None, :] * m[:, None]
    diag = jnp.diag(1.0 - off.sum(axis=1))
    return jnp.where(m[:, None] > 0, off + diag, eye)


def reset_rejoining(stacked_tree: Any, rejoined: jnp.ndarray,
                    fill_value: float = 0.0) -> Any:
    """Zero per-cluster buffers (pending deltas, error feedback) of clusters
    that just rejoined — their stale local state predates the current
    global params and must not leak into the next average."""

    def one(x):
        m = rejoined.astype(bool).reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, jnp.full_like(x, fill_value), x)

    return jax.tree.map(one, stacked_tree)


def effective_batch_scale(alive: jnp.ndarray, n_clusters: int) -> jnp.ndarray:
    """Outer-lr compensation for lost data parallelism: with fewer clusters
    the averaged pseudo-gradient has higher variance; scale by
    sqrt(alive/C) (linear-scaling-rule analogue for the outer step)."""
    frac = alive.astype(jnp.float32).sum() / max(n_clusters, 1)
    return jnp.sqrt(jnp.maximum(frac, 1e-9))
