"""Pallas kernels for the compute hot-spots DiLoCoX actually optimizes.

Modules
-------
- ``lowrank_mm.py`` / ``quant4.py`` / ``flash.py``: single-op kernels
  (PowerSGD projections, block-256 symmetric int4 pack/unpack, flash
  attention) with eager references in ``ref.py``.
- ``fused_compress.py``: the fused Alg. 1+2 outer-step pipeline —
  one pass computes the EF-corrected delta (δ + e), its rank-r PowerSGD
  projection with f32 VMEM accumulation, block-wise int4 quantize+pack
  of both factors, and the *new* EF residual e' = (δ + e) − decompress,
  plus the decompress dual for the receive side.  The unfused oracle
  chain is ``ref.outer_step_ref``; ``ops.fused_outer_step`` dispatches
  between them on ``REPRO_USE_PALLAS=1``.

Adaptive-rank contract (jit shape stability)
--------------------------------------------
All rank-r entry points accept a traced ``rank_scalar`` r_t ≤ r_max and
keep every output at the static r_max shape, with columns ≥ r_t masked
to exactly zero (factors, warm-start Q, packed payload codes).  One
compiled executable therefore serves the whole Alg. 3 rank schedule.

Interpret mode vs real TPU
--------------------------
This repo runs the kernels in Pallas interpret mode on CPU, where each
grid step pays a Python-level tile copy — so the CPU lane favors
single-tile (full-matrix) grids and hoists the EF add into the driver.
On hardware the trade-offs invert (HBM traffic dominates, VMEM tiling
binds): keep the kernels' ``with_e`` fused path and real tile grids.
Per-module docstrings carry the specific caveats; numeric gates live in
``tests/test_kernels.py`` (bit-identical packing vs ``quant4_pack_ref``,
ulp-bounded reconstruction, exact decompress dual).
"""
