"""Pure-jnp oracles for every Pallas kernel (the correctness reference the
kernel tests assert_allclose against).

Wire format (int4, block-wise symmetric):
  packed: uint8, two int4 codes per byte (low nibble = even index)
  scales: float32, one per `block` elements
Numerics match core.compression.quantize_sim exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# int4 block quantization (pack / unpack)
# ---------------------------------------------------------------------------

def quant4_pack_ref(x: jnp.ndarray, block: int = 256):
    """x: flat (n,) f32, n % (2*block assumptions): pads internally.
    Returns (packed uint8 (ceil(n/2),), scales f32 (ceil(n/block),), n)."""
    n = x.shape[0]
    pad = (-n) % block
    xf = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, block)
    qmax = 7.0
    scale = jnp.max(jnp.abs(xf), axis=1) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xf / scale[:, None]), -8, 7).astype(jnp.int32)
    qu = (q & 0xF).astype(jnp.uint8).reshape(-1)          # two's complement
    lo = qu[0::2]
    hi = qu[1::2]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return packed, scale, n


def quant4_unpack_ref(packed: jnp.ndarray, scales: jnp.ndarray, n: int,
                      block: int = 256) -> jnp.ndarray:
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    codes = jnp.stack([lo, hi], axis=1).reshape(-1)
    codes = jnp.where(codes >= 8, codes - 16, codes)       # sign extend
    vals = codes.astype(jnp.float32).reshape(-1, block) * scales[:, None]
    return vals.reshape(-1)[:n]


def quant4_roundtrip_ref(x: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    shape = x.shape
    packed, scales, n = quant4_pack_ref(x.reshape(-1), block)
    return quant4_unpack_ref(packed, scales, n, block).reshape(shape)


# ---------------------------------------------------------------------------
# tiled matmul (PowerSGD projections G@Q / G^T@P)
# ---------------------------------------------------------------------------

def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(a.dtype)


# ---------------------------------------------------------------------------
# fused outer-step compressor (kernels/fused_compress.py) — unfused oracle
# ---------------------------------------------------------------------------

class FusedPayload(NamedTuple):
    """Wire payload of one compressed parameter matrix: packed int4 factor
    codes + per-block scales, in ``quant4_pack_ref``'s flat row-major
    layout.  ``p_factor``/``q_factor`` are the *pre-quantization* f32
    factors (warm-start/audit fields — they never go on the wire; the
    tests ref-pack them to assert the in-kernel pack is bit-identical)."""
    packed_p: jnp.ndarray     # uint8 (ceil(m*r/block) * block//2,)
    scales_p: jnp.ndarray     # f32   (ceil(m*r/block),)
    packed_q: jnp.ndarray     # uint8 (ceil(n*r/block) * block//2,)
    scales_q: jnp.ndarray     # f32   (ceil(n*r/block),)
    p_factor: jnp.ndarray     # f32 (m, r)
    q_factor: jnp.ndarray     # f32 (n, r)


def outer_step_ref(delta: jnp.ndarray, error, q_prev: jnp.ndarray,
                   rank_scalar=None, block: int = 256):
    """The unfused op-chain the fused Pallas pipeline replaces, one XLA op
    per arrow: EF add -> P = M Qm -> Cholesky-QR -> Q = M^T P -> quantize
    factors -> pack (wire) -> reconstruct -> EF residual.  Numerics match
    ``core.compression.LowRankQuant`` (``quantize_sim`` and
    ``quant4_pack_ref`` compute identical values) — this is both the
    correctness oracle for ``fused_compress_ef`` and the "before" side of
    the outer-step benchmark.  Returns (delta_hat, e_new, q_new, payload).
    """
    from repro.core.compression import _orthonormalize
    m, n = delta.shape
    r = q_prev.shape[1]
    M = delta.astype(jnp.float32)
    if error is not None:
        M = M + error.astype(jnp.float32)
    if rank_scalar is not None:
        cm = (jnp.arange(r) < rank_scalar).astype(jnp.float32)
    else:
        cm = jnp.ones((r,), jnp.float32)
    qm = q_prev.astype(jnp.float32) * cm
    P = M @ qm
    P = _orthonormalize(P) * cm
    Q = M.T @ P
    pP, sP, _ = quant4_pack_ref(P.reshape(-1), block)
    pQ, sQ, _ = quant4_pack_ref(Q.reshape(-1), block)
    Pq = quant4_unpack_ref(pP, sP, m * r, block).reshape(m, r)
    Qq = quant4_unpack_ref(pQ, sQ, n * r, block).reshape(n, r)
    rec = Pq @ Qq.T
    delta_hat = rec.astype(delta.dtype)
    e_new = M - rec
    q_new = jnp.where(jnp.sum(Q * Q) > 0, Q, qm)
    return delta_hat, e_new, q_new, FusedPayload(pP, sP, pQ, sQ, P, Q)


# ---------------------------------------------------------------------------
# flash attention (causal, GQA) — semantic oracle
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """q: (B,Sq,H,d); k,v: (B,Sk,KV,d). Plain softmax attention in f32."""
    B, Sq, H, d = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(d).astype(jnp.float32)
    if causal:
        Sk = k.shape[1]
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, d).astype(q.dtype)
