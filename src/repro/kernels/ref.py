"""Pure-jnp oracles for every Pallas kernel (the correctness reference the
kernel tests assert_allclose against).

Wire format (int4, block-wise symmetric):
  packed: uint8, two int4 codes per byte (low nibble = even index)
  scales: float32, one per `block` elements
Numerics match core.compression.quantize_sim exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# int4 block quantization (pack / unpack)
# ---------------------------------------------------------------------------

def quant4_pack_ref(x: jnp.ndarray, block: int = 256):
    """x: flat (n,) f32, n % (2*block assumptions): pads internally.
    Returns (packed uint8 (ceil(n/2),), scales f32 (ceil(n/block),), n)."""
    n = x.shape[0]
    pad = (-n) % block
    xf = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, block)
    qmax = 7.0
    scale = jnp.max(jnp.abs(xf), axis=1) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xf / scale[:, None]), -8, 7).astype(jnp.int32)
    qu = (q & 0xF).astype(jnp.uint8).reshape(-1)          # two's complement
    lo = qu[0::2]
    hi = qu[1::2]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return packed, scale, n


def quant4_unpack_ref(packed: jnp.ndarray, scales: jnp.ndarray, n: int,
                      block: int = 256) -> jnp.ndarray:
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    codes = jnp.stack([lo, hi], axis=1).reshape(-1)
    codes = jnp.where(codes >= 8, codes - 16, codes)       # sign extend
    vals = codes.astype(jnp.float32).reshape(-1, block) * scales[:, None]
    return vals.reshape(-1)[:n]


def quant4_roundtrip_ref(x: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    shape = x.shape
    packed, scales, n = quant4_pack_ref(x.reshape(-1), block)
    return quant4_unpack_ref(packed, scales, n, block).reshape(shape)


# ---------------------------------------------------------------------------
# tiled matmul (PowerSGD projections G@Q / G^T@P)
# ---------------------------------------------------------------------------

def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(a.dtype)


# ---------------------------------------------------------------------------
# flash attention (causal, GQA) — semantic oracle
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """q: (B,Sq,H,d); k,v: (B,Sk,KV,d). Plain softmax attention in f32."""
    B, Sq, H, d = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(d).astype(jnp.float32)
    if causal:
        Sk = k.shape[1]
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, d).astype(q.dtype)
