"""Fused Pallas kernels for the DiLoCoX outer-step compressor (Alg. 1).

The per-round compressor is the outer step's compute hot path: per
parameter matrix it runs  EF add -> PowerSGD project -> Cholesky-QR ->
back-project -> int4 quantize -> pack -> reconstruct -> EF residual.  As
separate XLA ops every arrow materializes an HBM-sized intermediate (the
EF-corrected delta alone is touched five times).  This module fuses the
chain into three Pallas kernels plus one tiny host-level r x r step:

  1. ``_proj_kernel``        P = (delta + e) @ (Q_warm * mask)
        The EF add happens on the operand tile in VMEM — the (m, n)
        corrected delta is never materialized.  Tiled matmul with f32
        VMEM accumulation (the ``lowrank_mm`` pattern).
  2. host: Cholesky-QR orthonormalize + rank mask.  An r x r Gram matrix,
        Cholesky, and triangular solve — a few hundred KB at r = 2048.
        Kept as jnp ops between the kernels (``core.compression``'s
        ``_orthonormalize`` is the single implementation; its relative-eps
        ridge lesson applies verbatim).
  3. ``_proj_t_pack_kernel`` Q = (delta + e)^T @ P, and on the final K
        step the flush quantizes the finished (bn, r) tile block-wise and
        packs two int4 codes per byte *in the same kernel* — the wire
        payload leaves the pallas_call; no separate quantize pass over Q.
        (P is packed by ``quant4.quant4_pack_pallas`` after the host
        orthonormalization step that sits between its projection and its
        quantization.)
  4. ``_recon_kernel``       delta_hat = dequant(P) @ dequant(Q)^T and
        e' = (delta + e) - delta_hat, both written by one grid cell from
        the *packed* factors — the decompress dual (unpack -> dequant ->
        P Q^T) fused with the error-feedback residual, so neither the
        dequantized factors nor the reconstruction round-trips HBM
        between ops.

Adaptive-rank contract (jit-shape-stable, from ``core.compression``):
factors are allocated at the warm start's full width ``r_max``; a traced
``rank_scalar`` zero-masks columns >= r_t.  Masked columns of P are
exactly zero, hence Q's masked columns are exactly zero, hence their
quantized codes are zero — wire-byte accounting may bill only r_t columns
while the arrays (and the compiled program) keep one shape.

Wire format is bit-identical to ``ref.quant4_pack_ref`` on the row-major
flattened factor: row tiles are chosen so ``tile_rows * r % block == 0``
(quantization blocks never straddle a tile boundary) and grid padding
appends zero rows only, which quantize to the same zero codes the
reference pads with.

Interpret-vs-TPU caveats: everything here runs under ``interpret=True``
on CPU (the correctness lane; it is jit-traceable, so the grid loops
compile).  The transposed projection accumulates Q^T via
``dot_general`` dimension_numbers (no ``m_tile.T`` relayout) — the
MXU-native form on TPU and ~1.6x faster on the CPU lane too.  On real
TPU: the flush-step reshapes used for packing prefer a (2, block/2)
sublane layout, and 1-D BlockSpecs (scales) should be widened to
(rows, 1).  The BlockSpec tiling — the part that carries to hardware —
is MXU-aligned as long as ``row_cap`` stays a multiple of 128; in
interpret mode each grid step pays a Python-level tile copy, so the
benchmark lane raises ``row_cap`` to cover the matrix in one tile
(grid-step overhead, not VMEM, is the binding constraint on CPU).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import FusedPayload


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _row_tile(dim: int, r: int, block: int, cap: int) -> int:
    """Row-tile size for an (dim, r) factor such that every tile holds a
    whole number of flat quantization blocks: tile * r % block == 0."""
    unit = block // math.gcd(r, block)
    full = _ceil_to(max(dim, 1), unit)
    if unit >= cap:
        return full if full <= unit else unit * (cap // unit or 1)
    return min(full, (cap // unit) * unit)


def _pad2d(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0, p1 = _ceil_to(x.shape[0], m0) - x.shape[0], \
        _ceil_to(x.shape[1], m1) - x.shape[1]
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _col_mask(r: int, rank_scalar) -> jnp.ndarray:
    if rank_scalar is None:
        return jnp.ones((r,), jnp.float32)
    return (jnp.arange(r) < rank_scalar).astype(jnp.float32)


# ---------------------------------------------------------------------------
# kernel 1: P = (D + E) @ Qm, EF add fused into the operand load
# ---------------------------------------------------------------------------

def _proj_kernel(*refs, n_k: int, with_e: bool):
    if with_e:
        d_ref, e_ref, q_ref, o_ref, acc_ref = refs
        m_tile = d_ref[...].astype(jnp.float32) + e_ref[...]
    else:
        d_ref, q_ref, o_ref, acc_ref = refs
        m_tile = d_ref[...].astype(jnp.float32)
    k = pl.program_id(1)
    prod = jnp.dot(m_tile, q_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _first():                       # no zero-init pass on step 0
        acc_ref[...] = prod

    @pl.when(k > 0)
    def _rest():
        acc_ref[...] += prod

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _project(d, e, qm, bm: int, bn: int, interpret: bool) -> jnp.ndarray:
    """(M_pad, N_pad) x (N_pad, r) -> (M_pad, r) f32; d/e pre-padded."""
    M, N = d.shape
    r = qm.shape[1]
    gm, gk = M // bm, N // bn
    with_e = e is not None
    in_specs = [pl.BlockSpec((bm, bn), lambda i, k: (i, k))]
    ins = [d]
    if with_e:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, k: (i, k)))
        ins.append(e)
    in_specs.append(pl.BlockSpec((bn, r), lambda i, k: (k, 0)))
    ins.append(qm)
    return pl.pallas_call(
        functools.partial(_proj_kernel, n_k=gk, with_e=with_e),
        grid=(gm, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, r), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, r), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
    )(*ins)


# ---------------------------------------------------------------------------
# kernel 2: Q = (D + E)^T @ P with the int4 quantize+pack fused in the flush
# ---------------------------------------------------------------------------

def _quant_pack_tile(q: jnp.ndarray, block: int):
    """(rows, r) f32 -> (packed (nblk, block//2) uint8, scales (nblk,)).
    Exactly ``ref.quant4_pack_ref`` on the row-major flat tile."""
    nblk = q.size // block
    flat = q.reshape(nblk, block)
    amax = jnp.max(jnp.abs(flat), axis=1)
    scale = jnp.where(amax == 0.0, 1.0, amax / 7.0)
    codes = jnp.clip(jnp.round(flat / scale[:, None]), -8, 7).astype(
        jnp.int32)
    qu = (codes & 0xF).astype(jnp.uint8)
    pair = qu.reshape(nblk, block // 2, 2)
    return pair[:, :, 0] | (pair[:, :, 1] << 4), scale


def _proj_t_pack_kernel(*refs, n_k: int, with_e: bool, block: int):
    if with_e:
        d_ref, e_ref, p_ref, q_ref, packed_ref, scale_ref, acc_ref = refs
        m_tile = d_ref[...].astype(jnp.float32) + e_ref[...]
    else:
        d_ref, p_ref, q_ref, packed_ref, scale_ref, acc_ref = refs
        m_tile = d_ref[...].astype(jnp.float32)
    k = pl.program_id(1)
    # accumulate Q^T = P^T (D+E): dimension_numbers contract row axes
    # directly instead of relaying out m_tile.T — ~1.6x faster on the CPU
    # lane and the MXU-native form on TPU (no transpose unit pass).
    prod = jax.lax.dot_general(
        p_ref[...], m_tile, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _first():                       # no zero-init pass on step 0
        acc_ref[...] = prod

    @pl.when(k > 0)
    def _rest():
        acc_ref[...] += prod

    @pl.when(k == n_k - 1)
    def _flush():
        q = acc_ref[...].T                  # (bn, r): row-major factor tile
        q_ref[...] = q
        packed, scale = _quant_pack_tile(q, block)
        packed_ref[...] = packed
        scale_ref[...] = scale


def _project_t_pack(d, e, p, bm: int, bn: int, block: int, interpret: bool):
    """Q = (D+E)^T @ P plus fused pack.  Returns (Q (N_pad, r) f32,
    packed (N_pad*r//block, block//2) uint8, scales (N_pad*r//block,))."""
    M, N = d.shape
    r = p.shape[1]
    gn, gk = N // bn, M // bm
    nblk_tile = bn * r // block
    with_e = e is not None
    in_specs = [pl.BlockSpec((bm, bn), lambda i, k: (k, i))]
    ins = [d]
    if with_e:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, k: (k, i)))
        ins.append(e)
    in_specs.append(pl.BlockSpec((bm, r), lambda i, k: (k, 0)))
    ins.append(p)
    return pl.pallas_call(
        functools.partial(_proj_t_pack_kernel, n_k=gk, with_e=with_e,
                          block=block),
        grid=(gn, gk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bn, r), lambda i, k: (i, 0)),
            pl.BlockSpec((nblk_tile, block // 2), lambda i, k: (i, 0)),
            pl.BlockSpec((nblk_tile,), lambda i, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, r), jnp.float32),
            jax.ShapeDtypeStruct((N * r // block, block // 2), jnp.uint8),
            jax.ShapeDtypeStruct((N * r // block,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((r, bn), jnp.float32)],
        interpret=interpret,
    )(*ins)


# ---------------------------------------------------------------------------
# kernel 3: decompress dual + EF residual, from the packed factors
# ---------------------------------------------------------------------------

def _dequant_tile(packed, scales, rows: int, r: int, block: int):
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    codes = jnp.stack([lo, hi], axis=2).reshape(packed.shape[0], block)
    codes = jnp.where(codes >= 8, codes - 16, codes)
    return (codes.astype(jnp.float32) * scales[:, None]).reshape(rows, r)


def _recon_kernel(*refs, block: int, r: int, bm: int, bn: int,
                  with_e: bool, with_ef: bool):
    if with_ef:
        if with_e:
            (pp_ref, sp_ref, pq_ref, sq_ref, d_ref, e_ref, hat_ref,
             enew_ref) = refs
        else:
            pp_ref, sp_ref, pq_ref, sq_ref, d_ref, hat_ref, enew_ref = refs
    else:
        pp_ref, sp_ref, pq_ref, sq_ref, hat_ref = refs
    P = _dequant_tile(pp_ref[...], sp_ref[...], bm, r, block)
    Q = _dequant_tile(pq_ref[...], sq_ref[...], bn, r, block)
    rec = jax.lax.dot_general(P, Q, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    hat_ref[...] = rec.astype(hat_ref.dtype)
    if with_ef:
        m_tile = d_ref[...].astype(jnp.float32)
        if with_e:
            m_tile = m_tile + e_ref[...]
        enew_ref[...] = m_tile - rec


def _reconstruct(pp, sp, pq, sq, d, e, M: int, N: int, r: int,
                 bm: int, bn: int, block: int, out_dtype,
                 with_ef: bool, interpret: bool):
    """d/e pre-padded to (M, N) or None.  Packed/scales padded to the tile
    grid.  Returns hat (M, N) out_dtype, and e_new (M, N) f32 if with_ef."""
    gm, gn = M // bm, N // bn
    nblk_p, nblk_q = bm * r // block, bn * r // block
    with_e = e is not None
    in_specs = [
        pl.BlockSpec((nblk_p, block // 2), lambda i, j: (i, 0)),
        pl.BlockSpec((nblk_p,), lambda i, j: (i,)),
        pl.BlockSpec((nblk_q, block // 2), lambda i, j: (j, 0)),
        pl.BlockSpec((nblk_q,), lambda i, j: (j,)),
    ]
    ins = [pp.reshape(M * r // block, block // 2), sp,
           pq.reshape(N * r // block, block // 2), sq]
    if with_ef:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j: (i, j)))
        ins.append(d)
        if with_e:
            in_specs.append(pl.BlockSpec((bm, bn), lambda i, j: (i, j)))
            ins.append(e)
    out_specs = [pl.BlockSpec((bm, bn), lambda i, j: (i, j))]
    out_shape = [jax.ShapeDtypeStruct((M, N), out_dtype)]
    if with_ef:
        out_specs.append(pl.BlockSpec((bm, bn), lambda i, j: (i, j)))
        out_shape.append(jax.ShapeDtypeStruct((M, N), jnp.float32))
    out = pl.pallas_call(
        functools.partial(_recon_kernel, block=block, r=r, bm=bm, bn=bn,
                          with_e=with_e, with_ef=with_ef),
        grid=(gm, gn),
        in_specs=in_specs,
        out_specs=out_specs if with_ef else out_specs[0],
        out_shape=out_shape if with_ef else out_shape[0],
        interpret=interpret,
    )(*ins)
    return out if with_ef else (out, None)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def _pad_packed(packed, scales, rows_pad: int, r: int, block: int):
    """Zero-pad a ref-layout flat payload out to the tile grid (zero rows
    quantize to zero codes with scale 0 -> dequant exactly 0)."""
    want_b, want_s = rows_pad * r // 2, rows_pad * r // block
    packed = jnp.pad(packed, (0, want_b - packed.shape[0]))
    scales = jnp.pad(scales, (0, want_s - scales.shape[0]))
    return packed, scales


def fused_compress_ef(delta: jnp.ndarray,
                      error: Optional[jnp.ndarray],
                      q_prev: jnp.ndarray,
                      rank_scalar=None, *,
                      block: int = 256,
                      row_cap: int = 2048,
                      interpret: bool = True,
                      compute_error: bool = True,
                      ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray],
                                 jnp.ndarray, FusedPayload]:
    """The fused outer-step compressor for one (m, n) parameter matrix.

    ``delta``: pseudo-gradient (f32 or bf16); ``error``: EF residual
    (f32) or None; ``q_prev``: (n, r_max) PowerSGD warm start;
    ``rank_scalar``: traced adaptive rank r_t (columns >= r_t masked).

    Returns ``(delta_hat, e_new, q_new, payload)`` — semantically the ref
    chain ``ref.outer_step_ref`` (same wire bytes bit-for-bit; recon
    within a reordering ulp bound).  ``e_new`` is None when
    ``compute_error=False`` (the compressor-backend path, where the core
    round loop owns error feedback).
    """
    m, n = delta.shape
    r = q_prev.shape[1]
    out_dtype = delta.dtype
    if block % 2:
        raise ValueError(f"block must be even, got {block}")
    cm = _col_mask(r, rank_scalar)
    qm = q_prev.astype(jnp.float32) * cm

    bm = _row_tile(m, r, block, row_cap)
    bn = _row_tile(n, r, block, row_cap)
    # EF hoist (CPU lane): materialize the corrected delta once and feed
    # every kernel with_e=False — interpret mode would re-pay the (m, n)
    # add per kernel, which costs more than one materialization here.  On
    # TPU (HBM-traffic-bound) flip this to keep the add fused in VMEM;
    # the kernels' with_e path is what carries to hardware.
    if error is not None:
        delta = delta.astype(jnp.float32) + error.astype(jnp.float32)
    d = _pad2d(delta, bm, bn)
    e = None
    M_pad, N_pad = d.shape
    qm_p = jnp.pad(qm, ((0, N_pad - n), (0, 0)))

    # 1) P projection (EF add fused), 2) host r x r orthonormalize + mask
    from repro.core.compression import _orthonormalize
    P = _project(d, e, qm_p, bm, bn, interpret)
    P = _orthonormalize(P) * cm

    # 3) Q projection with in-flush quantize+pack; P packed by the quant4
    #    kernel (its projection/quantization are separated by the host QR)
    from repro.kernels.quant4 import quant4_pack_pallas
    Q, packed_q, scales_q = _project_t_pack(d, e, P, bm, bn, block,
                                            interpret)
    n_rows_p = M_pad * r // block
    packed_p, scales_p = quant4_pack_pallas(
        P.reshape(-1), block, rows_per_tile=min(n_rows_p, 4096),
        interpret=interpret)

    # 4) fused decompress + EF residual from the packed payload
    hat_pad, enew_pad = _reconstruct(
        packed_p, scales_p, packed_q, scales_q, d, e, M_pad, N_pad, r,
        bm, bn, block, out_dtype, with_ef=compute_error,
        interpret=interpret)
    delta_hat = hat_pad[:m, :n]
    e_new = enew_pad[:m, :n] if compute_error else None

    # warm start: keep the unquantized Q; zero-input guard as in the ref
    # chain (the first delayed round's all-zero delta must not wipe it)
    Qs = Q[:n]
    q_new = jnp.where(jnp.sum(Qs * Qs) > 0, Qs, qm)

    # payload in the ref layout: flat prefix of the padded factors (the
    # grid padding rows are exactly zero, matching the ref's block pad)
    nb_p, nb_q = -(-m * r // block), -(-n * r // block)
    payload = FusedPayload(
        packed_p=packed_p[:nb_p * (block // 2)],
        scales_p=scales_p[:nb_p],
        packed_q=packed_q.reshape(-1)[:nb_q * (block // 2)],
        scales_q=scales_q[:nb_q],
        p_factor=P[:m], q_factor=Qs)
    return delta_hat, e_new, q_new, payload


def fused_decompress(packed_p, scales_p, packed_q, scales_q,
                     m: int, n: int, r: int, *,
                     block: int = 256, row_cap: int = 2048,
                     out_dtype=jnp.float32,
                     interpret: bool = True) -> jnp.ndarray:
    """Decompress dual: unpack -> dequant -> P Q^T, one fused kernel."""
    bm = _row_tile(m, r, block, row_cap)
    bn = _row_tile(n, r, block, row_cap)
    M_pad, N_pad = _ceil_to(m, bm), _ceil_to(n, bn)
    pp, sp = _pad_packed(packed_p, scales_p, M_pad, r, block)
    pq, sq = _pad_packed(packed_q, scales_q, N_pad, r, block)
    hat, _ = _reconstruct(pp, sp, pq, sq, None, None, M_pad, N_pad, r,
                          bm, bn, block, out_dtype, with_ef=False,
                          interpret=interpret)
    return hat[:m, :n]
