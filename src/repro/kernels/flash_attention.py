"""Pallas TPU kernel: causal flash attention (online softmax).

Used for the 32k prefill / 4k train attention hot spot: the (Sq, Sk) score
matrix never leaves VMEM — each (batch*head, q-block) grid cell streams
k/v blocks, maintaining running max/denominator in f32 (Rabe-Staats /
FlashAttention recurrence). GQA is handled by the wrapper (kv heads are
index-mapped, not materialized, via the BlockSpec head mapping).

Validated in interpret mode against ``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, n_kblocks: int, scale: float,
                  causal: bool):
    """Grid: (bh, n_qblocks, n_kblocks); q block fixed per (i,j), k/v block
    varies with kk (innermost)."""
    j = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0].astype(jnp.float32)                 # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        q_pos = j * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kk == n_kblocks - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, bq: int = 256,
                           bk: int = 256, interpret: bool = True
                           ) -> jnp.ndarray:
    """q: (B,Sq,H,d); k,v: (B,Sk,KV,d). Returns (B,Sq,H,d)."""
    B, Sq, H, d = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(d)
    bq_ = max(1, min(bq, Sq))
    bk_ = max(1, min(bk, Sk))
    assert Sq % bq_ == 0 and Sk % bk_ == 0, (Sq, Sk, bq_, bk_)

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)

    n_q, n_k = Sq // bq_, Sk // bk_
    grid = (B * H, n_q, n_k)

    def q_map(h, j, kk):
        return (h, j, 0)

    def kv_map(h, j, kk):
        # GQA: query head h reads kv head h // G of its batch
        b = h // H
        kvh = (h % H) // G
        return (b * KV + kvh, kk, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq_, bk=bk_, n_kblocks=n_k,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), q_map),
            pl.BlockSpec((1, bk_, d), kv_map),
            pl.BlockSpec((1, bk_, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),     # running max
            pltpu.VMEM((bq_,), jnp.float32),     # running denom
            pltpu.VMEM((bq_, d), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
