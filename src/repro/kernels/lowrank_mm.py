"""Pallas TPU kernel: tiled matmul with f32 VMEM accumulation.

The PowerSGD projections P = M Q and Q = M^T P are the compute hot spot of
the DiLoCoX compressor at 100B scale (two skinny matmuls per parameter
matrix per outer step). Tiles are MXU-aligned (128 by default); the K loop
is the innermost grid dim with a VMEM accumulator flushed on the last K
step — the standard Pallas matmul pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                            b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                  bn: int = 128, bk: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """(m,k) @ (k,n) -> (m,n), f32 accumulation, MXU-aligned tiles."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    bm_, bn_, bk_ = max(1, min(bm, m)), max(1, min(bn, n)), max(1, min(bk, k))
    ap = _pad_to(a, bm_, bk_)
    bp = _pad_to(b, bk_, bn_)
    gm, gn, gk = ap.shape[0] // bm_, bp.shape[1] // bn_, ap.shape[1] // bk_
    out = pl.pallas_call(
        functools.partial(_mm_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
