"""Jit'd kernel entry points. Each op dispatches to the Pallas TPU kernel
when available/enabled and to the pure-jnp reference otherwise (CPU tests,
and the GSPMD dry-run where the kernel is a per-shard local op).

Set ``REPRO_USE_PALLAS=1`` (or pass use_pallas=True) to route through
``pl.pallas_call`` in interpret mode on CPU — the kernel tests sweep both
paths and assert they agree with ref.py.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _use_pallas() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


# ---------------------------------------------------------------------------
# int4 block quantization
# ---------------------------------------------------------------------------

def quant4_pack(x: jnp.ndarray, block: int = 256):
    """x: flat (n,) -> (packed uint8, scales f32). Pads internally."""
    if _use_pallas():
        from repro.kernels.quant4 import quant4_pack_pallas
        return quant4_pack_pallas(x, block)
    packed, scales, _ = ref.quant4_pack_ref(x, block)
    return packed, scales


def quant4_unpack(packed: jnp.ndarray, scales: jnp.ndarray, n: int,
                  block: int = 256) -> jnp.ndarray:
    if _use_pallas():
        from repro.kernels.quant4 import quant4_unpack_pallas
        return quant4_unpack_pallas(packed, scales, n, block)
    return ref.quant4_unpack_ref(packed, scales, n, block)


def quant_dequant(x: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    shape = x.shape
    p, s = quant4_pack(x.reshape(-1), block)
    return quant4_unpack(p, s, x.size, block).reshape(shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# fused outer-step compressor (EF add + PowerSGD + quant4 pack + recon + EF)
# ---------------------------------------------------------------------------

def fused_outer_step(delta, error, q_prev, rank_scalar=None,
                     block: int = 256):
    """One parameter matrix's full outer-step compression: returns
    ``(delta_hat, e_new, q_new, payload)`` — the fused Pallas pipeline
    under REPRO_USE_PALLAS=1, the unfused jnp op-chain otherwise.  Same
    wire bytes either way; reconstruction agrees within the reorder-ulp
    bound gated in tests/test_kernels.py."""
    from repro.obs import profile as _prof
    if _use_pallas():
        from repro.kernels.fused_compress import fused_compress_ef
        with _prof.scope("fused_outer_step/pallas"):
            return fused_compress_ef(delta, error, q_prev, rank_scalar,
                                     block=block)
    with _prof.scope("fused_outer_step/ref"):
        return ref.outer_step_ref(delta, error, q_prev, rank_scalar, block)


# ---------------------------------------------------------------------------
# matmul (PowerSGD projection hot spot)
# ---------------------------------------------------------------------------

def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    if _use_pallas() and a.ndim == 2 and b.ndim == 2:
        from repro.kernels.lowrank_mm import matmul_pallas
        return matmul_pallas(a, b)
    return ref.matmul_ref(a, b)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True):
    if _use_pallas():
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal)
    return ref.flash_attention_ref(q, k, v, causal=causal)
