"""Pallas TPU kernel: block-wise symmetric int4 quantization (pack/unpack).

This is the wire-format hot spot of the DiLoCoX compressor (Alg. 1 step 2):
every outer step quantizes the PowerSGD factors of every parameter matrix.
On TPU the kernel streams `rows_per_tile` quantization blocks from HBM into
VMEM, computes the per-block scale on the VPU, packs two int4 codes per
byte, and writes the packed payload + scales back out.

Validated in interpret mode on CPU against ``ref.quant4_pack_ref`` (the
tests sweep sizes/dtypes). Layout note: the pair-split uses a
reshape-(block/2,2) access pattern; on real TPU the final pack prefers a
(2, block/2) sublane layout — the BlockSpec keeps the whole quantization
block in one tile so either layout stays VMEM-local.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, packed_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)              # (rows, block)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(amax == 0, 1.0, amax / 7.0)   # qmax = 7
    q = jnp.clip(jnp.round(x / scale[:, None]), -8, 7).astype(jnp.int32)
    qu = (q & 0xF).astype(jnp.uint8)
    rows, block = qu.shape
    pair = qu.reshape(rows, block // 2, 2)
    packed_ref[...] = pair[:, :, 0] | (pair[:, :, 1] << 4)
    scale_ref[...] = scale


def _unpack_kernel(packed_ref, scale_ref, out_ref):
    p = packed_ref[...]                             # (rows, block//2) uint8
    lo = (p & 0xF).astype(jnp.int32)
    hi = ((p >> 4) & 0xF).astype(jnp.int32)
    codes = jnp.stack([lo, hi], axis=2).reshape(p.shape[0], -1)
    codes = jnp.where(codes >= 8, codes - 16, codes)
    out_ref[...] = (codes.astype(jnp.float32)
                    * scale_ref[...][:, None])


def quant4_pack_pallas(x: jnp.ndarray, block: int = 256,
                       rows_per_tile: int = 8, interpret: bool = True):
    """x: flat (n,) -> (packed uint8 (ceil(n/2),), scales f32)."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, block)
    rows = xp.shape[0]
    row_pad = (-rows) % rows_per_tile
    if row_pad:
        xp = jnp.pad(xp, ((0, row_pad), (0, 0)))
    grid = (xp.shape[0] // rows_per_tile,)
    packed, scales = pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows_per_tile, block // 2), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], block // 2), jnp.uint8),
            jax.ShapeDtypeStruct((xp.shape[0],), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    packed = packed[:rows].reshape(-1)[: (n + pad) // 2]
    scales = scales[:rows]
    return packed, scales


def quant4_unpack_pallas(packed: jnp.ndarray, scales: jnp.ndarray, n: int,
                         block: int = 256, rows_per_tile: int = 8,
                         interpret: bool = True) -> jnp.ndarray:
    rows = scales.shape[0]
    pp = packed.reshape(rows, block // 2)
    row_pad = (-rows) % rows_per_tile
    if row_pad:
        pp = jnp.pad(pp, ((0, row_pad), (0, 0)))
        scales = jnp.pad(scales, (0, row_pad))
    grid = (pp.shape[0] // rows_per_tile,)
    out = pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_tile, block // 2), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pp.shape[0], block), jnp.float32),
        interpret=interpret,
    )(pp, scales)
    return out[:rows].reshape(-1)[:n]
