"""Injectable faults for the virtual decentralized cluster.

Faults are plain frozen dataclasses collected in a ``FaultSchedule``; the
simulator queries the schedule once per outer round.  Round intervals are
half-open ``[start_round, end_round)`` — the operational vocabulary of
OpenDiLoCo/NoLoCo's WAN setting:

 - ``Straggler``: one cluster's local step time is multiplied by
   ``slowdown`` (a slow/preempted site; the outer barrier waits for it).
 - ``LinkDegradation``: link bandwidth multiplied by ``factor`` (<1), for
   every link or only the links touching one cluster.
 - ``Leave`` / ``Join``: membership churn.  A leaving cluster stops
   participating in the outer average (mask-weighted mean,
   ``core.membership``); a (re)joining cluster restarts from the current
   global params with zeroed pending-delta/error buffers.
 - ``Byzantine``: an adversarial cluster whose *published* compressed
   delta is corrupted (scaled by an arbitrary factor, e.g. sign-flipped
   and blown up) before it enters any aggregation — the attack model the
   trimmed-mean robust aggregation in ``core.membership`` defends
   against.  Only meaningful under ``sync="bounded_stale"``, where the
   publish step is an explicit engine event (barrier-mode aggregation
   happens inside the jitted round program with no injection point).

Under ``sync="bounded_stale"`` there is no global round: ``Straggler`` /
``LinkDegradation`` / ``Leave`` windows are indexed by each cluster's OWN
round clock, while ``Join`` fires when the fleet frontier (highest
committed leg anywhere) reaches the join round (see ``sim/engine.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Straggler:
    cluster: int
    start_round: int
    end_round: int                 # exclusive
    slowdown: float = 3.0          # multiplies t_step_s while active

    def describe(self) -> str:
        return (f"straggler(c{self.cluster} x{self.slowdown:g} "
                f"@[{self.start_round},{self.end_round}))")


@dataclass(frozen=True)
class LinkDegradation:
    start_round: int
    end_round: int                 # exclusive
    factor: float = 0.5            # multiplies link bandwidth while active
    cluster: Optional[int] = None  # None: every link; else links of one site

    def describe(self) -> str:
        who = "all" if self.cluster is None else f"c{self.cluster}"
        return (f"degrade({who} x{self.factor:g} "
                f"@[{self.start_round},{self.end_round}))")


@dataclass(frozen=True)
class Leave:
    cluster: int
    round: int

    def describe(self) -> str:
        return f"leave(c{self.cluster} @r{self.round})"


@dataclass(frozen=True)
class Join:
    cluster: int
    round: int

    def describe(self) -> str:
        return f"join(c{self.cluster} @r{self.round})"


@dataclass(frozen=True)
class Byzantine:
    cluster: int
    start_round: int
    end_round: int                 # exclusive
    scale: float = -8.0            # multiplies the published delta while
                                   # active (default: sign-flip + blow-up)

    def describe(self) -> str:
        return (f"byzantine(c{self.cluster} x{self.scale:g} "
                f"@[{self.start_round},{self.end_round}))")


@dataclass(frozen=True)
class FaultSchedule:
    events: Tuple = field(default_factory=tuple)

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    def step_multiplier(self, cluster: int, rnd: int) -> float:
        """Product of active straggler slowdowns for one cluster."""
        m = 1.0
        for e in self.events:
            if (isinstance(e, Straggler) and e.cluster == cluster
                    and e.start_round <= rnd < e.end_round):
                m *= e.slowdown
        return m

    def bandwidth_factor(self, cluster: int, rnd: int) -> float:
        """Product of active degradation factors on one cluster's links."""
        f = 1.0
        for e in self.events:
            if (isinstance(e, LinkDegradation)
                    and e.start_round <= rnd < e.end_round
                    and (e.cluster is None or e.cluster == cluster)):
                f *= e.factor
        return f

    def membership(self, rnd: int, alive: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Apply this round's Leave/Join events.  Returns (alive', rejoined)
        — rejoined marks clusters that were dead and came back this round
        (their stale buffers must be reset before the outer average)."""
        new = alive.copy()
        rejoined = np.zeros_like(alive)
        for e in self.events:
            if isinstance(e, Leave) and e.round == rnd:
                new[e.cluster] = False
            elif isinstance(e, Join) and e.round == rnd:
                if not new[e.cluster]:
                    rejoined[e.cluster] = True
                new[e.cluster] = True
        return new, rejoined

    def byzantine_scale(self, cluster: int, rnd: int) -> Optional[float]:
        """Product of active Byzantine corruption scales on one cluster's
        published delta, or None when the cluster is honest this round."""
        s = None
        for e in self.events:
            if (isinstance(e, Byzantine) and e.cluster == cluster
                    and e.start_round <= rnd < e.end_round):
                s = e.scale if s is None else s * e.scale
        return s

    def leaves_at(self, rnd: int) -> Tuple[int, ...]:
        """Clusters leaving at round ``rnd`` (sorted) — the per-event query
        the bounded-stale engine uses in place of ``membership``."""
        return tuple(sorted(e.cluster for e in self.events
                            if isinstance(e, Leave) and e.round == rnd))

    def leave_events(self) -> Tuple[Tuple[int, int], ...]:
        """All ``(round, cluster)`` Leave events (engine init input)."""
        return tuple((e.round, e.cluster) for e in self.events
                     if isinstance(e, Leave))

    def join_events(self) -> Tuple[Tuple[int, int], ...]:
        """All ``(round, cluster)`` Join events (engine init input)."""
        return tuple((e.round, e.cluster) for e in self.events
                     if isinstance(e, Join))

    def active(self, rnd: int) -> Tuple[str, ...]:
        """Human-readable tags of everything firing/active at round rnd
        (recorded on the event timeline)."""
        tags = []
        for e in self.events:
            if isinstance(e, (Straggler, LinkDegradation, Byzantine)):
                if e.start_round <= rnd < e.end_round:
                    tags.append(e.describe())
            elif e.round == rnd:
                tags.append(e.describe())
        return tuple(tags)
