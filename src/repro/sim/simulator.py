"""Deterministic virtual decentralized-cluster simulator.

``simulate(scenario)`` replays ``scenario.rounds`` outer rounds of the
DiLoCoX loop over N virtual clusters and returns an event ``Timeline``:

 - **timing**: per-round compute time (H x the *slowest* alive cluster's
   step — the outer sync is a barrier), wire time of the outer collective
   from ``core.comm``'s analytic arithmetic over the *bottleneck* link,
   and the §2.3 overlap rule ``exposed = max(0, T_comm - H*T_step)``;
 - **faults** (``sim.faults``): stragglers inflate a cluster's step time,
   link degradation shrinks bandwidth, Leave/Join drive the
   ``core.membership`` mask semantics (mask-weighted outer mean, buffer
   reset on rejoin);
 - **numerics** (optional): pass ``numeric=make_quadratic_problem(...)``
   (or any ``NumericProblem``) and each simulated round *actually runs*
   ``core.diloco.diloco_round`` — compression, error feedback, one-step
   delay, masked cluster mean — recording the realized loss per round.

All randomness (link/step jitter) is drawn from ``numpy`` generators
seeded by ``(scenario.seed, round)``: the same scenario always produces a
bit-identical timeline (``Timeline.fingerprint()``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import comm
from repro.sim.engine import BoundedStaleEngine, run_barrier
from repro.sim.scenario import Scenario
from repro.sim.timeline import (RoundEvent, Timeline, combine_row_hashes,
                                tree_hash)

# NOTE: repro.core.compression (and with it jax) is imported lazily inside
# simulate() — `import repro.sim` must stay jax-free so the proc backend's
# timing-only workers really do spawn without paying the jax import.


# ---------------------------------------------------------------------------
# optional numeric problem (runs the real diloco_round per simulated round)
# ---------------------------------------------------------------------------

@dataclass
class NumericProblem:
    params: Any                      # initial global params
    inner_opt_stacked: Any           # per-cluster inner optimizer states
    inner_fn: Callable               # diloco inner_fn(params, opt, t)
    outer_lr: float = 0.7
    outer_momentum: float = 0.5
    compress: bool = True
    error_feedback: bool = True
    eval_fn: Optional[Callable] = None   # params -> scalar loss (recorded)
    inner_fn_stacked: Optional[Callable] = None  # gossip mode: like
                                     # inner_fn but params carry a
                                     # (n_clusters, ...) leading axis
                                     # (each cluster trains from its OWN
                                     # outer params)
    inner_fn_h: Optional[Callable] = None        # per-cluster-H variant:
                                     # inner_fn(params, opt, t, h_vec)
                                     # where h_vec is a (n_clusters,)
                                     # int32 local-step schedule (masked
                                     # fixed-length scan; aux = per-
                                     # cluster mean loss)
    inner_fn_h_stacked: Optional[Callable] = None  # gossip x per-cluster H
    engine: str = "scalar"           # which inner engine built the fns:
                                     # "scalar" (single-replica) or "pp"
                                     # (sharded pipeline-parallel unit
                                     # mesh); cross-checked against
                                     # Scenario.inner_engine
    inner_fn_row: Optional[Callable] = None      # bounded-stale async mode:
                                     # ONE cluster's H-step inner program
                                     # (params_row, opt_row, cluster) ->
                                     # (params_H, opt', losses) — the same
                                     # per-row program a proc worker jits,
                                     # so the async executor mirrors the
                                     # worker op-for-op


def make_quadratic_problem(n_clusters: int, **kw) -> NumericProblem:
    """Tiny per-cluster least-squares problem (see ``sim.quadratic``).
    Kept here for back-compat; the construction now lives in
    ``QuadraticSpec`` so the proc backend can rebuild it in a subprocess."""
    from repro.sim.quadratic import make_quadratic_problem as _mk
    return _mk(n_clusters, **kw)


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

def _jitter_factors(seed: int, rnd: int, n: int, sigma: float, salt: int
                    ) -> np.ndarray:
    """Deterministic positive per-(round, cluster) noise: exp(sigma * z)
    with z ~ N(0,1) from a generator seeded by (seed, salt, round)."""
    if sigma <= 0:
        return np.ones(n)
    rng = np.random.default_rng([seed, salt, rnd])
    return np.exp(sigma * rng.standard_normal(n))


def simulate(sc: Scenario, numeric: Optional[NumericProblem] = None,
             adaptive_cfg: Optional[Any] = None,
             rank_schedule: Optional[Any] = None) -> Timeline:
    """Run the scenario; returns the event Timeline.

    Adaptive compression (paper §2.4), three ways:

     - ``sc.adaptive`` / ``adaptive_cfg`` = an ``adaptive.AdaptiveSpec``:
       the spectral/bandwidth/hybrid controller picks the per-round rank
       r_t (and per-edge send ranks under gossip).  Spectral modes need
       ``numeric`` (the rank signal is the effective rank of the realized
       averaged pseudo-gradient, as in train/trainer.py) and ``sc.delay``;
       ``mode="bandwidth"`` is pure link arithmetic and also works
       timing-only.
     - ``adaptive_cfg`` = a legacy ``adaptive.AdaGradCmpConfig``: treated
       as ``AdaptiveSpec(mode="spectral")`` with the same knobs.
     - ``rank_schedule`` = a recorded per-round rank list (e.g. a previous
       adaptive run's ``Timeline.rank_schedule()``): replayed verbatim for
       the wire accounting — timing-only scenarios can replay an adaptive
       run without a numeric problem or controller.  Entries are scalars,
       or per-alive-cluster send-rank lists for per-edge gossip rounds
       (requires the recording run's fault schedule, so the alive sets
       line up).
    """
    from repro.core import adaptive as _ada
    from repro.core.compression import make_compressor
    from repro.topology import (MixingMatrix, compute_leg, gossip_round_comm,
                                round_wire_total)
    from repro.topology import mixing as topo_mixing

    if sc.sync == "bounded_stale":
        if adaptive_cfg is not None or rank_schedule is not None:
            raise ValueError(
                "sync='bounded_stale' has no global round clock for the "
                "adaptive controller / a recorded rank schedule to index; "
                "run them under sync='barrier'")
        return _simulate_bounded_stale(sc, numeric)
    from repro.sim.faults import Byzantine
    if any(isinstance(e, Byzantine) for e in sc.faults.events):
        raise ValueError(
            "Byzantine faults model corrupt *published* deltas, which only "
            "exist under sync='bounded_stale' (the barrier round mixes "
            "inside one jitted program with no publish step to corrupt)")

    C = sc.n_clusters
    shapes = sc.shapes()
    compressor = make_compressor(sc.compressor, **sc.compressor_kw)
    alive = (np.ones(C, bool) if sc.initial_alive is None
             else np.asarray(sc.initial_alive, bool).copy())
    if alive.shape != (C,):
        raise ValueError(f"initial_alive must have shape ({C},)")

    topo = sc.topo()
    gossip = topo.is_gossip
    if gossip and sc.allreduce_per_step:
        raise ValueError("allreduce_per_step models the per-step DDP "
                         "baseline; gossip topologies sync per round only")
    h_active = sc.h_spec is not None and sc.h_spec.active
    if h_active and sc.allreduce_per_step:
        raise ValueError("allreduce_per_step has no outer-round barrier to "
                         "balance; h_spec needs the DiLoCo round structure")

    # dynamic time-varying topology: a fresh random graph (and mixing
    # matrix) per round, cached by seed — round r communicates over
    # sc.topo(r)
    _topo_cache: Dict[int, Any] = {}

    def topo_at(rnd: int):
        if sc.topology_seed_schedule is None:
            return topo
        key = rnd % len(sc.topology_seed_schedule)
        if key not in _topo_cache:
            _topo_cache[key] = sc.topo(rnd)
        return _topo_cache[key]

    _mm_cache: Dict[int, MixingMatrix] = {}

    def mm_at(rnd: int, topo_r) -> Optional[MixingMatrix]:
        if not gossip:
            return None
        if sc.topology_seed_schedule is None:
            key = -1
        else:
            key = rnd % len(sc.topology_seed_schedule)
        if key not in _mm_cache:
            _mm_cache[key] = MixingMatrix.metropolis(topo_r)
        return _mm_cache[key]

    # --- numeric state (real diloco rounds) --------------------------------
    num = None
    if numeric is not None:
        import jax
        import jax.numpy as jnp

        from repro.core import diloco, membership

        engine = getattr(numeric, "engine", "scalar")
        if engine != sc.inner_engine:
            raise ValueError(
                f"Scenario.inner_engine={sc.inner_engine!r} but the "
                f"NumericProblem was built for engine {engine!r} "
                "(PPSpec.problem() tags engine='pp'; quadratic/trainer "
                "problems are 'scalar')")
        if engine == "pp" and gossip:
            raise ValueError(
                "inner_engine='pp' supports gather topologies only: the "
                "gossip leg needs a stacked inner_fn, and stacking C "
                "pipeline meshes in one program would compile a different "
                "(non-bitwise) computation than a lone pp worker")

        rcfg = diloco.RoundConfig(
            outer_lr=numeric.outer_lr, outer_momentum=numeric.outer_momentum,
            delay=sc.delay, compress=numeric.compress,
            error_feedback=numeric.error_feedback)

        if gossip:
            if numeric.inner_fn_stacked is None:
                raise ValueError(
                    f"topology {sc.topology!r} needs a stacked inner_fn "
                    "(each cluster trains from its own outer params); the "
                    "NumericProblem provides no inner_fn_stacked")
            if h_active and numeric.inner_fn_h_stacked is None:
                raise ValueError(
                    f"h policy {sc.h_spec.policy!r} needs a per-cluster-H "
                    "stacked inner_fn (masked scan); the NumericProblem "
                    "provides no inner_fn_h_stacked")
            state = diloco.init_state(
                diloco.stack_replicas(numeric.params, C),
                numeric.inner_opt_stacked, C, compressor,
                stacked_params=True)

            def _round(st, rank_scalar, W):
                mix = lambda tree: topo_mixing.mix_stacked(W, tree)
                mix.returns_stacked = True
                return diloco.diloco_round(st, numeric.inner_fn_stacked,
                                           compressor, mix, rcfg,
                                           rank_scalar)

            def _round_h(st, rank_scalar, W, h_vec):
                mix = lambda tree: topo_mixing.mix_stacked(W, tree)
                mix.returns_stacked = True
                return diloco.diloco_round_h(
                    st, numeric.inner_fn_h_stacked, compressor, mix,
                    rcfg, h_vec, rank_scalar)
        else:
            if h_active and numeric.inner_fn_h is None:
                raise ValueError(
                    f"h policy {sc.h_spec.policy!r} needs a per-cluster-H "
                    "inner_fn (masked scan); the NumericProblem provides "
                    "no inner_fn_h")
            state = diloco.init_state(numeric.params,
                                      numeric.inner_opt_stacked,
                                      C, compressor)

            def _round(st, rank_scalar, alive_vec):
                cm = lambda tree: membership.masked_cluster_mean(
                    tree, alive_vec)
                return diloco.diloco_round(st, numeric.inner_fn,
                                           compressor, cm, rcfg,
                                           rank_scalar)

            def _round_h(st, rank_scalar, alive_vec, h_vec):
                cm = lambda tree: membership.masked_cluster_mean(
                    tree, alive_vec)
                return diloco.diloco_round_h(st, numeric.inner_fn_h,
                                             compressor, cm, rcfg,
                                             h_vec, rank_scalar)

        # NOTE on the two round programs: a round whose planned schedule is
        # uniform at the budget H runs the SCALAR program — bit-for-bit
        # today's path — and only genuinely heterogeneous rounds run the
        # masked-scan program.  The dispatch is host-side on the planned
        # h_map, identical on both backends (the coordinator only puts
        # "h_steps" in the round header for heterogeneous rounds), because
        # the masked program is a *different compiled computation*: XLA may
        # tile e.g. the AdamW grad-clip norm reduction differently around
        # the selects, which is a last-ulp difference the scalar-vs-uniform
        # guarantee must not depend on.  jit compiles lazily, so runs that
        # never hit a heterogeneous round never pay the second compile.
        num = {"state": state, "round": jax.jit(_round),
               "round_h": (jax.jit(_round_h) if h_active else None),
               "jnp": jnp,
               "membership": membership, "jax": jax,
               "mean": jax.jit(membership.masked_cluster_mean),
               "comp0": compressor.init_state(numeric.params)}

    ctrl = None
    schedule = None
    if rank_schedule is not None:
        if adaptive_cfg is not None or sc.adaptive is not None:
            raise ValueError("rank_schedule replays a recorded adaptive "
                             "run; drop adaptive_cfg / Scenario.adaptive")
        def _norm(x):
            if x is None:
                return None
            if isinstance(x, (list, tuple)):   # per-edge gossip round
                return [int(v) for v in x]
            return int(x)

        schedule = [_norm(x) for x in rank_schedule]
        if len(schedule) < sc.rounds:
            raise ValueError(f"rank_schedule has {len(schedule)} entries "
                             f"for {sc.rounds} rounds")
    else:
        spec = adaptive_cfg if adaptive_cfg is not None else sc.adaptive
        if isinstance(spec, _ada.AdaGradCmpConfig):   # legacy entry point
            spec = _ada.AdaptiveSpec(
                mode="spectral", window=spec.window, r1=spec.r1, h1=spec.h1,
                h_min=spec.h_min, r_min=spec.r_min, h_mode=spec.mode)
        if spec is not None:
            ctrl = spec.controller(compressor)
        if ctrl is not None and ctrl.needs_spectral:
            if numeric is None:
                raise ValueError(
                    f"adaptive mode {spec.mode!r} needs a numeric problem "
                    "(the spectral rank signal comes from realized "
                    "deltas); timing-only runs can use mode='bandwidth' "
                    "or replay a recorded rank_schedule")
            if not sc.delay:
                raise ValueError(
                    f"adaptive mode {spec.mode!r} reads the pending "
                    "pseudo-gradient, which only delay=True rounds carry; "
                    "use mode='bandwidth' for synchronous rounds")

    events = []

    def _barrier_round(r: int) -> None:
        # The pre-engine per-round body, verbatim: ``run_barrier`` drives it
        # with the same index sequence, so sync="barrier" through the engine
        # stays bit-for-bit identical to the old inline loop (same host
        # arithmetic, same jit call order — the property every proc≡in-
        # process CI gate certifies).
        nonlocal alive
        alive, rejoined = sc.faults.membership(r, alive)
        alive_ids = tuple(int(i) for i in np.flatnonzero(alive))
        n_alive = len(alive_ids)
        topo_r = topo_at(r)
        mm_r = mm_at(r, topo_r)

        h_t = sc.h_steps

        # ---- compute leg: barrier on the slowest alive cluster -----------
        step_j = _jitter_factors(sc.seed, r, C, sc.link.jitter, salt=1)
        t_steps = np.array([sc.t_step_s * sc.faults.step_multiplier(c, r)
                            * step_j[c] for c in range(C)])
        # per-cluster local-step schedule: slow sites do fewer steps so the
        # barrier tightens; under gossip the spread is clamped by the
        # masked mixing matrix's spectral-gap certificate
        gap = (mm_r.masked(alive).spectral_gap(alive)
               if (gossip and h_active and n_alive) else None)
        h_map = _ada.plan_h(sc.h_spec, h_t, t_steps, alive,
                            spectral_gap=gap)
        leg = compute_leg(h_map, t_steps, alive)
        slowest, t_compute = leg.slowest_cluster, leg.t_barrier_s

        # ---- link state (modeled per-cluster bandwidths) -----------------
        bw_j = _jitter_factors(sc.seed, r, C, sc.link.jitter, salt=2)
        bws = np.array([sc.link.bytes_per_s * sc.faults.bandwidth_factor(c, r)
                        * bw_j[c] for c in range(C)])

        # ---- rank decision: controller fuses the Alg. 3 spectral state
        # (through round r-1) with THIS round's measured link/compute
        # numbers; the executed rank is decided BEFORE the round runs and
        # is what the timeline charges (no post-update off-by-one).  The
        # controller's H co-adaptation is NOT applied here: the numeric
        # inner loop executes the problem's fixed h_steps
        # (train/trainer.py parity), and the timeline must charge the
        # compute that actually ran.
        rank_t = sc.rank
        ranks_map = None
        if schedule is not None:
            entry = schedule[r]
            if isinstance(entry, list):        # recorded per-edge ranks
                if not gossip:
                    raise ValueError(
                        f"rank_schedule round {r} is a per-edge list but "
                        f"topology {sc.topology!r} is not gossip")
                if len(entry) != n_alive:
                    raise ValueError(
                        f"rank_schedule round {r} has {len(entry)} send "
                        f"ranks for {n_alive} alive clusters (replay needs "
                        "the recording run's fault schedule)")
                ranks_map = dict(zip(alive_ids, entry))
                rank_t = max(entry) if entry else sc.rank
            else:
                rank_t = entry
        elif ctrl is not None:
            rank_t, ranks_map = ctrl.decide(compressor, shapes, topo_r,
                                            alive, bws, sc.link.latency_s,
                                            t_compute, gossip)
        ranks_tuple = (tuple(ranks_map[c] for c in alive_ids)
                       if ranks_map is not None else None)

        # ---- comm leg: analytic collective over the bottleneck link ------
        wire = int(compressor.wire_bytes(shapes, rank=rank_t))
        if gossip:
            # neighbor exchange: each cluster ships its payload to every
            # alive graph neighbor over its own (serialized) uplink;
            # per-edge adaptive ranks give each sender its own payload size
            wire_by = (compressor.wire_bytes_per_edge(shapes, ranks_map)
                       if ranks_map is not None else None)
            gc = gossip_round_comm(topo_r, alive, wire, bws,
                                   sc.link.latency_s,
                                   wire_by_cluster=wire_by)
            t_comm, bottleneck = gc.t_comm_s, gc.bottleneck_cluster
            wire_total = gc.wire_bytes_total
            exposed = (max(0.0, t_comm - t_compute) if sc.delay else t_comm)
        elif n_alive >= 2:
            bottleneck = int(min(alive_ids, key=lambda c: bws[c]))
            bw = float(bws[bottleneck])
            csub = comm.CommScenario(n_clusters=n_alive, link_bytes_per_s=bw,
                                     t_step_s=sc.t_step_s)
            if sc.allreduce_per_step:
                per_step = (comm.ring_allreduce_time(wire, csub)
                            + 2 * (n_alive - 1) * sc.link.latency_s)
                t_comm = h_t * per_step
                exposed = t_comm                   # no overlap in DDP style
                wire_total = round_wire_total("allreduce", n_alive, wire,
                                              h_t)
            else:
                t_comm = (comm.gather_time(wire, csub)
                          + (n_alive - 1) * sc.link.latency_s)
                exposed = (max(0.0, t_comm - t_compute) if sc.delay
                           else t_comm)
                wire_total = round_wire_total("gather", n_alive, wire)
        else:
            bottleneck, t_comm, exposed, wire_total = -1, 0.0, 0.0, 0

        t_round = t_compute + exposed
        tokens = sc.tokens_per_step * sum(h_map.values()) / max(C, 1)

        # ---- numeric leg: one REAL diloco round over the alive set -------
        loss = None
        param_hash = None
        disagreement = None
        if num is not None:
            jnp = num["jnp"]
            _jax = num["jax"]

            def reset_buffers(st, mask_np):
                """Zero per-cluster pending-delta/error for masked clusters
                (dead sites neither train nor accumulate error)."""
                m = jnp.asarray(mask_np, jnp.float32)
                return st._replace(
                    delta_pending=num["membership"].reset_rejoining(
                        st.delta_pending, m),
                    error=num["membership"].reset_rejoining(st.error, m))

            def reset_rejoined(st, mask_np):
                """A rejoining cluster is a *fresh worker* (the proc backend
                respawns the process): pending/error zeroed, inner-optimizer
                moments zeroed (== adamw.init), and the compressor warm
                start RE-INITIALIZED to its deterministic init value — never
                zeroed, a zero Q bricks PowerSGD (P = M @ 0 forever)."""
                st = reset_buffers(st, mask_np)
                m = jnp.asarray(mask_np, bool)

                def row(x):
                    return m.reshape((-1,) + (1,) * (max(x.ndim, 1) - 1))

                inner = _jax.tree.map(
                    lambda x: (jnp.where(row(x), jnp.zeros_like(x), x)
                               if hasattr(x, "ndim") and x.ndim >= 1 else x),
                    st.inner_opt)
                comp = _jax.tree.map(
                    lambda x, x0: (jnp.where(
                        row(x),
                        jnp.broadcast_to(x0, x.shape).astype(x.dtype), x)
                        if hasattr(x, "ndim") and x.ndim >= 1 else x),
                    st.comp_state, num["comp0"])
                return st._replace(inner_opt=inner, comp_state=comp)

            def consensus_bootstrap(st, rejoined_np, alive_prev_np):
                """Gossip-mode rejoin: there is no single global replica to
                copy, so a rejoiner restarts from the masked MEAN of the
                surviving clusters' (params, outer momentum) — the same
                arithmetic (zero-masked rows through the standalone jitted
                ``masked_cluster_mean``) the proc coordinator uses to
                bootstrap a respawned worker, hence bit-identical."""
                from repro.core.diloco import stack_replicas

                m_prev = jnp.asarray(alive_prev_np, jnp.float32)
                rej = jnp.asarray(rejoined_np, bool)

                def row(mask, x):
                    return mask.reshape((-1,) + (1,) * (x.ndim - 1))

                def mean_rows(tree):
                    zeroed = _jax.tree.map(
                        lambda x: jnp.where(row(m_prev > 0, x), x,
                                            jnp.zeros_like(x)), tree)
                    return num["mean"](zeroed, m_prev)

                mp = stack_replicas(mean_rows(st.params), C)
                mv = stack_replicas(mean_rows(st.outer_opt.momentum), C)
                params = _jax.tree.map(
                    lambda x, m: jnp.where(row(rej, x), m.astype(x.dtype),
                                           x), st.params, mp)
                mom = _jax.tree.map(
                    lambda x, m: jnp.where(row(rej, x), m, x),
                    st.outer_opt.momentum, mv)
                return st._replace(
                    params=params,
                    outer_opt=st.outer_opt._replace(momentum=mom))

            st = num["state"]
            if rejoined.any():
                st = reset_rejoined(st, rejoined)
                if gossip:
                    st = consensus_bootstrap(st, rejoined,
                                             alive & ~rejoined)
            if ranks_map is not None:
                # per-EDGE gossip ranks: one send rank per cluster row
                # (dead rows compress zeros — any rank; use the round max)
                rank_vec = np.full((C,), int(rank_t), np.int32)
                for c, rv in ranks_map.items():
                    rank_vec[c] = int(rv)
                rank_scalar = jnp.asarray(rank_vec, jnp.int32)
            else:
                rank_scalar = (None if rank_t is None
                               else jnp.asarray(rank_t, jnp.int32))
            alive_vec = jnp.asarray(alive, jnp.float32)
            het_round = h_active and any(h_map[c] != h_t for c in alive_ids)
            round_fn, round_args = num["round"], []
            if het_round:
                # dead rows get the budget H (deterministic filler: their
                # pendings are zeroed after the round and their state is
                # reset on rejoin, so the value never reaches a hash)
                h_vec_np = np.full((C,), h_t, np.int32)
                for c, hv in h_map.items():
                    h_vec_np[c] = hv
                round_fn, round_args = num["round_h"], [jnp.asarray(h_vec_np)]
            if gossip:
                W_r = mm_r.masked(alive).W
                st, aux = round_fn(st, rank_scalar, jnp.asarray(W_r),
                                   *round_args)
            else:
                st, aux = round_fn(st, rank_scalar, alive_vec, *round_args)
            # dead clusters neither train nor accumulate error
            if (~alive).any():
                st = reset_buffers(st, ~alive)
            num["state"] = st
            if gossip:
                from repro.core.diloco import take_row
                rows = [(c, tree_hash(take_row(st.params, c)))
                        for c in alive_ids]
                param_hash = combine_row_hashes(rows)
                flat = np.concatenate(
                    [np.asarray(x).reshape(C, -1)
                     for x in _jax.tree.leaves(st.params)], axis=1)
                disagreement = topo_mixing.consensus_distance(flat, alive)
            else:
                param_hash = tree_hash(st.params)
            aux_np = np.asarray(aux)
            if n_alive:
                loss = float(np.mean(aux_np[np.asarray(alive)]))
            if ctrl is not None and ctrl.needs_spectral:
                # spectral feedback AFTER the executed rank was logged;
                # the jitted masked mean is the same compiled program the
                # proc coordinator runs on the workers' reported pendings,
                # keeping the two backends' rank schedules bit-identical
                ctrl.observe(num["mean"](st.delta_pending, alive_vec))

        # ---- telemetry: modeled phase spans (obs/trace.py consumes these;
        # strictly read-only — derived from the same compute_leg/comm
        # arithmetic that filled the timing fields above) ------------------
        spans = []
        for c in alive_ids:
            spans.append(("inner", c, 0.0, float(leg.t_by[c])))
            spans.append(("idle", c, float(leg.t_by[c]),
                          float(leg.idle_by[c])))
        if t_comm > 0:
            # delayed rounds ship LAST round's delta while training, so the
            # modeled wire span starts at 0; synchronous (and per-step
            # allreduce) rounds put it after the compute leg
            wire_start = (0.0 if (sc.delay and not sc.allreduce_per_step)
                          else float(t_compute))
            for c in alive_ids:
                spans.append(("wire", c, wire_start, float(t_comm)))

        events.append(RoundEvent(
            round=r, alive=alive_ids,
            rejoined=tuple(int(i) for i in np.flatnonzero(rejoined)),
            h_steps=h_t, rank=rank_t, t_compute_s=t_compute,
            t_comm_s=t_comm, exposed_comm_s=exposed, t_round_s=t_round,
            wire_bytes=wire, slowest_cluster=slowest,
            bottleneck_cluster=bottleneck, tokens=tokens,
            faults=sc.faults.active(r), loss=loss, param_hash=param_hash,
            wire_bytes_total=wire_total, disagreement=disagreement,
            ranks=ranks_tuple,
            h_by=(tuple(h_map[c] for c in alive_ids) if h_active and n_alive
                  else None),
            t_compute_by=(tuple(leg.t_by[c] for c in alive_ids)
                          if n_alive else None),
            idle_by=(tuple(leg.idle_by[c] for c in alive_ids)
                     if n_alive else None),
            spans=(tuple(spans) if spans else None)))

    run_barrier(sc.rounds, _barrier_round)

    tl = Timeline(scenario=sc.meta(), events=events)
    if num is not None:
        tl.final_params = num["state"].params      # handy for callers/tests
    return tl


# ---------------------------------------------------------------------------
# bounded-staleness async rounds (sync="bounded_stale")
# ---------------------------------------------------------------------------

def async_modeled_times(sc: Scenario, wire: int, topo):
    """The bounded-stale engine's modeled timing callbacks, built from the
    same host arithmetic (``_jitter_factors`` salts 1/2, fault multipliers)
    the barrier path uses.  This is the ONE definition — the proc
    coordinator imports it too, so the engine's commit sequence (and every
    structural Timeline field) is identical across the two backends.

    Returns ``(leg_seconds, send_seconds, sends)`` where ``sends[c]`` is
    the number of uplink transfers charged per publish: gossip pushes to
    each graph neighbor; gather models the relay hub (one up + one down
    transfer).
    """
    C = sc.n_clusters
    sends = [topo.degree(c) if topo.is_gossip else (2 if C > 1 else 0)
             for c in range(C)]

    def leg_seconds(c: int, k: int) -> float:
        step_j = _jitter_factors(sc.seed, k, C, sc.link.jitter, salt=1)
        return float(sc.h_steps * sc.t_step_s
                     * sc.faults.step_multiplier(c, k) * step_j[c])

    def send_seconds(c: int, k: int) -> float:
        if sends[c] == 0:
            return 0.0
        bw_j = _jitter_factors(sc.seed, k, C, sc.link.jitter, salt=2)
        bw = float(sc.link.bytes_per_s * sc.faults.bandwidth_factor(c, k)
                   * bw_j[c])
        return float(sends[c] * wire / bw + sends[c] * sc.link.latency_s)

    return leg_seconds, send_seconds, sends


class _AsyncNumeric:
    """Per-cluster numeric executor for bounded-stale commits.

    Holds one (params, inner opt, outer opt, EF error, compressor state)
    replica per cluster plus a versioned store of *published* compressed
    deltas.  The engine's publish/commit split maps onto two entry points:
    :meth:`publish` (the ``on_publish`` callback) runs the inner leg and
    materializes the compressed — possibly Byzantine-corrupted — delta
    into the store the moment the leg finishes, so the version exists even
    while its publisher is still gate-blocked; :meth:`commit` then mixes
    the exact delta versions the engine recorded in ``AsyncCommit.used``
    and applies the outer step.  A ``used`` version missing from the store
    is an engine/executor contract violation and raises instead of
    silently substituting zeros (which would deflate the outer step while
    ``staleness_weights``/the trimmed mean still credited the row).

    Every jitted program mirrors the proc worker's sync arm op-for-op
    (``proc/worker.py``: ``inner_j``/``raw_j``/``compress_j``/``err_j``/
    ``outer_j`` with the same lambda structure), and the weighted mean runs
    through the same standalone jitted ``masked_cluster_mean`` the proc
    coordinator applies to the workers' reported rows — which is what makes
    the two backends' async param hashes bit-identical.

    Error feedback is the CLASSIC compressor-local form ``e = δ − C(δ)``
    (vs the worker's own uncorrupted hat), never Alg. 2's ``δ − Δ``: under
    partial/stale mixing the latter's ``I − W`` error iteration has
    spectral radius > 1 and diverges (see ``core.diloco._error_feedback``).
    """

    def __init__(self, sc: Scenario, numeric: NumericProblem, compressor,
                 W_base: np.ndarray):
        import jax
        import jax.numpy as jnp

        from repro.core import diloco, membership
        from repro.optim import nesterov

        if numeric.inner_fn_row is None:
            raise ValueError(
                "sync='bounded_stale' needs NumericProblem.inner_fn_row — "
                "the per-cluster H-step program a proc worker jits "
                "(QuadraticSpec.problem() provides it)")
        if not (numeric.compress and numeric.error_feedback):
            raise ValueError("bounded_stale models the compressed published "
                             "delta; compress/error_feedback must stay on")
        self.jax, self.jnp = jax, jnp
        self.C = sc.n_clusters
        self.W = np.asarray(W_base, np.float64)
        self.max_staleness = int(sc.max_staleness)
        self.trimmed = sc.aggregation == "trimmed_mean"
        self.faults = sc.faults
        self._stw = diloco.staleness_weights
        rank_scalar = (None if sc.rank is None
                       else jnp.asarray(sc.rank, jnp.int32))

        self.zeros = jax.tree.map(
            lambda x: jnp.zeros_like(x, jnp.float32), numeric.params)
        self._inner0 = [diloco.take_row(numeric.inner_opt_stacked, c)
                        for c in range(self.C)]
        self.params = [numeric.params for _ in range(self.C)]
        self.inner_opt = list(self._inner0)
        self.outer_opt = [nesterov.init(numeric.params)
                          for _ in range(self.C)]
        self.error = [self.zeros for _ in range(self.C)]
        self._comp0 = compressor.init_state(numeric.params)
        self.comp = [self._comp0 for _ in range(self.C)]
        self.store = [dict() for _ in range(self.C)]   # leg -> published hat
        # c -> (hat, inner_new, comp_new, losses) between publish and commit
        self._inflight: Dict[int, Tuple] = {}
        self.alive = (np.ones(self.C, bool) if sc.initial_alive is None
                      else np.asarray(sc.initial_alive, bool).copy())
        self.nesterov = nesterov

        # jitted programs — the worker's exact lambda structure
        self.inner_j = jax.jit(numeric.inner_fn_row)
        self.raw_j = jax.jit(lambda a, p, e: jax.tree.map(
            lambda ai, pi, ei: (ai.astype(jnp.float32)
                                - pi.astype(jnp.float32)) + ei, a, p, e))
        self.compress_j = jax.jit(
            lambda d, s: compressor.roundtrip(d, s, rank_scalar))
        self.err_j = jax.jit(lambda raw, D: jax.tree.map(
            lambda d, Di: d - Di, raw, D))
        self.outer_j = jax.jit(lambda D, o, p: nesterov.update(
            D, o, p, lr=numeric.outer_lr,
            momentum=numeric.outer_momentum))
        self.mean_j = jax.jit(membership.masked_cluster_mean)
        self.trim_j = jax.jit(
            lambda t, m: membership.trimmed_cluster_mean(t, m, sc.trim_k))
        self.corrupt_j = jax.jit(lambda t, s: jax.tree.map(
            lambda x: (s * x.astype(jnp.float32)).astype(x.dtype), t))

    def _stack(self, rows):
        jnp = self.jnp
        return self.jax.tree.map(lambda *xs: jnp.stack(xs), *rows)

    def publish(self, c: int, k: int, t: float) -> None:
        """Engine ``on_publish``: run leg ``k`` from the post-commit anchor
        (the engine schedules leg ``k`` only after leg ``k-1``'s commit, so
        nothing mutates cluster ``c`` between here and its commit) and
        materialize the published version the instant it exists."""
        jnp = self.jnp
        anchor = self.params[c]
        p_inner, inner_new, losses = self.inner_j(
            anchor, self.inner_opt[c], jnp.asarray(c, jnp.int32))
        raw = self.raw_j(anchor, p_inner, self.error[c])
        hat, comp_new = self.compress_j(raw, self.comp[c])
        # a Byzantine cluster corrupts what it PUBLISHES (everyone's mix
        # row, including its own) but keeps honest EF vs its clean hat —
        # the attack is on the wire, not on its local buffers
        scale = self.faults.byzantine_scale(c, k)
        pub = (hat if scale is None
               else self.corrupt_j(hat, jnp.asarray(scale, jnp.float32)))
        self.store[c][k] = pub
        self._inflight[c] = (self.err_j(raw, hat), inner_new, comp_new,
                             losses)

    def commit(self, ev):
        """One bounded-stale outer step; returns (loss, hash, disagreement).
        """
        jnp = self.jnp
        c, k = ev.cluster, ev.round
        anchor = self.params[c]
        err_new, inner_new, comp_new, losses = self._inflight.pop(c)

        used = dict(ev.used)
        rows = []
        for p in range(self.C):
            if p not in used:
                rows.append(self.zeros)        # weight/mask 0 anyway
            elif used[p] in self.store[p]:
                rows.append(self.store[p][used[p]])
            else:
                raise RuntimeError(
                    f"bounded-stale store miss: commit (c{c}, k{k}) uses "
                    f"version (c{p}, k{used[p]}) which was never "
                    f"materialized — engine publish/commit contract broken")
        stacked = self._stack(rows)
        if self.trimmed:
            mask = np.array([1.0 if p in used else 0.0
                             for p in range(self.C)], np.float32)
            Delta = self.trim_j(stacked, jnp.asarray(mask))
        else:
            stal = np.full((self.C,), -1, np.int64)
            for p, s_p in ev.staleness:
                stal[p] = s_p
            w = self._stw(self.W[c], stal, self.max_staleness)
            Delta = self.mean_j(stacked, jnp.asarray(w))
        params_new, outer_new = self.outer_j(Delta, self.outer_opt[c],
                                             anchor)
        self.params[c] = params_new
        self.inner_opt[c] = inner_new
        self.outer_opt[c] = outer_new
        self.error[c] = err_new
        self.comp[c] = comp_new
        # GC: the engine's arrived-publish watermarks are monotone (per
        # epoch), so versions below avail[p] can never be referenced again
        for p in range(self.C):
            for old in [v for v in self.store[p] if v < ev.avail[p]]:
                del self.store[p][old]

        from repro.topology.mixing import consensus_distance
        flat = np.stack(
            [np.concatenate([np.asarray(x).reshape(-1) for x in
                             self.jax.tree.leaves(self.params[p])])
             for p in range(self.C)], axis=0)
        return (float(np.mean(np.asarray(losses))),
                tree_hash(params_new),
                consensus_distance(flat, self.alive))

    def on_leave(self, c: int, k: int, t: float) -> None:
        self.alive[c] = False     # state freezes; nobody mixes it anymore

    def on_join(self, c: int, k: int, t: float) -> None:
        """Consensus bootstrap: a rejoiner is a fresh worker (proc respawn)
        restarting from the masked mean of the SURVIVORS' (params, outer
        momentum) — the same zero-masked rows through the same jitted
        ``masked_cluster_mean`` the proc coordinator uses."""
        jnp = self.jnp
        m = jnp.asarray(self.alive, jnp.float32)
        self.params[c] = self.mean_j(self._stack(self.params), m)
        mom = self.mean_j(
            self._stack([o.momentum for o in self.outer_opt]), m)
        self.outer_opt[c] = self.nesterov.NesterovState(
            step=jnp.zeros((), jnp.int32), momentum=mom)
        self.inner_opt[c] = self._inner0[c]
        self.error[c] = self.zeros
        self.comp[c] = self._comp0     # re-INIT, never zeroed (PowerSGD)
        self.store[c].clear()          # engine retires the old epoch too
        self._inflight.pop(c, None)
        self.alive[c] = True

    def final_params(self):
        return self._stack(self.params)


def _simulate_bounded_stale(sc: Scenario,
                            numeric: Optional[NumericProblem]) -> Timeline:
    """Drive ``BoundedStaleEngine`` over the scenario: modeled per-cluster
    leg/publish times from the SAME host arithmetic the barrier path uses
    (``_jitter_factors`` salts 1/2, fault multipliers), push-sum-supported
    mixing weights, and one :class:`RoundEvent` per committed outer step.

    ``sc.delay`` is ignored here on purpose: publish-at-finish means the
    send *always* overlaps the staleness wait and the next leg, which
    subsumes the §2.3 one-step-delay rule — ``exposed_comm_s`` records the
    gate wait instead.
    """
    from repro.core.compression import make_compressor
    from repro.topology import async_mix_weights

    if sc.topology_seed_schedule is not None:
        raise ValueError(
            "sync='bounded_stale' gates on a FIXED peer set per cluster; "
            "a per-round topology re-draw would change the staleness-gate "
            "semantics mid-flight (run dynamic topologies under barrier)")

    C = sc.n_clusters
    compressor = make_compressor(sc.compressor, **sc.compressor_kw)
    wire = int(compressor.wire_bytes(sc.shapes(), rank=sc.rank))
    topo = sc.topo()
    W_base = async_mix_weights(topo)
    peers = [tuple(p for p in range(C) if p != c and W_base[c, p] > 0.0)
             for c in range(C)]
    leg_seconds, send_seconds, sends = async_modeled_times(sc, wire, topo)

    execr = (None if numeric is None
             else _AsyncNumeric(sc, numeric, compressor, W_base))

    events = []

    def on_commit(ev) -> None:
        loss = param_hash = disagreement = None
        if execr is not None:
            loss, param_hash, disagreement = execr.commit(ev)
        c, k = ev.cluster, ev.round
        t_comp, wait, t_send = (float(ev.t_compute), float(ev.wait),
                                float(ev.t_send))
        spans = [("inner", c, 0.0, t_comp),
                 ("stale_wait", c, t_comp, wait)]
        if t_send > 0:
            spans.append(("wire", c, t_comp, t_send))
        spans.append(("leg", c, 0.0, t_comp + wait))
        events.append(RoundEvent(
            round=k, alive=ev.alive, rejoined=ev.rejoined,
            h_steps=sc.h_steps, rank=sc.rank,
            t_compute_s=t_comp, t_comm_s=t_send, exposed_comm_s=wait,
            t_round_s=t_comp + wait, wire_bytes=wire,
            slowest_cluster=c, bottleneck_cluster=c,
            tokens=sc.tokens_per_step * sc.h_steps / max(C, 1),
            faults=sc.faults.active(k), loss=loss, param_hash=param_hash,
            wire_bytes_total=wire * sends[c], disagreement=disagreement,
            t_compute_by=(t_comp,), idle_by=(wait,),
            spans=tuple(spans), cluster=c, staleness=ev.staleness,
            round_clock=ev.round_clock, t_start_s=float(ev.t_start)))

    alive0 = (None if sc.initial_alive is None
              else tuple(int(i) for i in
                         np.flatnonzero(np.asarray(sc.initial_alive, bool))))
    engine = BoundedStaleEngine(
        n_clusters=C, rounds=sc.rounds, max_staleness=sc.max_staleness,
        peers=peers, leg_seconds=leg_seconds, send_seconds=send_seconds,
        commit=on_commit,
        on_publish=(execr.publish if execr is not None else None),
        leaves=sc.faults.leave_events(),
        joins=sc.faults.join_events(), initial_alive=alive0,
        on_leave=(execr.on_leave if execr is not None else None),
        on_join=(execr.on_join if execr is not None else None))
    engine.run()

    tl = Timeline(scenario=sc.meta(), events=events)
    if execr is not None:
        tl.final_params = execr.final_params()
    return tl


# ---------------------------------------------------------------------------
# paper-method comparison (Fig. 4 / Table 1 / 357x as a runnable program)
# ---------------------------------------------------------------------------

def compare_methods(base: Scenario, rank: int = 64) -> Dict[str, Any]:
    """Run the paper's four methods through the *same* scenario (same link
    profile, same faults) and compare effective throughput.  Mirrors
    benchmarks/throughput.py's method table, but simulated round-by-round —
    so fault schedules change the ordering measurably instead of being
    outside the model."""
    H = base.h_steps
    variants = {
        "allreduce": replace(base, compressor="identity", compressor_kw={},
                             allreduce_per_step=True, delay=False, h_steps=1),
        "opendiloco": replace(base, compressor="fp16", compressor_kw={},
                              delay=False, h_steps=4 * H),
        "cocktail": replace(base, compressor="cocktail", compressor_kw={},
                            allreduce_per_step=True, delay=False, h_steps=1),
        "diloco_x": replace(base, compressor="diloco_x",
                            compressor_kw=dict(base.compressor_kw,
                                               rank=rank),
                            delay=True, h_steps=H),
    }
    timelines = {name: simulate(v) for name, v in variants.items()}
    tps = {name: tl.tokens_per_s for name, tl in timelines.items()}
    ar = tps["allreduce"]
    return {
        "tokens_per_s": tps,
        "speedup_vs_allreduce": {k: (v / ar if ar > 0 else float("inf"))
                                 for k, v in tps.items()},
        "timelines": timelines,
    }
