"""Event-driven round engine: one control plane for both sim backends.

This module extracts the round-loop control flow that ``sim/simulator.py``
and ``sim/proc/coordinator.py`` each hard-coded as ``for r in
range(rounds)`` into a shared engine with pluggable **outer-sync
policies**:

``sync="barrier"`` → :func:`run_barrier`
    The degenerate schedule: every cluster's round-``r`` leg ends at the
    same global barrier, so the event queue collapses to a lockstep
    iteration and the engine just drives the backend's whole-round body in
    today's exact order.  This path is bitwise-identical to the pre-engine
    loops by construction — the body is the same code called in the same
    sequence — which is what keeps every proc≡in-process CI gate alive
    through the refactor.

``sync="bounded_stale"`` → :class:`BoundedStaleEngine`
    SSP-style bounded-staleness asynchronous rounds (NoLoCo / OpenDiLoCo
    are the no-global-barrier reference points; see PAPERS.md).  Each
    cluster runs on its own round clock, *publishes* its compressed outer
    delta the moment a local leg finishes (the send overlaps whatever the
    cluster does next, generalizing the paper's §2.3 one-step-delay
    overlap), and *commits* an outer step eagerly against the freshest
    published peer deltas — gated so that no incorporated delta is more
    than ``max_staleness`` rounds older than the committing cluster's own
    round.  ``max_staleness=0`` degenerates to barrier cadence: nobody
    commits round ``k`` before every live peer has published round ``k``.

The engine is deliberately jax-free: it owns event ordering, per-cluster
round clocks, the staleness gate, and membership (leave/join) sequencing,
and delegates all timing arithmetic and all numerics to callbacks.  Both
backends construct those callbacks from identical Scenario-derived inputs,
so the engine's decision sequence — and therefore every structural
Timeline field, including ``staleness`` and ``round_clock`` — is
bit-for-bit reproducible across the in-process and multi-process backends.

Determinism contract: the heap is keyed ``(time, kind, cluster)`` with
publish-availability events ordered before leg-finish events at equal
times, blocked clusters are re-checked in sorted cluster order until a
fixpoint, and all clock arithmetic is plain python floats — two runs of
the same scenario produce the same commit sequence, which the CI
structural-fingerprint drift gate asserts.

Membership semantics under local clocks (documented in the sim README):
``Leave(c, r)`` fires when cluster ``c`` is about to *start* its local leg
``r``; ``Join(c, r)`` fires when the fleet frontier (the highest committed
leg index anywhere) reaches ``r - 1`` — the rejoiner adopts the frontier
clock and, until its first real publish, carries a *virtual* published
index equal to the frontier so it never retroactively stalls peers it was
not part of.  Its pre-leave publishes are retired for good: the join
resets the cluster's published watermark and bumps its publish epoch, so
an in-flight pre-leave arrival can never resurrect a version the numeric
backends discarded when they bootstrapped the fresh replica.  A blocked
cluster has always already published the leg it is waiting to commit
(publish happens at finish, commit is what the gate delays), so the
staleness gate cannot deadlock among live clusters.

Publish/commit split for the backends: ``on_publish(c, k, t)`` fires the
moment leg ``k`` finishes — BEFORE the gate is evaluated and before any
peer can observe the version — and is where a numeric backend must
materialize the published (compressed, possibly Byzantine-corrupted)
delta into its versioned store.  ``commit`` then only aggregates and
applies the outer step.  This is what guarantees every ``(peer, leg)``
pair in ``AsyncCommit.used`` exists in the store even when the publishing
peer is itself still gate-blocked: availability is a property of the
*publish*, never of the publisher's own commit.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

#: Outer-sync policies understood by ``Scenario.sync``.
SYNC_KINDS = ("barrier", "bounded_stale")

# heap tie-break at equal times: a delta that lands exactly when another
# cluster finishes its leg is visible to that cluster's gate
_AVAIL, _FINISH = 0, 1


def run_barrier(rounds: int, round_fn: Callable[[int], None]) -> None:
    """Drive the barrier policy: the backend's whole-round body, in order.

    Staleness bound 0 with a global clock makes every event queue
    permutation collapse to ``0..rounds-1`` — so the engine's barrier
    policy is exactly the sequential loop both backends ran before the
    refactor, and the bitwise CI gates survive unchanged.
    """
    for rnd in range(rounds):
        round_fn(rnd)


@dataclass(frozen=True)
class AsyncCommit:
    """One committed bounded-stale outer step, handed to the backend.

    ``used`` names the exact delta versions incorporated (``(peer, leg)``
    pairs, self first, then peers in cluster order) so a numeric executor
    can fetch them from its versioned store; ``staleness`` is the parallel
    ``(peer, rounds_stale)`` view recorded on the Timeline (self is always
    0; a peer that is *ahead* clamps to 0).
    """

    cluster: int                  # owner of this outer step
    round: int                    # owner's local leg index k
    t_start: float                # global modeled clock at leg start
    t_compute: float              # seconds of local compute for this leg
    t_send: float                 # modeled publish (uplink) seconds
    wait: float                   # staleness-gate wait after finishing
    t_commit: float               # global clock when the outer step ran
    used: Tuple[Tuple[int, int], ...]
    staleness: Tuple[Tuple[int, int], ...]
    alive: Tuple[int, ...]        # alive cluster ids at commit time
    rejoined: Tuple[int, ...]     # (c,) on the first commit after a Join
    round_clock: Tuple[int, ...]  # per-cluster committed-leg counters
    avail: Tuple[int, ...]        # per-cluster arrived-publish watermarks;
                                  # versions below avail[p] can never be
                                  # referenced again (avail is monotone per
                                  # epoch), so backends may GC them


class BoundedStaleEngine:
    """Deterministic event queue over per-cluster round clocks.

    Parameters
    ----------
    peers:
        Per-cluster in-neighbor ids (excluding self) — the clusters whose
        published deltas this cluster incorporates, i.e. the support of
        its row of the (push-sum) mixing weights.  The staleness gate
        ranges over exactly this set.
    leg_seconds / send_seconds:
        ``(cluster, leg) -> float`` modeled compute / publish times.
    commit:
        Called once per committed outer step with an :class:`AsyncCommit`.
    on_publish:
        ``(cluster, leg, t_finish)`` — fired at leg finish, before the
        gate is evaluated and before any peer can commit against the new
        version.  Numeric backends materialize the published delta here
        (see module docstring); timing-only callers may omit it.
    leaves / joins:
        ``(round, cluster)`` membership events (see module docstring for
        the local-clock semantics).
    """

    def __init__(
        self,
        *,
        n_clusters: int,
        rounds: int,
        max_staleness: int,
        peers: Sequence[Sequence[int]],
        leg_seconds: Callable[[int, int], float],
        send_seconds: Callable[[int, int], float],
        commit: Callable[[AsyncCommit], None],
        on_publish: Optional[Callable[[int, int, float], None]] = None,
        leaves: Iterable[Tuple[int, int]] = (),
        joins: Iterable[Tuple[int, int]] = (),
        initial_alive: Optional[Sequence[int]] = None,
        on_leave: Optional[Callable[[int, int, float], None]] = None,
        on_join: Optional[Callable[[int, int, float], None]] = None,
    ) -> None:
        if rounds < 1:
            raise ValueError("rounds must be >= 1")
        if max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        self.n = int(n_clusters)
        self.rounds = int(rounds)
        self.s = int(max_staleness)
        self.peers = [tuple(sorted(int(p) for p in peers[c]))
                      for c in range(self.n)]
        self._leg_seconds = leg_seconds
        self._send_seconds = send_seconds
        self._commit_cb = commit
        self._on_publish = on_publish
        self._on_leave = on_leave
        self._on_join = on_join
        self._leave_set = {(int(r), int(c)) for r, c in leaves}
        self._pending_joins: List[Tuple[int, int]] = sorted(
            (int(r), int(c)) for r, c in joins)
        if initial_alive is None:
            self._alive = [True] * self.n
        else:
            live = {int(c) for c in initial_alive}
            self._alive = [c in live for c in range(self.n)]
        self._committed = [-1] * self.n   # highest committed leg
        self._avail = [-1] * self.n       # highest peer-visible published leg
        self._virtual = [-1] * self.n     # rejoiner gate floor (pre-publish)
        self._own = [-1] * self.n         # highest locally finished leg
        self._epoch = [0] * self.n        # publish epoch; bumped on Join so
                                          # in-flight pre-leave arrivals die
        self._frontier = -1               # max committed leg fleet-wide
        self._rejoin_pending: set = set()
        # c -> (k, t_finish, t_start, t_leg, t_send) awaiting the gate
        self._blocked: Dict[int, Tuple[int, float, float, float, float]] = {}
        self._heap: List[Tuple[float, int, int, int, int]] = []
        self._leg_meta: Dict[int, Tuple[int, float, float]] = {}

    # ------------------------------------------------------------------ run

    def run(self) -> None:
        """Process events until every live cluster has committed its last
        leg (or left).  Raises ``RuntimeError`` on an engine deadlock —
        impossible by construction, kept as a bug tripwire."""
        self._fire_joins(t=0.0)           # Join(c, 0): alive from the start
        for c in range(self.n):
            if self._alive[c]:
                self._schedule_leg(c, 0, 0.0)
        while self._heap:
            t, kind, c, k, epoch = heapq.heappop(self._heap)
            if kind == _AVAIL:
                if epoch != self._epoch[c]:
                    continue              # pre-leave publish of a rejoiner:
                                          # the version was discarded at the
                                          # join bootstrap, never resurrect
                if k > self._avail[c]:
                    self._avail[c] = k
                self._recheck_blocked(t)
            else:
                if not self._alive[c]:
                    continue              # left while this event was queued
                self._finish(c, k, t)
        if self._blocked:
            raise RuntimeError(
                f"bounded-stale engine deadlock: blocked={self._blocked}")

    # ----------------------------------------------------------- internals

    def _schedule_leg(self, c: int, k: int, t: float) -> None:
        if k >= self.rounds:
            return                         # this cluster is done
        if (k, c) in self._leave_set:
            self._alive[c] = False
            if self._on_leave is not None:
                self._on_leave(c, k, t)
            self._recheck_blocked(t)       # shrinking a gate set can unblock
            return
        dur = float(self._leg_seconds(c, k))
        self._leg_meta[c] = (k, t, dur)
        heapq.heappush(self._heap, (t + dur, _FINISH, c, k, self._epoch[c]))

    def _finish(self, c: int, k: int, t: float) -> None:
        # publish first: the delta exists now and the send overlaps the
        # gate wait and the next leg (the async generalization of §2.3).
        # on_publish materializes the version BEFORE any gate/commit can
        # reference it — a gate-blocked publisher's delta is still real.
        t_send = float(self._send_seconds(c, k))
        self._own[c] = k
        if self._on_publish is not None:
            self._on_publish(c, k, t)
        heapq.heappush(self._heap, (t + t_send, _AVAIL, c, k, self._epoch[c]))
        _, t_start, t_leg = self._leg_meta[c]
        if self._gate_ok(c, k):
            self._commit(c, k, t, t, t_start, t_leg, t_send)
        else:
            self._blocked[c] = (k, t, t_start, t_leg, t_send)

    def _gate_ok(self, c: int, k: int) -> bool:
        floor = k - self.s
        for p in self.peers[c]:
            if not self._alive[p]:
                continue
            if max(self._avail[p], self._virtual[p]) < floor:
                return False
        return True

    def _commit(self, c: int, k: int, t: float, t_finish: float,
                t_start: float, t_leg: float, t_send: float) -> None:
        used = [(c, self._own[c])]
        stal = [(c, 0)]
        for p in self.peers[c]:
            # incorporate only deltas that respect the bound themselves: a
            # rejoiner's *virtual* index satisfies the gate (it must not
            # stall peers) but its last real publish predates the leave —
            # mixing that would smuggle in a delta older than max_staleness
            if self._alive[p] and self._avail[p] >= 0 \
                    and self._avail[p] >= k - self.s:
                idx = self._avail[p]
                used.append((p, idx))
                stal.append((p, max(0, k - idx)))
        self._committed[c] = k
        rejoined: Tuple[int, ...] = ()
        if c in self._rejoin_pending:
            self._rejoin_pending.discard(c)
            rejoined = (c,)
        ev = AsyncCommit(
            cluster=c, round=k, t_start=t_start, t_compute=t_leg,
            t_send=t_send, wait=t - t_finish, t_commit=t,
            used=tuple(used), staleness=tuple(stal),
            alive=tuple(i for i in range(self.n) if self._alive[i]),
            rejoined=rejoined,
            round_clock=tuple(self._committed),
            avail=tuple(self._avail),
        )
        self._commit_cb(ev)
        if k > self._frontier:
            self._frontier = k
            self._fire_joins(t)
        self._schedule_leg(c, k + 1, t)

    def _fire_joins(self, t: float) -> None:
        while self._pending_joins and \
                self._pending_joins[0][0] <= self._frontier + 1:
            _, c = self._pending_joins.pop(0)
            if self._alive[c]:
                continue                  # joining a live cluster is a no-op
            self._alive[c] = True
            self._committed[c] = self._frontier
            self._virtual[c] = self._frontier
            # the rejoiner is a FRESH replica: its pre-leave publishes are
            # gone from the numeric stores, so retire them here too (new
            # epoch kills in-flight arrivals; watermark back to "nothing
            # published") — only current-epoch versions ever enter `used`
            self._avail[c] = -1
            self._epoch[c] += 1
            self._rejoin_pending.add(c)
            if self._on_join is not None:
                self._on_join(c, self._frontier + 1, t)
            self._schedule_leg(c, self._frontier + 1, t)

    def _recheck_blocked(self, t: float) -> None:
        # commits fired here can trigger joins/leaves that change other
        # clusters' gate sets, so iterate to a fixpoint in sorted order
        changed = True
        while changed:
            changed = False
            for c in sorted(self._blocked):
                k, t_finish, t_start, t_leg, t_send = self._blocked[c]
                if self._gate_ok(c, k):
                    del self._blocked[c]
                    self._commit(c, k, t, t_finish, t_start, t_leg, t_send)
                    changed = True
