"""Problem-kind registry for the simulator backends (jax-free import).

A numeric problem crosses the process boundary as a JSON dict with a
``kind`` discriminator (``spec.to_dict()``).  The proc worker rebuilds its
spec through ``problem_from_dict`` instead of hard-wiring one spec class,
and reads ``xla_device_count`` *jax-free* — the pp engine needs
``--xla_force_host_platform_device_count`` in XLA_FLAGS before the
worker's first jax import, so the count must come from the raw dict.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.sim.quadratic import QuadraticSpec


def problem_from_dict(d: Dict[str, Any]):
    """Rebuild a problem spec from its ``to_dict()`` payload."""
    kind = d.get("kind", "quadratic")
    if kind == "quadratic":
        return QuadraticSpec.from_dict(d)
    if kind == "pp_lm":
        from repro.sim.pp_problem import PPSpec
        return PPSpec.from_dict(d)
    raise ValueError(f"unknown problem kind {kind!r}")


def xla_device_count(d: Dict[str, Any]) -> int:
    """Faked host devices the hosting process needs for this problem dict
    (1 = no pipeline mesh; computed without importing jax)."""
    if d.get("kind") == "pp_lm":
        return int(d.get("data_parallel", 1)) * int(d.get("n_stages", 1))
    return 1
