"""Serializable pipeline-parallel LM problem for the simulator backends.

The pp counterpart of ``sim/quadratic.QuadraticSpec``: a tiny dense
decoder LM (``configs.base.reduced`` dims) whose inner loop runs H AdamW
steps through the sharded GPipe pipeline loss
(``parallel.inner_engine.make_pp_one_cluster``) on a per-cluster
("data","model") unit mesh of faked host devices — the real thing the
proc worker and the in-process simulator both execute when
``Scenario.inner_engine == "pp"``.

Same bitwise discipline as the quadratic:

 - ``one_cluster_fn()`` / ``one_cluster_fn_h()`` expose the exact worker
   signatures ``(params_g, opt, c[, h])``; the cluster index is traced and
   only feeds integer PRNG derivations (batch keys), so constant-folding
   it in the in-process unroll cannot perturb the float arithmetic.
 - ``problem()`` lifts them with a python-level unroll over clusters
   (``make_pp_inner_fns``), NOT vmap — vmapping the pipeline's matmuls
   would change accumulation order by ~1 ulp (the ``per_cluster_compress``
   lesson).
 - Batches are **round-invariant** (keyed by seed, cluster, inner step
   only): the worker's inner function takes no round index, so any
   round-dependence would silently diverge the two backends.

The process (main or worker) must initialize jax with at least
``xla_device_count`` faked devices; ``repro.sim.problems`` exposes the
count jax-free so ``proc/worker.py`` can set XLA_FLAGS before its first
jax import.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Any, Dict

import numpy as np


@dataclass(frozen=True)
class PPSpec:
    """Cluster c trains a reduced dense decoder on its own synthetic token
    stream through the pipeline-parallel inner engine.  Heterogeneity
    comes from the per-cluster data (distinct PRNG folds), like real
    decentralized corpora — not from a target offset."""
    n_clusters: int
    arch: str = "granite-3-8b"
    n_layers: int = 2
    vocab_size: int = 64
    seq_len: int = 8
    local_batch: int = 4
    n_stages: int = 2
    n_micro: int = 2
    data_parallel: int = 1
    h_steps: int = 2
    inner_lr: float = 1e-3
    seed: int = 0
    outer_lr: float = 0.7
    outer_momentum: float = 0.5

    # ---- serialization (worker subprocess bootstrap) ----------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "pp_lm", **asdict(self)}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "PPSpec":
        d = dict(d)
        if d.pop("kind", "pp_lm") != "pp_lm":
            raise ValueError(f"unknown problem kind {d!r}")
        return PPSpec(**d)

    @property
    def engine(self) -> str:
        """Inner-engine tag cross-checked against Scenario.inner_engine."""
        return "pp"

    @property
    def xla_device_count(self) -> int:
        """Devices the hosting process must fake BEFORE jax initializes
        (``--xla_force_host_platform_device_count``)."""
        return self.data_parallel * self.n_stages

    # ---- deterministic construction ---------------------------------------
    def model_config(self):
        import dataclasses

        from repro.configs.base import get_config

        cfg = get_config(self.arch).reduced()
        return dataclasses.replace(cfg, n_layers=self.n_layers,
                                   vocab_size=self.vocab_size)

    def _engine(self):
        from repro.parallel import inner_engine as IE
        from repro.parallel import pipeline as PP

        cfg = self.model_config()
        pcfg = PP.PipelineConfig(n_stages=self.n_stages,
                                 n_micro=self.n_micro)
        mesh = IE.unit_mesh(pcfg, self.data_parallel)
        return cfg, pcfg, mesh

    def batch_fn(self):
        """(c, i) -> tokens (B, S), round-invariant (see module doc)."""
        import jax

        base = jax.random.PRNGKey(self.seed + 13)
        B, S, V = self.local_batch, self.seq_len, self.vocab_size

        def fn(c, i):
            key = jax.random.fold_in(jax.random.fold_in(base, c), i)
            return jax.random.randint(key, (B, S), 0, V)

        return fn

    def init_params(self):
        import jax

        from repro.parallel import pipeline as PP

        cfg = self.model_config()
        pcfg = PP.PipelineConfig(n_stages=self.n_stages,
                                 n_micro=self.n_micro)
        return PP.init_pp_params(cfg, jax.random.PRNGKey(self.seed), pcfg)

    def one_cluster_fn(self):
        """(params_global, inner_opt, c) -> (params_H, inner_opt', losses)
        — the exact per-cluster program a proc worker jits."""
        from repro.parallel import inner_engine as IE

        cfg, pcfg, mesh = self._engine()
        one, _ = IE.make_pp_one_cluster(cfg, pcfg, mesh,
                                        inner_lr=self.inner_lr,
                                        h_steps=self.h_steps,
                                        batch_fn=self.batch_fn())
        return one

    def one_cluster_fn_h(self):
        """(params_global, inner_opt, c, h) -> (params, opt', mean_loss):
        the masked fixed-length variant (``diloco.masked_local_steps``);
        uniform-at-budget rounds must dispatch to ``one_cluster_fn`` (the
        PR 5 rule — the masked program compiles differently)."""
        from repro.parallel import inner_engine as IE

        cfg, pcfg, mesh = self._engine()
        _, one_h = IE.make_pp_one_cluster(cfg, pcfg, mesh,
                                          inner_lr=self.inner_lr,
                                          h_steps=self.h_steps,
                                          batch_fn=self.batch_fn())
        return one_h

    def problem(self):
        """The in-process ``NumericProblem`` (unrolled over clusters),
        tagged ``engine="pp"`` so ``simulate`` can cross-check it against
        ``Scenario.inner_engine``."""
        import jax
        import jax.numpy as jnp

        from repro.optim import adamw
        from repro.parallel import inner_engine as IE
        from repro.sim.simulator import NumericProblem

        params = self.init_params()
        one = self.one_cluster_fn()
        one_h = self.one_cluster_fn_h()
        inner_fn, inner_fn_h = IE.make_pp_inner_fns(one, one_h,
                                                    self.n_clusters)

        opt0 = adamw.init(params)
        inner_stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_clusters,)
                                       + x.shape).copy(), opt0)

        return NumericProblem(params=params,
                              inner_opt_stacked=inner_stacked,
                              inner_fn=inner_fn, outer_lr=self.outer_lr,
                              outer_momentum=self.outer_momentum,
                              inner_fn_h=inner_fn_h, engine="pp")
