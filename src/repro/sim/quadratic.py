"""Serializable quadratic test problem for the simulator backends.

``make_quadratic_problem`` (re-exported from ``repro.sim``) historically
built the tiny per-cluster least-squares instance as opaque closures.  The
multi-process backend needs to rebuild the *same* problem inside a worker
subprocess from a JSON-able description, so the construction now lives in
``QuadraticSpec``:

 - ``spec.problem()``          -> the in-process ``NumericProblem`` (vmapped
   inner_fn), exactly what ``simulate(sc, numeric=...)`` consumes;
 - ``spec.one_cluster_fn()``   -> the single-cluster H-step inner function a
   proc worker jits for itself;
 - ``spec.init_params()``      -> deterministic initial global params.

Both views are built from the same PRNG derivations, and the single-cluster
function is the exact per-cluster slice of the vmapped one — together with
``core.diloco.per_cluster_compress`` this is what makes the proc backend's
outer deltas bit-identical to the in-process simulator.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Any, Dict

import numpy as np


@dataclass(frozen=True)
class QuadraticSpec:
    """Cluster c minimizes 0.5*||W - T_c||^2 with T_c = T* + hetero*off_c.
    Cheap enough for tier-1, but it exercises the full round machinery
    (AdamW inner, Nesterov outer, compression round-trips, error feedback,
    one-step delay)."""
    n_clusters: int
    d: int = 16
    n_mats: int = 2
    h_steps: int = 8
    inner_lr: float = 3e-2
    hetero: float = 0.1
    seed: int = 0
    outer_lr: float = 0.7
    outer_momentum: float = 0.5

    # ---- serialization (worker subprocess bootstrap) ----------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "quadratic", **asdict(self)}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "QuadraticSpec":
        d = dict(d)
        if d.pop("kind", "quadratic") != "quadratic":
            raise ValueError(f"unknown problem kind {d!r}")
        return QuadraticSpec(**d)

    # ---- deterministic construction ---------------------------------------
    def _arrays(self):
        import jax
        import jax.numpy as jnp

        key = jax.random.PRNGKey(self.seed)
        k_init, k_tgt, k_off = jax.random.split(key, 3)
        params = {f"w{i}": 0.5 * jax.random.normal(
            jax.random.fold_in(k_init, i), (self.d, self.d), jnp.float32)
            for i in range(self.n_mats)}
        target = {k: jax.random.normal(jax.random.fold_in(k_tgt, i),
                                       (self.d, self.d))
                  for i, k in enumerate(params)}
        offsets = {k: self.hetero * jax.random.normal(
            jax.random.fold_in(k_off, i), (self.n_clusters, self.d, self.d))
            for i, k in enumerate(params)}
        return params, target, offsets

    def init_params(self):
        return self._arrays()[0]

    def cluster_loss_fn(self):
        import jax.numpy as jnp

        _, target, offsets = self._arrays()

        def cluster_loss(p, c):
            per = [jnp.sum((p[k] - (target[k] + offsets[k][c])) ** 2)
                   for k in p]
            return 0.5 * sum(per) / len(per)

        return cluster_loss

    def one_cluster_fn(self):
        """(params_global, inner_opt, c) -> (params_H, inner_opt', losses):
        H AdamW steps for one cluster — what a proc worker runs, and the
        exact per-cluster slice of ``problem()``'s vmapped inner_fn."""
        import jax

        from repro.optim import adamw

        cluster_loss = self.cluster_loss_fn()
        h, lr = self.h_steps, self.inner_lr

        def one_cluster(params_g, opt_state, c):
            def step(carry, _):
                p, o = carry
                loss, g = jax.value_and_grad(
                    lambda q: cluster_loss(q, c))(p)
                p, o = adamw.update(g, o, p, lr=lr)
                return (p, o), loss

            (p, o), losses = jax.lax.scan(step, (params_g, opt_state),
                                          None, length=h)
            return p, o, losses

        return one_cluster

    def one_cluster_fn_h(self):
        """(params_global, inner_opt, c, h) -> (params, opt', mean_loss):
        the per-cluster-H variant — a fixed ``self.h_steps``-length scan
        of which only the first ``h`` (traced) steps apply
        (``core.diloco.masked_local_steps``).  With ``h == h_steps`` the
        carried state is bit-identical to ``one_cluster_fn()``; a proc
        worker jits this with its own scalar ``h`` while ``problem()``
        vmaps it over the schedule vector — the same op sequence per
        cluster (the quadratic stays matmul-free, so vmapping does not
        perturb the arithmetic)."""
        import jax

        from repro.core.diloco import masked_local_steps
        from repro.optim import adamw

        cluster_loss = self.cluster_loss_fn()
        h_max, lr = self.h_steps, self.inner_lr

        def one_cluster_h(params_g, opt_state, c, h):
            def step(carry, _i):
                p, o = carry
                loss, g = jax.value_and_grad(
                    lambda q: cluster_loss(q, c))(p)
                p, o = adamw.update(g, o, p, lr=lr)
                return (p, o), loss

            (p, o), mean_loss = masked_local_steps(
                step, (params_g, opt_state), h_max, h)
            return p, o, mean_loss

        return one_cluster_h

    def problem(self):
        """The in-process ``NumericProblem`` (vmapped over clusters)."""
        import jax
        import jax.numpy as jnp

        from repro.optim import adamw
        from repro.sim.simulator import NumericProblem

        params = self.init_params()
        cluster_loss = self.cluster_loss_fn()
        one_cluster = self.one_cluster_fn()
        one_cluster_h = self.one_cluster_fn_h()
        n = self.n_clusters

        opt0 = adamw.init(params)
        inner_stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), opt0)

        def inner_fn(params_g, inner_opt_stacked, t):
            f = lambda opt, c: one_cluster(params_g, opt, c)
            return jax.vmap(f)(inner_opt_stacked, jnp.arange(n))

        def inner_fn_stacked(params_stacked, inner_opt_stacked, t):
            # gossip mode: every cluster trains from its OWN params row.
            # The quadratic is elementwise + per-matrix reductions, so the
            # vmapped rows stay bit-identical to a lone worker running
            # one_cluster on its row (matmul-free — the property the
            # sim/proc equivalence gate leans on).
            return jax.vmap(one_cluster)(params_stacked, inner_opt_stacked,
                                         jnp.arange(n))

        def inner_fn_h(params_g, inner_opt_stacked, t, h_vec):
            # per-cluster H: each row runs its own h_vec[c] of the shared
            # masked scan; aux is the per-cluster mean loss
            f = lambda opt, c, h: one_cluster_h(params_g, opt, c, h)
            return jax.vmap(f)(inner_opt_stacked, jnp.arange(n), h_vec)

        def inner_fn_h_stacked(params_stacked, inner_opt_stacked, t, h_vec):
            return jax.vmap(one_cluster_h)(params_stacked,
                                           inner_opt_stacked,
                                           jnp.arange(n), h_vec)

        def eval_fn(p):
            return float(np.mean([float(cluster_loss(p, c))
                                  for c in range(n)]))

        return NumericProblem(params=params, inner_opt_stacked=inner_stacked,
                              inner_fn=inner_fn, outer_lr=self.outer_lr,
                              outer_momentum=self.outer_momentum,
                              eval_fn=eval_fn,
                              inner_fn_stacked=inner_fn_stacked,
                              inner_fn_h=inner_fn_h,
                              inner_fn_h_stacked=inner_fn_h_stacked,
                              inner_fn_row=one_cluster)


def make_quadratic_problem(n_clusters: int, *, d: int = 16, n_mats: int = 2,
                           h_steps: int = 8, inner_lr: float = 3e-2,
                           hetero: float = 0.1, seed: int = 0,
                           outer_lr: float = 0.7, outer_momentum: float = 0.5):
    """Back-compat wrapper: build the spec and return the in-process
    ``NumericProblem`` (the historical return type)."""
    return QuadraticSpec(n_clusters=n_clusters, d=d, n_mats=n_mats,
                         h_steps=h_steps, inner_lr=inner_lr, hetero=hetero,
                         seed=seed, outer_lr=outer_lr,
                         outer_momentum=outer_momentum).problem()
