"""Virtual decentralized-cluster simulator (fault injection + timing).

Runs the full DiLoCoX round loop (core/diloco.py) over N *simulated*
clusters connected by modeled slow links (core/comm.py arithmetic), with
injectable faults: stragglers, link degradation, membership churn
(core/membership.py semantics). See README.md in this directory.
"""
from repro.sim.engine import (SYNC_KINDS, AsyncCommit, BoundedStaleEngine,
                              run_barrier)
from repro.sim.faults import (Byzantine, FaultSchedule, Join, Leave,
                              LinkDegradation, Straggler)
from repro.sim.pp_problem import PPSpec
from repro.sim.problems import problem_from_dict
from repro.sim.quadratic import QuadraticSpec
from repro.sim.scenario import LinkProfile, Scenario, synthetic_shapes
from repro.sim.simulator import (NumericProblem, compare_methods,
                                 make_quadratic_problem, simulate)
from repro.sim.timeline import (RoundEvent, Timeline, combine_row_hashes,
                                tree_hash)

__all__ = [
    "SYNC_KINDS", "AsyncCommit", "BoundedStaleEngine", "run_barrier",
    "Byzantine",
    "FaultSchedule", "Join", "Leave", "LinkDegradation", "Straggler",
    "LinkProfile", "Scenario", "synthetic_shapes", "QuadraticSpec",
    "PPSpec", "problem_from_dict",
    "NumericProblem", "compare_methods", "make_quadratic_problem",
    "simulate", "RoundEvent", "Timeline", "tree_hash", "combine_row_hashes",
]
