"""Scenario description for the virtual decentralized cluster.

A ``Scenario`` is everything the simulator needs to replay a decentralized
training run deterministically: cluster count, round/local-step budget,
the link model, a fault schedule, and the compression method whose wire
bytes (core.compression accounting — the same accounting the paper's
Fig. 4 numbers come from) drive the comm times.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.core import comm
from repro.core.adaptive import AdaptiveSpec, HSpec
from repro.sim.faults import FaultSchedule


@dataclass(frozen=True)
class LinkProfile:
    """Per-link WAN model.  ``jitter`` is the fractional sigma of the
    deterministic per-(round, cluster) lognormal-ish noise applied to both
    step time and bandwidth (0 = the paper's idealized constant link)."""
    bytes_per_s: float = comm.GBPS       # 1 Gbps, the paper's setting
    latency_s: float = 0.0               # per forwarding hop
    jitter: float = 0.0


def synthetic_shapes(n_params: float, n_mats: int = 8
                     ) -> Dict[str, Tuple[int, ...]]:
    """A stand-in parameter tree of ``n_mats`` square matrices totalling
    ~n_params elements, so compressor wire accounting (incl. the low-rank
    (m+n)*r arithmetic) behaves like a real model of that size without
    building one."""
    d = max(8, int(round((n_params / max(n_mats, 1)) ** 0.5)))
    return {f"w{i}": (d, d) for i in range(n_mats)}


@dataclass(frozen=True)
class Scenario:
    n_clusters: int = 4
    rounds: int = 20
    h_steps: int = 30                    # H local steps per outer round
    t_step_s: float = 1.0                # §2.4.1 baseline local step time
    tokens_per_step: int = 36_000        # global tokens per local step
    link: LinkProfile = field(default_factory=LinkProfile)
    faults: FaultSchedule = field(default_factory=FaultSchedule)

    # method knobs (the Fig. 4 / Table 1 axes)
    compressor: str = "diloco_x"
    compressor_kw: Dict[str, Any] = field(default_factory=dict)
    rank: Optional[int] = None           # wire-accounting rank r_t override

    # §2.4 adaptive compression: an ``core.adaptive.AdaptiveSpec`` enables
    # the spectral/bandwidth/hybrid controller on BOTH backends (the proc
    # coordinator broadcasts the per-round decision in the round header);
    # None = fixed rank.  ``spec.r1=None`` resolves to the compressor rank.
    adaptive: Optional[AdaptiveSpec] = None

    # heterogeneous local-step scheduling (``core.adaptive.HSpec``): None/
    # "global" runs the uniform h_steps budget everywhere (the paper's
    # setting); policy="balance" sets each cluster's H from its modeled
    # step time so all clusters land near the barrier together (slow
    # sites do fewer local steps), clamped under gossip by the mixing
    # matrix's spectral-gap certificate.  Applied by BOTH backends,
    # including the numeric leg (masked fixed-length scan).
    h_spec: Optional[HSpec] = None
    delay: bool = True                   # §2.3 one-step-delay overlap
    allreduce_per_step: bool = False     # vanilla-DDP/CocktailSGD style:
                                         # ring allreduce EVERY local step

    # outer-sync communication pattern (repro.topology): "star" is the
    # seed hub/gather, "full" the same average with all-to-all accounting,
    # "ring"/"torus"/"random" are neighbor-gossip mixing graphs
    topology: str = "star"
    topology_degree: int = 0             # random k-regular degree (0=auto)
    topology_seed: int = 0               # random topology edge seed
    # dynamic time-varying topology (NoLoCo-style fresh random partners):
    # a per-round seed schedule for the "random" kind — round r draws the
    # k-regular graph from seed schedule[r % len] instead of the fixed
    # topology_seed.  In-process backend only (proc raises).
    topology_seed_schedule: Optional[Tuple[int, ...]] = None

    # outer-sync policy (sim/engine.py): "barrier" is the historical
    # lockstep round loop (staleness bound 0 on a global clock; bitwise-
    # identical to the pre-engine backends); "bounded_stale" runs SSP-
    # style async rounds — each cluster commits an outer step the moment
    # its local leg finishes, mixing the freshest published peer deltas
    # through push-sum weights, gated so no incorporated delta is more
    # than max_staleness rounds older than its own clock.
    sync: str = "barrier"
    max_staleness: int = 1

    # bounded-stale aggregation: "mean" is the staleness-discounted
    # weighted mean (push-sum debiased); "trimmed_mean" drops the
    # coordinate-wise top/bottom trim_k candidate rows before averaging
    # (core.membership.trimmed_cluster_mean) — the robust defense against
    # a Byzantine cluster's corrupted deltas.
    aggregation: str = "mean"
    trim_k: int = 1

    # inner engine: "scalar" is the historical single-replica inner loop
    # (quadratic/trainer vmap); "pp" runs each cluster's H local steps
    # through the sharded pipeline-parallel engine
    # (parallel/inner_engine.py) on a per-cluster ("data","model") mesh of
    # faked host devices.  Timing-only scenarios may declare either (the
    # engine only changes the numeric leg); numeric runs cross-check the
    # declared engine against the problem's ``engine`` tag.
    inner_engine: str = "scalar"

    # what is being shipped: explicit shapes win; else a synthetic tree
    param_shapes: Optional[Dict[str, Tuple[int, ...]]] = None
    n_params: float = 1.0e9

    # initial membership (default: everyone alive)
    initial_alive: Optional[Tuple[bool, ...]] = None

    seed: int = 0

    def shapes(self) -> Dict[str, Tuple[int, ...]]:
        if self.param_shapes is not None:
            return dict(self.param_shapes)
        return synthetic_shapes(self.n_params)

    def topo(self, rnd: Optional[int] = None):
        """The ``repro.topology.Topology`` this scenario communicates
        over (built fresh; Topology construction is deterministic).
        With a ``topology_seed_schedule``, ``rnd`` selects round ``rnd``'s
        fresh random graph (``rnd=None`` gives the base graph)."""
        from repro.topology import make_topology
        seed = self.topology_seed
        if rnd is not None and self.topology_seed_schedule:
            seed = int(self.topology_seed_schedule[
                rnd % len(self.topology_seed_schedule)])
        return make_topology(self.topology, self.n_clusters,
                             degree=self.topology_degree, seed=seed)

    def __post_init__(self):
        if self.inner_engine not in ("scalar", "pp"):
            raise ValueError(
                f"inner_engine must be 'scalar' or 'pp', "
                f"got {self.inner_engine!r}")
        from repro.sim.engine import SYNC_KINDS
        if self.sync not in SYNC_KINDS:
            raise ValueError(
                f"sync must be one of {SYNC_KINDS}, got {self.sync!r}")
        if self.aggregation not in ("mean", "trimmed_mean"):
            raise ValueError(
                f"aggregation must be 'mean' or 'trimmed_mean', "
                f"got {self.aggregation!r}")
        if self.sync == "bounded_stale":
            if self.max_staleness < 0:
                raise ValueError("max_staleness must be >= 0")
            if self.allreduce_per_step:
                raise ValueError("bounded_stale has no per-step allreduce "
                                 "(there is no global step barrier)")
            if self.adaptive is not None or self.h_spec is not None:
                raise ValueError(
                    "bounded_stale does not support adaptive compression "
                    "or H policies yet (the controllers assume a global "
                    "round clock)")
            if self.inner_engine != "scalar":
                raise ValueError("bounded_stale supports the scalar inner "
                                 "engine only")
        elif self.aggregation != "mean":
            raise ValueError("trimmed_mean aggregation is a bounded_stale "
                             "feature (barrier aggregation happens inside "
                             "the jitted round program)")
        if self.topology_seed_schedule is not None:
            if self.topology != "random":
                raise ValueError(
                    "topology_seed_schedule redraws the random k-regular "
                    f"graph per round; topology {self.topology!r} is fixed")
            if not self.topology_seed_schedule:
                raise ValueError("topology_seed_schedule must be non-empty")
            object.__setattr__(self, "topology_seed_schedule",
                               tuple(int(s)
                                     for s in self.topology_seed_schedule))

    @property
    def is_gossip(self) -> bool:
        from repro.topology import GOSSIP_KINDS
        return self.topology in GOSSIP_KINDS

    def meta(self) -> Dict[str, Any]:
        """JSON-serializable scenario header for the Timeline."""
        return {
            "n_clusters": self.n_clusters,
            "rounds": self.rounds,
            "h_steps": self.h_steps,
            "t_step_s": self.t_step_s,
            "tokens_per_step": self.tokens_per_step,
            "link": {"bytes_per_s": self.link.bytes_per_s,
                     "latency_s": self.link.latency_s,
                     "jitter": self.link.jitter},
            "faults": [e.describe() if hasattr(e, "describe") else repr(e)
                       for e in self.faults.events],
            "compressor": self.compressor,
            "rank": self.rank,
            "adaptive": (None if self.adaptive is None
                         else self.adaptive.to_dict()),
            "h_spec": (None if self.h_spec is None
                       else self.h_spec.to_dict()),
            "delay": self.delay,
            "sync": self.sync,
            "max_staleness": self.max_staleness,
            "aggregation": self.aggregation,
            "trim_k": self.trim_k,
            "inner_engine": self.inner_engine,
            "allreduce_per_step": self.allreduce_per_step,
            "topology": self.topology,
            "topology_degree": self.topology_degree,
            "topology_seed": self.topology_seed,
            "topology_seed_schedule": (
                None if self.topology_seed_schedule is None
                else list(self.topology_seed_schedule)),
            "seed": self.seed,
        }
