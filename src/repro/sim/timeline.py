"""Event-timeline output of the simulator.

One ``RoundEvent`` per outer round records who participated and where the
time went (compute vs total vs *exposed* comm — the §2.3 overlap means
exposed can be zero while the wire is busy).  ``Timeline`` aggregates to
effective throughput and provides a stable ``fingerprint()`` so tests can
assert determinism ("same seed => identical timeline") as an equality on
one string.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple


def tree_hash(tree: Any) -> str:
    """Canonical sha256 of a pytree of arrays: dicts by sorted key,
    lists/tuples (incl. NamedTuples) positionally; each leaf contributes its
    dtype, shape, and raw bytes.  Pure numpy/python so the proc worker can
    hash without importing jax; jax arrays go through ``np.asarray`` and
    hash to the same digest as their numpy copies — this is the bit-for-bit
    equality the proc-vs-in-process equivalence gate asserts on."""
    import numpy as np

    h = hashlib.sha256()

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}/{k}", node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            a = np.asarray(node)
            h.update(f"{prefix}:{a.dtype.str}:{a.shape}".encode())
            h.update(np.ascontiguousarray(a).tobytes())

    walk("", tree)
    return h.hexdigest()


def combine_row_hashes(pairs) -> str:
    """One digest over per-cluster ``(cluster_id, tree_hash)`` pairs — the
    gossip-mode equivalent of a single ``param_hash``: per-cluster outer
    params legitimately differ, so the round's currency is the multiset of
    row hashes.  The proc coordinator combines hashes reported by workers;
    the in-process simulator combines hashes of the stacked rows — equality
    of the combined digest is equality of every participating replica."""
    blob = "|".join(f"{int(c)}:{h}" for c, h in sorted(pairs))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


@dataclass(frozen=True)
class RoundEvent:
    round: int
    alive: Tuple[int, ...]             # participating cluster ids
    rejoined: Tuple[int, ...]          # ids whose buffers were reset
    h_steps: int
    rank: Optional[int]                # compressor rank r_t (None: n/a)
    t_compute_s: float                 # H * slowest alive cluster's step
    t_comm_s: float                    # full wire time of the outer sync
    exposed_comm_s: float              # comm not hidden behind compute
    t_round_s: float                   # t_compute + exposed
    wire_bytes: int
    slowest_cluster: int               # argmax local step time (-1: none)
    bottleneck_cluster: int            # argmin link bandwidth (-1: none)
    tokens: float                      # tokens trained this round
    faults: Tuple[str, ...] = ()
    loss: Optional[float] = None       # numeric mode only
    param_hash: Optional[str] = None   # tree_hash of global params after the
                                       # round (numeric mode; the proc/
                                       # in-process equivalence currency —
                                       # gossip mode: combine_row_hashes
                                       # over the alive replicas)
    wire_bytes_total: int = 0          # bytes crossing ALL links this round
                                       # (gossip: sum of neighbor sends;
                                       # gather: ring all-gather total)
    disagreement: Optional[float] = None   # gossip numeric mode: RMS
                                       # distance of per-cluster outer
                                       # params from their alive mean
    ranks: Optional[Tuple[int, ...]] = None   # per-cluster SEND ranks under
                                       # gossip adaptive compression (id
                                       # order over the alive set): a
                                       # degraded uplink's edges carry a
                                       # lower rank than healthy ones
    h_by: Optional[Tuple[int, ...]] = None    # per-cluster executed local
                                       # steps (alive-id order) when an
                                       # ``HSpec`` policy is active;
                                       # ``h_steps`` stays the round's
                                       # budget H (what "global" runs)
    t_compute_by: Optional[Tuple[float, ...]] = None  # per-cluster compute
                                       # seconds (alive-id order): modeled
                                       # h_c*t_step_c in-process, measured
                                       # wall clock on the proc backend
    idle_by: Optional[Tuple[float, ...]] = None       # per-cluster barrier
                                       # wait (t_compute_s - own compute) —
                                       # the straggler waste the balance
                                       # H-policy shrinks
    spans: Optional[Tuple[Tuple[str, int, float, float], ...]] = None
                                       # per-round phase spans for the
                                       # trace exporter (obs/trace.py):
                                       # (name, cluster, start_s, dur_s)
                                       # relative to round start — modeled
                                       # in-process, measured wall clock on
                                       # proc (cluster -1 = coordinator).
                                       # Telemetry only: deliberately NOT
                                       # in STRUCTURAL_FIELDS (proc spans
                                       # carry wall clock)
    cluster: Optional[int] = None      # bounded-stale async mode: the one
                                       # cluster that committed this outer
                                       # step (None = barrier round, where
                                       # every alive cluster commits)
    staleness: Optional[Tuple[Tuple[int, int], ...]] = None
                                       # async mode: (peer, rounds_stale)
                                       # for every delta incorporated in
                                       # this commit (self always 0); the
                                       # engine guarantees every entry is
                                       # <= max_staleness
    round_clock: Optional[Tuple[int, ...]] = None
                                       # async mode: per-cluster committed-
                                       # leg counters after this event —
                                       # the fleet's logical clock vector
                                       # (-1 = never committed)
    t_start_s: Optional[float] = None  # async mode: global modeled clock
                                       # at this leg's start.  Async events
                                       # overlap in global time, so the
                                       # timeline is laid out by t_start_s
                                       # instead of cumulative round sums
                                       # (telemetry; NOT structural)


@dataclass
class Timeline:
    scenario: Dict[str, Any]
    events: List[RoundEvent] = field(default_factory=list)

    # ---- aggregates -------------------------------------------------------
    @property
    def total_time_s(self) -> float:
        """Barrier mode: rounds are sequential, so total time is the sum.
        Bounded-stale async mode: commits overlap in global time (each
        event carries its ``t_start_s``), so total time is the makespan."""
        if any(e.t_start_s is not None for e in self.events):
            return max((e.t_start_s or 0.0) + e.t_round_s
                       for e in self.events)
        return sum(e.t_round_s for e in self.events)

    @property
    def total_tokens(self) -> float:
        return sum(e.tokens for e in self.events)

    @property
    def tokens_per_s(self) -> float:
        t = self.total_time_s
        return self.total_tokens / t if t > 0 else 0.0

    @property
    def total_wire_bytes(self) -> int:
        return sum(e.wire_bytes for e in self.events)

    @property
    def total_wire_bytes_on_links(self) -> int:
        """Sum of per-round all-link traffic (``wire_bytes_total``) — what
        the gossip-vs-gather benchmark compares."""
        return sum(e.wire_bytes_total for e in self.events)

    @property
    def exposed_comm_frac(self) -> float:
        t = self.total_time_s
        return (sum(e.exposed_comm_s for e in self.events) / t
                if t > 0 else 0.0)

    @property
    def total_hidden_comm_s(self) -> float:
        """Comm seconds overlapped behind compute (the §2.3 win):
        ``t_comm − exposed`` per round, clamped at 0 — proc measures the
        two independently, so noise can push exposed past t_comm."""
        return sum(max(0.0, e.t_comm_s - e.exposed_comm_s)
                   for e in self.events)

    @property
    def overlap_efficiency(self) -> float:
        """Fraction of all comm seconds hidden behind compute (1.0 when
        the wire was never busy — nothing needed hiding)."""
        comm = sum(e.t_comm_s for e in self.events)
        return self.total_hidden_comm_s / comm if comm > 0 else 1.0

    @property
    def total_barrier_idle_s(self) -> float:
        """Cluster-seconds burnt waiting at the end-of-round barrier,
        summed over rounds and clusters (``RoundEvent.idle_by``) — the
        straggler waste ``benchmarks/straggler_h.py`` compares across H
        policies."""
        return sum(sum(e.idle_by) for e in self.events
                   if e.idle_by is not None)

    @property
    def barrier_idle_frac(self) -> float:
        """Idle cluster-seconds as a fraction of all compute-side
        cluster-seconds (own compute + barrier wait)."""
        busy = sum(sum(e.t_compute_by) for e in self.events
                   if e.t_compute_by is not None)
        idle = self.total_barrier_idle_s
        return idle / (busy + idle) if busy + idle > 0 else 0.0

    def losses(self) -> List[float]:
        return [e.loss for e in self.events if e.loss is not None]

    # ---- serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "scenario": self.scenario,
            "summary": {
                "total_time_s": round(self.total_time_s, 6),
                "total_tokens": self.total_tokens,
                "tokens_per_s": round(self.tokens_per_s, 3),
                "total_wire_bytes": self.total_wire_bytes,
                "exposed_comm_frac": round(self.exposed_comm_frac, 6),
                "total_hidden_comm_s": round(self.total_hidden_comm_s, 6),
                "overlap_efficiency": round(self.overlap_efficiency, 6),
                "total_barrier_idle_s": round(self.total_barrier_idle_s, 6),
                "barrier_idle_frac": round(self.barrier_idle_frac, 6),
                "structural_fingerprint": self.structural_fingerprint(),
            },
            "events": [self._event_row(e) for e in self.events],
        }

    @classmethod
    def _event_row(cls, e: "RoundEvent") -> Dict[str, Any]:
        """One event as a dict, with never-set async fields omitted (see
        ``ASYNC_FIELDS``) — the single serialization used by both
        ``to_dict`` and ``fingerprint``."""
        return {k: v for k, v in asdict(e).items()
                if not (v is None and k in cls.ASYNC_FIELDS)}

    #: fields that only bounded-stale async events populate.  Omitted from
    #: serialization while None so that barrier timelines hash to the SAME
    #: fingerprints as before these fields existed (the bitwise guarantee
    #: the engine refactor preserves).
    ASYNC_FIELDS = ("cluster", "staleness", "round_clock", "t_start_s")

    def fingerprint(self) -> str:
        """Stable hash of the full event timeline (floats canonicalized to
        9 decimals).  Two runs are "identical" iff fingerprints match."""
        def canon(x):
            if isinstance(x, float):
                return round(x, 9)
            if isinstance(x, dict):
                return {k: canon(v) for k, v in sorted(x.items())}
            if isinstance(x, (list, tuple)):
                return [canon(v) for v in x]
            return x

        rows = [self._event_row(e) for e in self.events]
        blob = json.dumps(canon(rows), sort_keys=True).encode("utf-8")
        return hashlib.sha256(blob).hexdigest()

    STRUCTURAL_FIELDS = ("round", "alive", "rejoined", "h_steps", "h_by",
                         "rank", "ranks", "wire_bytes", "wire_bytes_total",
                         "faults", "param_hash",
                         # bounded-stale async rounds: the commit owner,
                         # the staleness of every incorporated delta, and
                         # the per-cluster round-clock vector are decision
                         # outputs of the event engine (no seconds), so
                         # they are part of the determinism currency the
                         # CI drift gate compares
                         "cluster", "staleness", "round_clock")

    def h_schedule(self) -> List[Any]:
        """Per-round executed local-step counts — the H-policy's decision
        trace, the analogue of ``rank_schedule()``.  Rounds scheduled by a
        per-cluster policy record the per-cluster list (``RoundEvent.h_by``,
        alive-id order); global rounds record the scalar budget."""
        return [list(e.h_by) if e.h_by is not None else e.h_steps
                for e in self.events]

    def rank_schedule(self) -> List[Any]:
        """Per-round executed compressor ranks — the adaptive controller's
        decision trace.  Feed it back to ``simulate(sc,
        rank_schedule=...)`` to replay an adaptive run's wire accounting in
        timing-only mode (no numeric problem, no controller).  Per-edge
        gossip rounds record the per-cluster send-rank list
        (``RoundEvent.ranks``, alive-id order) so the replay reproduces
        the per-edge payload sizes, not just the headline max."""
        return [list(e.ranks) if e.ranks is not None else e.rank
                for e in self.events]

    def structural_fingerprint(self) -> str:
        """Like ``fingerprint()`` but over the *stable* per-round fields only
        (participants, budgets, wire accounting, fault tags, param hashes) —
        no measured/modeled seconds.  A proc-backend run is wall-clock-noisy,
        yet two runs of the same scenario must produce the same structural
        fingerprint; CI fails on drift."""
        rows = []
        for e in self.events:
            row = [getattr(e, f) for f in self.STRUCTURAL_FIELDS]
            if e.cluster is None and e.staleness is None \
                    and e.round_clock is None:
                row = row[:-3]       # barrier event: pre-async row layout
            rows.append(row)
        blob = json.dumps(rows, sort_keys=True).encode("utf-8")
        return hashlib.sha256(blob).hexdigest()

    # ---- display ----------------------------------------------------------
    def table(self, max_rows: int = 0) -> str:
        hdr = (f"{'rnd':>4} {'alive':>10} {'H':>4} {'r_t':>5} "
               f"{'compute_s':>10} {'comm_s':>9} {'exposed_s':>10} "
               f"{'round_s':>9} {'wire_MB':>8} {'loss':>9}  faults")
        lines = [hdr, "-" * len(hdr)]
        events = self.events if not max_rows else self.events[:max_rows]
        for e in events:
            alive = (f"{len(e.alive)}/{self.scenario.get('n_clusters', '?')}")
            loss = "" if e.loss is None else f"{e.loss:9.4f}"
            lines.append(
                f"{e.round:>4} {alive:>10} {e.h_steps:>4} "
                f"{('-' if e.rank is None else e.rank):>5} "
                f"{e.t_compute_s:>10.3f} {e.t_comm_s:>9.3f} "
                f"{e.exposed_comm_s:>10.3f} {e.t_round_s:>9.3f} "
                f"{e.wire_bytes / 1e6:>8.2f} {loss:>9}  "
                f"{'; '.join(e.faults)}")
        if max_rows and len(self.events) > max_rows:
            lines.append(f"... ({len(self.events) - max_rows} more rounds)")
        lines.append(
            f"total {self.total_time_s:.2f}s  "
            f"{self.total_tokens:.0f} tokens  "
            f"{self.tokens_per_s:.1f} tok/s  "
            f"exposed-comm {100 * self.exposed_comm_frac:.1f}%  "
            f"overlap-eff {100 * self.overlap_efficiency:.1f}%")
        return "\n".join(lines)
