"""Coordinator for the multi-process backend: spawns one OS process per
virtual cluster, drives the outer rounds, and implements the gather-based
outer sync as ``core.membership.masked_cluster_mean`` over the *live*
connections.

Per round it:
 1. applies the ``FaultSchedule`` membership events — ``Leave`` kills the
    worker process (SIGKILL, abrupt), ``Join`` respawns a fresh process
    bootstrapped from a surviving replica's (params, outer momentum);
 2. derives each worker's modeled targets (straggler-inflated compute
    seconds, token-bucket rate from the degraded/jittered link, ring
    all-gather charge ``(n_alive−1)·wire_bytes``) from the *same*
    deterministic arithmetic the in-process simulator uses;
 3. gathers the compressed pseudo-gradient payloads (each throttled by the
    sender's token bucket), masks out dead/crashed members, broadcasts the
    mean, and collects round-done reports — asserting that every replica's
    post-round param hash agrees (distributed consistency check);
 4. records a measured ``RoundEvent``: wall-clock compute/comm/round
    seconds next to the deterministic structural fields (participants, wire
    accounting, hashes) that ``Timeline.structural_fingerprint()`` covers.

Unexpected worker death (socket EOF mid-round) is tolerated: the member is
masked out of the mean exactly like a scheduled ``Leave`` and the round
completes with the survivors — tagged ``crash(cN)`` on the timeline.

Topology note: the hub gathers and re-broadcasts, but each member's bucket
is charged the full ring-all-gather traffic ``(n_alive−1)·payload`` on its
own (possibly degraded) link, so measured comm time reproduces the modeled
ring collective over the bottleneck link; the hub's re-broadcast of the
mean is bookkeeping, not priced wire.
"""
from __future__ import annotations

import json
import os
import queue
import socket
import subprocess
import sys
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import comm
from repro.sim.scenario import Scenario
from repro.sim.timeline import RoundEvent, Timeline, tree_hash

# repro.core.compression (-> jax) is imported inside run_proc: the worker
# module executes this package's __init__ on spawn, and timing-only workers
# must not pay a jax import for it.


def _src_root() -> str:
    import repro
    pkg_dir = (os.path.dirname(repro.__file__) if repro.__file__
               else list(repro.__path__)[0])      # namespace package
    return os.path.dirname(os.path.abspath(pkg_dir))


class WorkerDied(Exception):
    pass


class _Handle:
    """One worker: process, connection, and a reader thread that turns the
    socket into a message queue (so the coordinator never blocks on one
    member while another is ready)."""

    def __init__(self, cluster: int, proc: subprocess.Popen):
        self.cluster = cluster
        self.proc = proc
        self.conn: Optional[socket.socket] = None
        self.q: "queue.Queue[Any]" = queue.Queue()
        self.dead = False

    def attach(self, conn: socket.socket) -> None:
        self.conn = conn
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        t = threading.Thread(target=self._reader, daemon=True)
        t.start()

    def _reader(self) -> None:
        from repro.sim.proc.transport import recv_frame
        try:
            while True:
                self.q.put(recv_frame(self.conn))
        except (ConnectionError, OSError, ValueError, EOFError):
            self.q.put({"type": "_eof"})

    def send(self, obj: Any) -> bool:
        from repro.sim.proc.transport import send_frame
        if self.dead or self.conn is None:
            return False
        try:
            send_frame(self.conn, obj)
            return True
        except OSError:
            self.dead = True
            return False

    def get(self, want: str, timeout: float) -> Optional[Dict[str, Any]]:
        """Next message of type ``want``; None if the worker died/timed out
        first (marks the handle dead)."""
        if self.dead:
            return None
        deadline = time.monotonic() + timeout
        while True:
            try:
                msg = self.q.get(timeout=max(0.0, deadline - time.monotonic()))
            except queue.Empty:
                self.dead = True
                return None
            if msg.get("type") == "_eof":
                self.dead = True
                return None
            if msg.get("type") == want:
                return msg
            # unexpected type: drop (stale frame from a killed round)

    def kill(self) -> None:
        self.dead = True
        try:
            self.proc.kill()
        except OSError:
            pass
        if self.conn is not None:
            try:
                self.conn.close()
            except OSError:
                pass


def _spawn(cluster: int, port: int, sc: Scenario, problem,
           crash_at: Optional[Dict[int, int]]) -> subprocess.Popen:
    cfg = {
        "host": "127.0.0.1",
        "port": port,
        "cluster": cluster,
        "problem": problem.to_dict() if problem is not None else None,
        "compressor": {"name": sc.compressor, "kw": dict(sc.compressor_kw)},
        "rank": sc.rank,
        "crash_at_round": (crash_at or {}).get(cluster),
    }
    env = os.environ.copy()
    src = _src_root()
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    return subprocess.Popen(
        [sys.executable, "-m", "repro.sim.proc.worker", json.dumps(cfg)],
        env=env)


def _stack_rows(rows: List[Any]):
    import jax
    import jax.numpy as jnp
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                        *rows)


def run_proc(sc: Scenario, problem=None, *,
             crash_at: Optional[Dict[int, int]] = None,
             spawn_timeout_s: float = 300.0,
             round_timeout_s: float = 300.0) -> Timeline:
    """Run the scenario on real processes + sockets; returns a Timeline
    whose seconds are *measured* wall clock and whose structural fields
    (participants, wire accounting, per-round param hashes) are
    deterministic and bit-comparable with ``simulate()``.

    ``problem`` is a ``sim.quadratic.QuadraticSpec`` (or None for
    timing-only workers, which skip jax entirely).  ``crash_at`` maps
    cluster -> round for injected hard crashes (``os._exit`` before the
    delta send — the membership-recovery test hook).
    """
    from repro.core.compression import make_compressor
    from repro.sim.simulator import _jitter_factors

    if not sc.delay:
        raise NotImplementedError(
            "backend='proc' realizes the §2.3 one-step-delay overlapped "
            "round (delay=True); the synchronous round is in-process only")
    if sc.allreduce_per_step:
        raise NotImplementedError(
            "backend='proc' implements the gather-based outer sync, not "
            "per-step allreduce baselines")
    numeric = problem is not None
    if numeric and problem.n_clusters != sc.n_clusters:
        raise ValueError("problem.n_clusters != scenario.n_clusters")

    C = sc.n_clusters
    compressor = make_compressor(sc.compressor, **sc.compressor_kw)
    wire = int(compressor.wire_bytes(sc.shapes(), rank=sc.rank))
    alive = (np.ones(C, bool) if sc.initial_alive is None
             else np.asarray(sc.initial_alive, bool).copy())

    if numeric:
        import jax
        import jax.numpy as jnp

        from repro.core.membership import masked_cluster_mean
        mean_j = jax.jit(masked_cluster_mean)
        zeros_row = jax.tree.map(
            lambda x: np.zeros(np.shape(x), np.float32),
            problem.init_params())
        # compile the gather-mean before round 0 so it isn't measured
        jax.block_until_ready(mean_j(_stack_rows([zeros_row] * C),
                                     jnp.ones((C,), jnp.float32)))

    server = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    server.bind(("127.0.0.1", 0))
    server.listen(C + 2)
    port = server.getsockname()[1]

    handles: Dict[int, _Handle] = {}

    def accept_one(expect: int, timeout: float) -> None:
        """Accept until the worker for cluster ``expect`` says hello."""
        from repro.sim.proc.transport import recv_frame
        deadline = time.monotonic() + timeout
        while handles[expect].conn is None:
            server.settimeout(max(0.1, deadline - time.monotonic()))
            conn, _ = server.accept()
            hello = recv_frame(conn, timeout=30.0)
            handles[int(hello["cluster"])].attach(conn)

    def bootstrap(c: int, state: Optional[Dict[str, Any]]) -> None:
        handles[c].send({"type": "bootstrap",
                         "params": None if state is None
                         else state["params"],
                         "outer_opt": None if state is None
                         else state["outer_opt"]})

    def dump_state() -> Dict[str, Any]:
        """Fetch the replicated outer state from the lowest live worker."""
        for c in sorted(handles):
            h = handles[c]
            if alive[c] and not h.dead:
                if h.send({"type": "dump"}):
                    st = h.get("state", round_timeout_s)
                    if st is not None:
                        return st
        raise WorkerDied("no live worker to bootstrap a rejoin from")

    events: List[RoundEvent] = []
    final_params = None
    try:
        for c in np.flatnonzero(alive):
            handles[int(c)] = _Handle(int(c), _spawn(int(c), port, sc,
                                                     problem, crash_at))
        for c in sorted(handles):
            if handles[c].conn is None:
                accept_one(c, spawn_timeout_s)
        for c in sorted(handles):
            bootstrap(c, None)

        for r in range(sc.rounds):
            alive, rejoined = sc.faults.membership(r, alive)
            crash_tags: List[str] = []

            # --- membership enforcement: kill leavers, respawn joiners ----
            for c in range(C):
                if not alive[c] and c in handles and not handles[c].dead:
                    handles[c].kill()
            for c in np.flatnonzero(rejoined):
                c = int(c)
                state = dump_state() if numeric else None
                handles[c] = _Handle(c, _spawn(c, port, sc, problem,
                                               crash_at))
                accept_one(c, spawn_timeout_s)
                bootstrap(c, state)

            alive_ids = [int(i) for i in np.flatnonzero(alive)]
            n_alive = len(alive_ids)
            if n_alive == 0:
                if numeric:
                    raise WorkerDied(
                        "all clusters dead in numeric mode: the proc "
                        "backend has no replica left to carry the outer "
                        "state (the in-process simulator keeps applying "
                        "momentum-only rounds; run that instead)")
                events.append(RoundEvent(
                    round=r, alive=(), rejoined=(), h_steps=sc.h_steps,
                    rank=sc.rank, t_compute_s=0.0, t_comm_s=0.0,
                    exposed_comm_s=0.0, t_round_s=0.0, wire_bytes=wire,
                    slowest_cluster=-1, bottleneck_cluster=-1, tokens=0.0,
                    faults=sc.faults.active(r)))
                continue

            # --- modeled targets: same arithmetic as simulate() -----------
            h_t = sc.h_steps
            step_j = _jitter_factors(sc.seed, r, C, sc.link.jitter, salt=1)
            t_steps = np.array([sc.t_step_s * sc.faults.step_multiplier(c, r)
                                * step_j[c] for c in range(C)])
            slowest = int(max(alive_ids, key=lambda c: t_steps[c]))
            bw_j = _jitter_factors(sc.seed, r, C, sc.link.jitter, salt=2)
            bws = np.array([sc.link.bytes_per_s
                            * sc.faults.bandwidth_factor(c, r) * bw_j[c]
                            for c in range(C)])
            if n_alive >= 2:
                bottleneck = int(min(alive_ids, key=lambda c: bws[c]))
                charge = (n_alive - 1) * wire
                latency = (n_alive - 1) * sc.link.latency_s
            else:
                bottleneck, charge, latency = -1, 0, 0.0

            # --- drive the round ------------------------------------------
            t0 = time.monotonic()
            for c in alive_ids:
                ok = handles[c].send({
                    "type": "round", "round": r,
                    "compute_target_s": float(h_t * t_steps[c]),
                    "charge_bytes": float(charge),
                    "rate_bytes_per_s": (float(bws[c]) if charge else None),
                    "latency_s": float(latency),
                })
                if not ok:
                    alive[c] = False
                    crash_tags.append(f"crash(c{c})")

            hats: Dict[int, Any] = {}
            for c in list(alive_ids):
                if not alive[c]:
                    continue
                msg = handles[c].get("delta", round_timeout_s)
                if msg is None:
                    alive[c] = False
                    crash_tags.append(f"crash(c{c})")
                    handles[c].kill()
                else:
                    hats[c] = msg["hat"]
            t_comm_meas = time.monotonic() - t0

            contributors = [int(i) for i in np.flatnonzero(alive)]
            delta_np = None
            if numeric:
                if not contributors:
                    raise WorkerDied("every worker crashed mid-round")
                stacked = _stack_rows([hats.get(c, zeros_row)
                                       for c in range(C)])
                Delta = mean_j(stacked, jnp.asarray(alive, jnp.float32))
                delta_np = jax.tree.map(lambda x: np.asarray(x), Delta)
            for c in contributors:
                if not handles[c].send({"type": "avg", "delta": delta_np}):
                    alive[c] = False
                    crash_tags.append(f"crash(c{c})")

            t_compute_meas = 0.0
            losses, hashes = [], []
            for c in list(contributors):
                if not alive[c]:
                    continue
                msg = handles[c].get("done", round_timeout_s)
                if msg is None:
                    alive[c] = False
                    crash_tags.append(f"crash(c{c})")
                    handles[c].kill()
                    continue
                t_compute_meas = max(t_compute_meas,
                                     float(msg["t_compute"]))
                if msg.get("loss") is not None:
                    losses.append(float(msg["loss"]))
                if msg.get("param_hash") is not None:
                    hashes.append(msg["param_hash"])
            t_round_meas = time.monotonic() - t0

            if numeric and len(set(hashes)) > 1:
                raise WorkerDied(
                    f"replica divergence at round {r}: param hashes "
                    f"{sorted(set(hashes))}")

            tokens = sc.tokens_per_step * h_t * len(contributors) / max(C, 1)
            events.append(RoundEvent(
                round=r, alive=tuple(contributors),
                rejoined=tuple(int(i) for i in np.flatnonzero(rejoined)),
                h_steps=h_t, rank=sc.rank,
                t_compute_s=t_compute_meas, t_comm_s=t_comm_meas,
                exposed_comm_s=max(0.0, t_round_meas - t_compute_meas),
                t_round_s=t_round_meas, wire_bytes=wire,
                slowest_cluster=slowest, bottleneck_cluster=bottleneck,
                tokens=tokens,
                faults=sc.faults.active(r) + tuple(crash_tags),
                loss=(float(np.mean(losses)) if losses else None),
                param_hash=(hashes[0] if hashes else None)))

        if numeric and alive.any():
            final_params = dump_state()["params"]
    finally:
        for h in handles.values():
            h.send({"type": "stop"})
        time.sleep(0.05)
        for h in handles.values():
            h.kill()
        server.close()
        for h in handles.values():
            try:
                h.proc.wait(timeout=10.0)
            except Exception:
                pass

    tl = Timeline(scenario={**sc.meta(), "backend": "proc"}, events=events)
    if final_params is not None:
        tl.final_params = final_params
    return tl
