"""Coordinator for the multi-process backend: spawns one OS process per
virtual cluster, drives the outer rounds, and realizes the outer sync for
the scenario's topology.

Gather kinds (star/full): implements the hub outer sync as
``core.membership.masked_cluster_mean`` over the *live* connections — the
coordinator gathers each worker's compressed pseudo-gradient, masks out
dead/crashed members, and broadcasts the mean.  Both the §2.3 delayed round
and the synchronous (``delay=False``) round are supported: the protocol is
identical (round → delta → avg → done); a sync worker simply trains before
shipping.

Gossip kinds (ring/torus/random): the coordinator does NOT touch payloads.
Workers exchange compressed deltas directly over ``PeerMesh`` p2p links
along the topology's edges and mix them through their row of the masked
Metropolis-Hastings matrix; the coordinator only orchestrates membership
and faults — it hands out each round's peer addresses (+ spawn epochs, so
respawned neighbors are re-dialed), mixing-matrix rows, and modeled
rate/latency/compute targets, then collects per-replica ``done`` reports.
Per-cluster outer params legitimately diverge under gossip, so the round's
``param_hash`` is ``combine_row_hashes`` over the alive replicas' row
hashes, and a rejoiner bootstraps from the masked *mean* of the survivors'
(params, outer momentum) — the same arithmetic the in-process simulator
uses, keeping the two backends bit-for-bit comparable.

Per round it:
 1. applies the ``FaultSchedule`` membership events — ``Leave`` kills the
    worker process (SIGKILL, abrupt), ``Join`` respawns a fresh process;
 2. derives each worker's modeled targets (straggler-inflated compute
    seconds, token-bucket rate from the degraded/jittered link, and the
    topology's wire charge: ring all-gather ``(n_alive−1)·wire`` for
    gather, ``deg·wire`` on the own uplink for gossip) from the *same*
    deterministic arithmetic the in-process simulator uses
    (``repro.topology.accounting``);
 3. records a measured ``RoundEvent`` next to the deterministic structural
    fields that ``Timeline.structural_fingerprint()`` covers.

Unexpected worker death (socket EOF mid-round) is tolerated: the member is
masked out exactly like a scheduled ``Leave`` and the round completes with
the survivors — tagged ``crash(cN)`` on the timeline (gossip neighbors mix
zeros for the silent peer that round, tagged ``p2pmiss``).
"""
from __future__ import annotations

import json
import os
import queue
import socket
import subprocess
import sys
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import comm
from repro.sim.engine import BoundedStaleEngine, run_barrier
from repro.sim.scenario import Scenario
from repro.sim.timeline import (RoundEvent, Timeline, combine_row_hashes,
                                tree_hash)

# repro.core.compression (-> jax) is imported inside run_proc: the worker
# module executes this package's __init__ on spawn, and timing-only workers
# must not pay a jax import for it.


def _src_root() -> str:
    import repro
    pkg_dir = (os.path.dirname(repro.__file__) if repro.__file__
               else list(repro.__path__)[0])      # namespace package
    return os.path.dirname(os.path.abspath(pkg_dir))


class WorkerDied(Exception):
    pass


# deterministic ordering for the merged per-round span list (wall-clock
# starts are noisy, so sorting by start alone would make the trace's event
# order nondeterministic across runs of the same scenario)
_SPAN_ORDER = {"gather": 0, "inner": 1, "idle": 2, "compress": 3,
               "wire": 4, "mix": 5, "outer": 6}


class _Handle:
    """One worker: process, connection, and a reader thread that turns the
    socket into a message queue (so the coordinator never blocks on one
    member while another is ready)."""

    def __init__(self, cluster: int, proc: subprocess.Popen):
        self.cluster = cluster
        self.proc = proc
        self.conn: Optional[socket.socket] = None
        self.p2p_port: Optional[int] = None
        self.q: "queue.Queue[Any]" = queue.Queue()
        self.dead = False

    def attach(self, conn: socket.socket) -> None:
        self.conn = conn
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        t = threading.Thread(target=self._reader, daemon=True)
        t.start()

    def _reader(self) -> None:
        from repro.sim.proc.transport import recv_frame
        try:
            while True:
                self.q.put(recv_frame(self.conn))
        except (ConnectionError, OSError, ValueError, EOFError):
            self.q.put({"type": "_eof"})

    def send(self, obj: Any) -> bool:
        from repro.sim.proc.transport import send_frame
        if self.dead or self.conn is None:
            return False
        try:
            send_frame(self.conn, obj)
            return True
        except OSError:
            self.dead = True
            return False

    def get(self, want: str, timeout: float) -> Optional[Dict[str, Any]]:
        """Next message of type ``want``; None if the worker died/timed out
        first (marks the handle dead)."""
        if self.dead:
            return None
        deadline = time.monotonic() + timeout
        while True:
            try:
                msg = self.q.get(timeout=max(0.0, deadline - time.monotonic()))
            except queue.Empty:
                self.dead = True
                return None
            if msg.get("type") == "_eof":
                self.dead = True
                return None
            if msg.get("type") == want:
                return msg
            # unexpected type: drop (stale frame from a killed round)

    def kill(self) -> None:
        self.dead = True
        try:
            self.proc.kill()
        except OSError:
            pass
        if self.conn is not None:
            try:
                self.conn.close()
            except OSError:
                pass


def _spawn(cluster: int, port: int, sc: Scenario, problem, gossip: bool,
           epoch: int, crash_at: Optional[Dict[int, int]]) -> subprocess.Popen:
    cfg = {
        "host": "127.0.0.1",
        "port": port,
        "cluster": cluster,
        "n_clusters": sc.n_clusters,
        "problem": problem.to_dict() if problem is not None else None,
        "compressor": {"name": sc.compressor, "kw": dict(sc.compressor_kw)},
        "rank": sc.rank,
        # adaptive compression: the coordinator broadcasts the controller's
        # per-round decision in the round header; workers compress with it
        # (and, in spectral modes, report their pending delta back as the
        # controller's rank signal)
        "adaptive_rank": (sc.adaptive is not None
                          and sc.adaptive.mode != "off"),
        "report_pending": (sc.adaptive is not None
                           and sc.adaptive.needs_spectral),
        "warm_rank": (None if sc.adaptive is None
                      else sc.adaptive.r1),
        # heterogeneous local-step scheduling: the coordinator broadcasts
        # each worker's per-round H in the round header; numeric workers
        # compile the masked fixed-length inner scan once (H traced)
        "dynamic_h": (sc.h_spec is not None and sc.h_spec.active),
        # bounded-stale async workers run the synchronous gather arm with
        # classic compressor-local EF: publish-at-finish overlap is modeled
        # by the engine, not by the worker's §2.3 comm thread
        "delay": sc.delay and sc.sync != "bounded_stale",
        "gossip": gossip,
        "classic_ef": sc.sync == "bounded_stale",
        "epoch": epoch,
        "crash_at_round": (crash_at or {}).get(cluster),
    }
    env = os.environ.copy()
    src = _src_root()
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    return subprocess.Popen(
        [sys.executable, "-m", "repro.sim.proc.worker", json.dumps(cfg)],
        env=env)


def _stack_rows(rows: List[Any]):
    import jax
    import jax.numpy as jnp
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                        *rows)


def run_proc(sc: Scenario, problem=None, *,
             crash_at: Optional[Dict[int, int]] = None,
             spawn_timeout_s: float = 300.0,
             round_timeout_s: float = 300.0,
             p2p_timeout_s: float = 30.0) -> Timeline:
    """Run the scenario on real processes + sockets; returns a Timeline
    whose seconds are *measured* wall clock and whose structural fields
    (participants, wire accounting, per-round param hashes) are
    deterministic and bit-comparable with ``simulate()``.

    ``problem`` is a ``sim.quadratic.QuadraticSpec`` (or None for
    timing-only workers, which skip jax entirely).  ``crash_at`` maps
    cluster -> round for injected hard crashes (``os._exit`` before the
    delta send — the membership-recovery test hook).
    """
    from repro.core import adaptive as _ada
    from repro.core.compression import make_compressor
    from repro.sim.simulator import _jitter_factors
    from repro.topology import (MixingMatrix, compute_leg, gossip_round_comm,
                                round_wire_total)

    if sc.allreduce_per_step:
        raise NotImplementedError(
            "backend='proc' implements the outer-round syncs (gather and "
            "gossip), not per-step allreduce baselines")
    if sc.sync == "bounded_stale":
        return _run_proc_bounded_stale(
            sc, problem, crash_at=crash_at,
            spawn_timeout_s=spawn_timeout_s,
            round_timeout_s=round_timeout_s)
    from repro.sim.faults import Byzantine
    if any(isinstance(e, Byzantine) for e in sc.faults.events):
        # mirror simulate()'s validation: a barrier round has no publish
        # step to corrupt, so silently ignoring the attack here would let
        # the two backends diverge on what the scenario even means
        raise ValueError(
            "Byzantine faults model corrupt *published* deltas, which only "
            "exist under sync='bounded_stale' (the barrier round mixes "
            "inside one jitted program with no publish step to corrupt)")
    topo = sc.topo()
    gossip = topo.is_gossip

    # dynamic time-varying topology: a fresh random graph (and mixing
    # matrix) per round, cached by seed — same key scheme as simulate()'s
    # topo_at/mm_at, so round r communicates over the identical graph on
    # both backends.  PeerMesh.set_peers reconciles each round's peer
    # dict (stale links closed, new ones dialed), so the workers re-dial
    # to the new neighbor sets transparently.
    _topo_cache: Dict[int, Any] = {}

    def topo_at(rnd: int):
        if sc.topology_seed_schedule is None:
            return topo
        key = rnd % len(sc.topology_seed_schedule)
        if key not in _topo_cache:
            _topo_cache[key] = sc.topo(rnd)
        return _topo_cache[key]

    _mm_cache: Dict[int, Any] = {}

    def mm_at(rnd: int, topo_r):
        if not gossip:
            return None
        if sc.topology_seed_schedule is None:
            key = -1
        else:
            key = rnd % len(sc.topology_seed_schedule)
        if key not in _mm_cache:
            _mm_cache[key] = MixingMatrix.metropolis(topo_r)
        return _mm_cache[key]
    h_active = sc.h_spec is not None and sc.h_spec.active
    numeric = problem is not None
    if numeric and problem.n_clusters != sc.n_clusters:
        raise ValueError("problem.n_clusters != scenario.n_clusters")
    if numeric:
        # mirror the in-process simulator's inner-engine validation: the
        # declared Scenario.inner_engine must match the problem's engine,
        # and the pp engine is gather-only (a gossip worker would need a
        # stacked pp program — a different compiled computation)
        engine = getattr(problem, "engine", "scalar")
        if engine != sc.inner_engine:
            raise ValueError(
                f"Scenario.inner_engine={sc.inner_engine!r} but the "
                f"problem was built for engine {engine!r}")
        if engine == "pp" and gossip:
            raise NotImplementedError(
                "backend='proc' runs inner_engine='pp' over gather "
                "topologies only (see simulate()'s matching check)")

    C = sc.n_clusters
    compressor = make_compressor(sc.compressor, **sc.compressor_kw)
    shapes = sc.shapes()
    ctrl = (sc.adaptive.controller(compressor)
            if sc.adaptive is not None else None)
    if ctrl is not None and ctrl.needs_spectral:
        # mirror the in-process simulator's validation exactly
        if not numeric:
            raise ValueError(
                f"adaptive mode {sc.adaptive.mode!r} needs a numeric "
                "problem (the spectral rank signal comes from realized "
                "deltas); timing-only runs can use mode='bandwidth'")
        if not sc.delay:
            raise ValueError(
                f"adaptive mode {sc.adaptive.mode!r} reads the pending "
                "pseudo-gradient, which only delay=True rounds carry; "
                "use mode='bandwidth' for synchronous rounds")
    wire = int(compressor.wire_bytes(shapes, rank=sc.rank))
    alive = (np.ones(C, bool) if sc.initial_alive is None
             else np.asarray(sc.initial_alive, bool).copy())
    epochs = {c: 0 for c in range(C)}

    if numeric:
        import jax
        import jax.numpy as jnp

        from repro.core.membership import masked_cluster_mean
        mean_j = jax.jit(masked_cluster_mean)
        zeros_row = jax.tree.map(
            lambda x: np.zeros(np.shape(x), np.float32),
            problem.init_params())
        # compile the gather-mean before round 0 so it isn't measured
        jax.block_until_ready(mean_j(_stack_rows([zeros_row] * C),
                                     jnp.ones((C,), jnp.float32)))

    server = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    server.bind(("127.0.0.1", 0))
    server.listen(C + 2)
    port = server.getsockname()[1]

    handles: Dict[int, _Handle] = {}

    def accept_one(expect: int, timeout: float) -> None:
        """Accept until the worker for cluster ``expect`` says hello."""
        from repro.sim.proc.transport import recv_frame
        deadline = time.monotonic() + timeout
        while handles[expect].conn is None:
            server.settimeout(max(0.1, deadline - time.monotonic()))
            conn, _ = server.accept()
            hello = recv_frame(conn, timeout=30.0)
            h = handles[int(hello["cluster"])]
            h.p2p_port = hello.get("p2p_port")
            h.attach(conn)

    def spawn(c: int) -> None:
        epochs[c] += 1
        handles[c] = _Handle(c, _spawn(c, port, sc, problem, gossip,
                                       epochs[c], crash_at))

    def bootstrap(c: int, state: Optional[Dict[str, Any]]) -> None:
        handles[c].send({"type": "bootstrap",
                         "params": None if state is None
                         else state["params"],
                         "outer_opt": None if state is None
                         else state["outer_opt"]})

    def dump_one(c: int) -> Optional[Dict[str, Any]]:
        h = handles.get(c)
        if h is None or h.dead or not h.send({"type": "dump"}):
            return None
        return h.get("state", round_timeout_s)

    def dump_state() -> Dict[str, Any]:
        """Gather mode: every worker replicates the outer state — fetch it
        from the lowest live one."""
        for c in sorted(handles):
            if alive[c] and not handles[c].dead:
                st = dump_one(c)
                if st is not None:
                    return st
        raise WorkerDied("no live worker to bootstrap a rejoin from")

    def consensus_state(alive_prev: np.ndarray) -> Dict[str, Any]:
        """Gossip mode: per-replica params differ, so a rejoiner restarts
        from the masked MEAN of the survivors' (params, outer momentum) —
        zeros-padded rows through the same jitted ``masked_cluster_mean``
        the in-process simulator's consensus bootstrap uses."""
        rows_p, rows_m, step = [], [], None
        states = {c: dump_one(c) for c in np.flatnonzero(alive_prev)}
        for c in range(C):
            st = states.get(c)
            if st is not None and st.get("params") is not None:
                rows_p.append(st["params"])
                rows_m.append(st["outer_opt"]["momentum"])
                step = st["outer_opt"]["step"]
            else:
                rows_p.append(zeros_row)
                rows_m.append(zeros_row)
        if step is None:
            raise WorkerDied("no live worker to bootstrap a rejoin from")
        m = jnp.asarray(
            [1.0 if states.get(c) is not None else 0.0 for c in range(C)],
            jnp.float32)
        params = jax.tree.map(np.asarray, mean_j(_stack_rows(rows_p), m))
        mom = jax.tree.map(np.asarray, mean_j(_stack_rows(rows_m), m))
        return {"params": params,
                "outer_opt": {"step": step, "momentum": mom}}

    events: List[RoundEvent] = []
    final_params = None
    try:
        for c in np.flatnonzero(alive):
            spawn(int(c))
        for c in sorted(handles):
            if handles[c].conn is None:
                accept_one(c, spawn_timeout_s)
        for c in sorted(handles):
            bootstrap(c, None)

        def _barrier_round(r: int) -> None:
            # The pre-engine per-round body, verbatim — run_barrier drives
            # it in the same index order, so the proc barrier path (and
            # with it every proc≡in-process equivalence gate) stays
            # bit-for-bit identical through the engine refactor.
            nonlocal alive
            prev_alive = alive.copy()
            alive, rejoined = sc.faults.membership(r, alive)
            crash_tags: List[str] = []
            topo_r = topo_at(r)
            mm_r = mm_at(r, topo_r)

            # --- membership enforcement: kill leavers, respawn joiners ----
            for c in range(C):
                if not alive[c] and c in handles and not handles[c].dead:
                    handles[c].kill()
            if rejoined.any():
                # one bootstrap state serves every rejoiner this round
                # (the survivors' consensus doesn't depend on which
                # rejoiner asks) — matches the in-process simulator's
                # single consensus_bootstrap call
                if numeric:
                    state = (consensus_state(prev_alive & alive) if gossip
                             else dump_state())
                else:
                    state = None
                for c in np.flatnonzero(rejoined):
                    c = int(c)
                    spawn(c)
                    accept_one(c, spawn_timeout_s)
                    bootstrap(c, state)

            alive_ids = [int(i) for i in np.flatnonzero(alive)]
            n_alive = len(alive_ids)
            if n_alive == 0:
                if numeric:
                    raise WorkerDied(
                        "all clusters dead in numeric mode: the proc "
                        "backend has no replica left to carry the outer "
                        "state (the in-process simulator keeps applying "
                        "momentum-only rounds; run that instead)")
                rank0 = (ctrl.executed()[0] if ctrl is not None else sc.rank)
                events.append(RoundEvent(
                    round=r, alive=(), rejoined=(), h_steps=sc.h_steps,
                    rank=rank0, t_compute_s=0.0, t_comm_s=0.0,
                    exposed_comm_s=0.0, t_round_s=0.0,
                    wire_bytes=int(compressor.wire_bytes(shapes, rank=rank0)),
                    slowest_cluster=-1, bottleneck_cluster=-1, tokens=0.0,
                    faults=sc.faults.active(r), wire_bytes_total=0))
                return

            # --- modeled targets: same arithmetic as simulate() -----------
            h_t = sc.h_steps
            step_j = _jitter_factors(sc.seed, r, C, sc.link.jitter, salt=1)
            t_steps = np.array([sc.t_step_s * sc.faults.step_multiplier(c, r)
                                * step_j[c] for c in range(C)])
            # per-cluster local-step schedule: same plan_h host arithmetic
            # (and, under gossip, the same spectral-gap clamp on the same
            # masked matrix) as the in-process simulator — the broadcast H
            # schedule cannot drift from the modeled one
            gap = (mm_r.masked(alive).spectral_gap(alive)
                   if (gossip and h_active) else None)
            h_map = _ada.plan_h(sc.h_spec, h_t, t_steps, alive,
                                spectral_gap=gap)
            leg = compute_leg(h_map, t_steps, alive)
            slowest = leg.slowest_cluster
            bw_j = _jitter_factors(sc.seed, r, C, sc.link.jitter, salt=2)
            bws = np.array([sc.link.bytes_per_s
                            * sc.faults.bandwidth_factor(c, r) * bw_j[c]
                            for c in range(C)])

            # --- adaptive rank decision: identical inputs (modeled bws /
            # barrier compute) and identical host arithmetic as the
            # in-process simulator, so the broadcast schedule matches it
            rank_t = sc.rank
            ranks_map = None
            wire_r = wire
            if ctrl is not None:
                rank_t, ranks_map = ctrl.decide(
                    compressor, shapes, topo_r, alive, bws,
                    sc.link.latency_s, leg.t_barrier_s, gossip)
                wire_r = int(compressor.wire_bytes(shapes, rank=rank_t))
            ranks_tuple = (tuple(ranks_map[c] for c in alive_ids)
                           if ranks_map is not None else None)

            if gossip:
                wire_by = (compressor.wire_bytes_per_edge(shapes, ranks_map)
                           if ranks_map is not None else None)
                gc = gossip_round_comm(topo_r, alive, wire_r, bws,
                                       sc.link.latency_s,
                                       wire_by_cluster=wire_by)
                bottleneck = gc.bottleneck_cluster
                wire_total = gc.wire_bytes_total
                W_r = (mm_r.masked(alive).W if numeric else None)
            elif n_alive >= 2:
                bottleneck = int(min(alive_ids, key=lambda c: bws[c]))
                wire_total = round_wire_total("gather", n_alive, wire_r)
            else:
                bottleneck, wire_total = -1, 0

            # --- drive the round ------------------------------------------
            t0 = time.monotonic()
            for c in alive_ids:
                rmsg: Dict[str, Any] = {
                    "type": "round", "round": r,
                    "compute_target_s": float(leg.t_by[c]),
                    "latency_s": float(sc.link.latency_s),
                }
                if h_active and any(h_map[j] != h_t for j in alive_ids):
                    # heterogeneous round: broadcast this worker's
                    # local-step count (the numeric worker masks its
                    # fixed-length scan with it).  Uniform-at-budget
                    # rounds deliberately OMIT the key so every worker
                    # runs the plain scalar-H program — the same dispatch
                    # the in-process simulator makes on the same h_map
                    rmsg["h_steps"] = int(h_map[c])
                if ctrl is not None:
                    # broadcast the controller decision: this worker's send
                    # rank for the round (gossip: its own per-edge rank)
                    rmsg["rank"] = int(ranks_map[c] if ranks_map is not None
                                       else rank_t)
                if gossip:
                    nbrs = topo_r.alive_neighbors(c, alive)
                    wire_c = (wire_by[c] if ranks_map is not None else wire_r)
                    rmsg.update({
                        "charge_bytes": float(wire_c) if nbrs else None,
                        "rate_bytes_per_s": (float(bws[c]) if nbrs
                                             else None),
                        "peers": {int(j): ("127.0.0.1",
                                           handles[j].p2p_port,
                                           epochs[j]) for j in nbrs},
                        "w_row": (np.asarray(W_r[c], np.float32)
                                  if W_r is not None else None),
                        "p2p_timeout_s": float(p2p_timeout_s),
                    })
                else:
                    charge = (n_alive - 1) * wire_r if n_alive >= 2 else 0
                    rmsg.update({
                        "charge_bytes": float(charge),
                        "rate_bytes_per_s": (float(bws[c]) if charge
                                             else None),
                        "latency_s": float((n_alive - 1)
                                           * sc.link.latency_s),
                    })
                if not handles[c].send(rmsg):
                    alive[c] = False
                    crash_tags.append(f"crash(c{c})")

            if not gossip:
                # central gather -> masked mean -> broadcast
                hats: Dict[int, Any] = {}
                for c in list(alive_ids):
                    if not alive[c]:
                        continue
                    msg = handles[c].get("delta", round_timeout_s)
                    if msg is None:
                        alive[c] = False
                        crash_tags.append(f"crash(c{c})")
                        handles[c].kill()
                    else:
                        hats[c] = msg["hat"]
                t_gather_meas = time.monotonic() - t0

                contributors = [int(i) for i in np.flatnonzero(alive)]
                delta_np = None
                if numeric:
                    if not contributors:
                        raise WorkerDied("every worker crashed mid-round")
                    stacked = _stack_rows([hats.get(c, zeros_row)
                                           for c in range(C)])
                    Delta = mean_j(stacked, jnp.asarray(alive, jnp.float32))
                    delta_np = jax.tree.map(lambda x: np.asarray(x), Delta)
                for c in contributors:
                    if not handles[c].send({"type": "avg",
                                            "delta": delta_np}):
                        alive[c] = False
                        crash_tags.append(f"crash(c{c})")
            else:
                contributors = list(alive_ids)

            # --- collect round-done reports -------------------------------
            t_compute_meas, t_comm_workers = 0.0, 0.0
            losses, hash_rows, miss_tags = [], [], []
            pend_rows: Dict[int, Any] = {}
            t_comp_by: Dict[int, float] = {}
            span_rows: List[Tuple[str, int, float, float]] = []
            if not gossip:
                # the hub's own gather phase (round start -> every delta in)
                span_rows.append(("gather", -1, 0.0,
                                  round(t_gather_meas, 6)))
            for c in list(contributors):
                if not alive[c]:
                    continue
                msg = handles[c].get("done", round_timeout_s)
                if msg is None:
                    alive[c] = False
                    crash_tags.append(f"crash(c{c})")
                    handles[c].kill()
                    continue
                t_comp_by[c] = float(msg["t_compute"])
                t_compute_meas = max(t_compute_meas,
                                     float(msg["t_compute"]))
                t_comm_workers = max(t_comm_workers,
                                     float(msg.get("t_comm", 0.0)))
                if msg.get("loss") is not None:
                    losses.append(float(msg["loss"]))
                if msg.get("param_hash") is not None:
                    hash_rows.append((c, msg["param_hash"]))
                if msg.get("pending") is not None:
                    pend_rows[c] = msg["pending"]
                for s in msg.get("spans") or []:
                    span_rows.append((str(s[0]), int(s[1]),
                                      float(s[2]), float(s[3])))
                for j in msg.get("missing", []):
                    miss_tags.append(f"p2pmiss(c{c}<-c{j})")
            t_round_meas = time.monotonic() - t0

            if ctrl is not None and ctrl.needs_spectral:
                # spectral feedback: masked mean of the workers' reported
                # post-round pending deltas through the same jitted mean
                # the in-process simulator uses — identical r' signal,
                # identical next-round rank
                stacked = _stack_rows([pend_rows.get(c, zeros_row)
                                       for c in range(C)])
                ctrl.observe(mean_j(stacked, jnp.asarray(alive, jnp.float32)))

            # measured comm time: the central gather phase for the
            # overlapped hub round; otherwise the slowest worker's own
            # comm leg (sync trains first; gossip never routes through us)
            t_comm_meas = (t_gather_meas if (not gossip and sc.delay)
                           else t_comm_workers)

            param_hash = None
            if numeric and hash_rows:
                if gossip:
                    param_hash = combine_row_hashes(hash_rows)
                else:
                    uniq = sorted({h for _, h in hash_rows})
                    if len(uniq) > 1:
                        raise WorkerDied(
                            f"replica divergence at round {r}: param "
                            f"hashes {uniq}")
                    param_hash = uniq[0]

            survivors = [int(i) for i in np.flatnonzero(alive)]
            tokens = (sc.tokens_per_step
                      * sum(h_map[c] for c in survivors) / max(C, 1))
            events.append(RoundEvent(
                round=r, alive=tuple(survivors),
                rejoined=tuple(int(i) for i in np.flatnonzero(rejoined)),
                h_steps=h_t, rank=rank_t,
                t_compute_s=t_compute_meas, t_comm_s=t_comm_meas,
                exposed_comm_s=max(0.0, t_round_meas - t_compute_meas),
                t_round_s=t_round_meas, wire_bytes=wire_r,
                slowest_cluster=slowest, bottleneck_cluster=bottleneck,
                tokens=tokens,
                faults=(sc.faults.active(r) + tuple(crash_tags)
                        + tuple(sorted(miss_tags))),
                loss=(float(np.mean(losses)) if losses else None),
                param_hash=param_hash, wire_bytes_total=wire_total,
                ranks=ranks_tuple,
                h_by=(tuple(h_map[c] for c in survivors)
                      if h_active and survivors else None),
                t_compute_by=(tuple(t_comp_by.get(c, 0.0)
                                    for c in survivors)
                              if survivors else None),
                idle_by=(tuple(t_compute_meas - t_comp_by.get(c, 0.0)
                               for c in survivors)
                         if survivors else None),
                spans=(tuple(sorted(
                    span_rows,
                    key=lambda s: (s[1], _SPAN_ORDER.get(s[0], 99), s[2])))
                    if span_rows else None)))

        run_barrier(sc.rounds, _barrier_round)

        if numeric and alive.any():
            if gossip:
                final_params = {}
                for c in np.flatnonzero(alive):
                    st = dump_one(int(c))
                    if st is not None and st.get("params") is not None:
                        final_params[int(c)] = st["params"]
            else:
                final_params = dump_state()["params"]
    finally:
        for h in handles.values():
            h.send({"type": "stop"})
        time.sleep(0.05)
        for h in handles.values():
            h.kill()
        server.close()
        for h in handles.values():
            try:
                h.proc.wait(timeout=10.0)
            except Exception:
                pass

    tl = Timeline(scenario={**sc.meta(), "backend": "proc"}, events=events)
    if final_params is not None:
        tl.final_params = final_params
    return tl


def _run_proc_bounded_stale(sc: Scenario, problem=None, *,
                            crash_at: Optional[Dict[int, int]] = None,
                            spawn_timeout_s: float = 300.0,
                            round_timeout_s: float = 300.0) -> Timeline:
    """Bounded-stale async rounds on real processes: the coordinator stops
    being a lockstep gather hub and becomes a membership/clock service over
    the SAME :class:`BoundedStaleEngine` the in-process backend drives.

    The engine runs on modeled time (``async_modeled_times`` — the one
    shared definition), so its commit sequence, staleness records, and
    round-clock vectors are bit-identical to ``simulate()``'s; each commit
    is realized as one serial round-trip with the owning worker (round →
    delta → weighted avg → done).  Workers run flat-out (no compute-target
    sleep, unthrottled links): wall clock never feeds a structural field,
    which is what makes the CI run-to-run drift gate and the cross-backend
    structural/param-hash comparison exact.

    Membership is event-driven: ``on_leave`` SIGKILLs the worker at its
    local leg start; ``on_join`` respawns it when the fleet frontier
    reaches the join round and bootstraps it from the survivors' consensus
    (masked mean of params + outer momentum — the in-process
    ``_AsyncNumeric.on_join`` arithmetic).
    """
    from repro.core.compression import make_compressor
    from repro.sim.simulator import async_modeled_times
    from repro.topology import async_mix_weights

    if crash_at:
        raise NotImplementedError(
            "crash_at is a barrier-round test hook; bounded_stale models "
            "churn through Leave/Join engine events")
    if sc.topology_seed_schedule is not None:
        raise ValueError(
            "sync='bounded_stale' gates on a FIXED peer set per cluster; "
            "run dynamic topologies under barrier")
    numeric = problem is not None
    if numeric and problem.n_clusters != sc.n_clusters:
        raise ValueError("problem.n_clusters != scenario.n_clusters")

    C = sc.n_clusters
    topo = sc.topo()
    compressor = make_compressor(sc.compressor, **sc.compressor_kw)
    wire = int(compressor.wire_bytes(sc.shapes(), rank=sc.rank))
    W_base = async_mix_weights(topo)
    peers = [tuple(p for p in range(C) if p != c and W_base[c, p] > 0.0)
             for c in range(C)]
    leg_seconds, send_seconds, sends = async_modeled_times(sc, wire, topo)
    trimmed = sc.aggregation == "trimmed_mean"
    alive = (np.ones(C, bool) if sc.initial_alive is None
             else np.asarray(sc.initial_alive, bool).copy())
    epochs = {c: 0 for c in range(C)}

    if numeric:
        import jax
        import jax.numpy as jnp

        from repro.core.diloco import staleness_weights
        from repro.core.membership import (masked_cluster_mean,
                                           trimmed_cluster_mean)
        mean_j = jax.jit(masked_cluster_mean)
        trim_j = jax.jit(
            lambda t, m: trimmed_cluster_mean(t, m, sc.trim_k))
        corrupt_j = jax.jit(lambda t, s: jax.tree.map(
            lambda x: (s * x.astype(jnp.float32)).astype(x.dtype), t))
        zeros_row = jax.tree.map(
            lambda x: np.zeros(np.shape(x), np.float32),
            problem.init_params())
        jax.block_until_ready(mean_j(_stack_rows([zeros_row] * C),
                                     jnp.ones((C,), jnp.float32)))

    server = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    server.bind(("127.0.0.1", 0))
    server.listen(C + 2)
    port = server.getsockname()[1]
    handles: Dict[int, _Handle] = {}

    def accept_one(expect: int, timeout: float) -> None:
        from repro.sim.proc.transport import recv_frame
        deadline = time.monotonic() + timeout
        while handles[expect].conn is None:
            server.settimeout(max(0.1, deadline - time.monotonic()))
            conn, _ = server.accept()
            hello = recv_frame(conn, timeout=30.0)
            h = handles[int(hello["cluster"])]
            h.p2p_port = hello.get("p2p_port")
            h.attach(conn)

    def spawn(c: int) -> None:
        epochs[c] += 1
        # gossip=False even on ring/torus: async mixing happens in the
        # coordinator's weighted mean over the versioned delta store, not
        # over p2p links (there is no synchronized peer round to exchange
        # with) — the topology enters through W_base/peers instead
        handles[c] = _Handle(c, _spawn(c, port, sc, problem, False,
                                       epochs[c], None))

    def dump_one(c: int) -> Optional[Dict[str, Any]]:
        h = handles.get(c)
        if h is None or h.dead or not h.send({"type": "dump"}):
            return None
        return h.get("state", round_timeout_s)

    def consensus_state() -> Dict[str, Any]:
        """Masked mean of the SURVIVORS' (params, outer momentum) — the
        same zero-padded rows through the same jitted mean as
        ``_AsyncNumeric.on_join``, hence a bit-identical bootstrap."""
        states = {c: dump_one(c) for c in range(C)
                  if alive[c] and not handles[c].dead}
        rows_p, rows_m, mask, step = [], [], [], None
        for c in range(C):
            st = states.get(c)
            if st is not None and st.get("params") is not None:
                rows_p.append(st["params"])
                rows_m.append(st["outer_opt"]["momentum"])
                step = st["outer_opt"]["step"]
                mask.append(1.0)
            else:
                rows_p.append(zeros_row)
                rows_m.append(zeros_row)
                mask.append(0.0)
        if step is None:
            raise WorkerDied("no live worker to bootstrap a rejoin from")
        m = jnp.asarray(mask, jnp.float32)
        params = jax.tree.map(np.asarray, mean_j(_stack_rows(rows_p), m))
        mom = jax.tree.map(np.asarray, mean_j(_stack_rows(rows_m), m))
        # the rejoiner's outer step counter restarts at 0, exactly like
        # _AsyncNumeric.on_join — NOT a survivor's counter: nesterov.update
        # ignores step today, but the documented bootstrap is bit-identical
        # and must stay so if step ever enters the update (e.g. a schedule)
        return {"params": params,
                "outer_opt": {"step": np.zeros((), np.int32),
                              "momentum": mom}}

    store: List[Dict[int, Any]] = [dict() for _ in range(C)]
    events: List[RoundEvent] = []
    final_params = None

    def publish_cb(c: int, k: int, t: float) -> None:
        """Engine ``on_publish``: drive the worker's leg (round → delta)
        and materialize the published version the instant the engine says
        it exists — the worker then parks awaiting its ``avg`` (it serves
        ``dump``/``stop`` while parked), so a gate-blocked publisher's
        delta is already in the store for every peer that commits against
        it."""
        h = handles[c]
        if not h.send({"type": "round", "round": k,
                       "compute_target_s": 0.0, "latency_s": 0.0,
                       "charge_bytes": None, "rate_bytes_per_s": None}):
            raise WorkerDied(f"worker c{c} died before async round {k}")
        msg = h.get("delta", round_timeout_s)
        if msg is None:
            raise WorkerDied(f"worker c{c} died in async round {k}")
        if numeric:
            hat = msg["hat"]
            scale = sc.faults.byzantine_scale(c, k)
            pub = (hat if scale is None
                   else jax.tree.map(np.asarray, corrupt_j(
                       hat, jnp.asarray(scale, jnp.float32))))
            store[c][k] = pub

    def commit_cb(ev) -> None:
        c, k = ev.cluster, ev.round
        h = handles[c]
        delta_np = None
        if numeric:
            used = dict(ev.used)
            rows = []
            for p in range(C):
                if p not in used:
                    rows.append(zeros_row)     # weight/mask 0 anyway
                elif used[p] in store[p]:
                    rows.append(store[p][used[p]])
                else:
                    raise WorkerDied(
                        f"bounded-stale store miss: commit (c{c}, k{k}) "
                        f"uses version (c{p}, k{used[p]}) which was never "
                        f"materialized — engine publish/commit contract "
                        f"broken")
            stacked = _stack_rows(rows)
            if trimmed:
                mask = np.array([1.0 if p in used else 0.0
                                 for p in range(C)], np.float32)
                Delta = trim_j(stacked, jnp.asarray(mask))
            else:
                stal = np.full((C,), -1, np.int64)
                for p, s_p in ev.staleness:
                    stal[p] = s_p
                w = staleness_weights(W_base[c], stal, sc.max_staleness)
                Delta = mean_j(stacked, jnp.asarray(w))
            delta_np = jax.tree.map(lambda x: np.asarray(x), Delta)
            # GC: avail watermarks are monotone (per epoch) — versions
            # below avail[p] can never be referenced again
            for p in range(C):
                for old in [v for v in store[p] if v < ev.avail[p]]:
                    del store[p][old]
        if not h.send({"type": "avg", "delta": delta_np}):
            raise WorkerDied(f"worker c{c} died in async round {k}")
        done = h.get("done", round_timeout_s)
        if done is None:
            raise WorkerDied(f"worker c{c} died in async round {k}")
        span_rows = [(str(s[0]), int(s[1]), float(s[2]), float(s[3]))
                     for s in done.get("spans") or []]
        t_comp, wait, t_send = (float(ev.t_compute), float(ev.wait),
                                float(ev.t_send))
        events.append(RoundEvent(
            round=k, alive=ev.alive, rejoined=ev.rejoined,
            h_steps=sc.h_steps, rank=sc.rank,
            t_compute_s=t_comp, t_comm_s=t_send, exposed_comm_s=wait,
            t_round_s=t_comp + wait, wire_bytes=wire,
            slowest_cluster=c, bottleneck_cluster=c,
            tokens=sc.tokens_per_step * sc.h_steps / max(C, 1),
            faults=sc.faults.active(k),
            loss=done.get("loss"), param_hash=done.get("param_hash"),
            wire_bytes_total=wire * sends[c],
            t_compute_by=(t_comp,), idle_by=(wait,),
            spans=(tuple(sorted(
                span_rows,
                key=lambda s: (s[1], _SPAN_ORDER.get(s[0], 99), s[2])))
                if span_rows else None),
            cluster=c, staleness=ev.staleness,
            round_clock=ev.round_clock, t_start_s=float(ev.t_start)))

    def on_leave(c: int, k: int, t: float) -> None:
        alive[c] = False
        if c in handles and not handles[c].dead:
            handles[c].kill()

    def on_join(c: int, k: int, t: float) -> None:
        state = consensus_state() if numeric else None
        spawn(c)
        accept_one(c, spawn_timeout_s)
        handles[c].send({"type": "bootstrap",
                         "params": None if state is None
                         else state["params"],
                         "outer_opt": None if state is None
                         else state["outer_opt"]})
        store[c].clear()
        alive[c] = True

    try:
        for c in np.flatnonzero(alive):
            spawn(int(c))
        for c in sorted(handles):
            if handles[c].conn is None:
                accept_one(c, spawn_timeout_s)
        for c in sorted(handles):
            handles[c].send({"type": "bootstrap", "params": None,
                             "outer_opt": None})

        engine = BoundedStaleEngine(
            n_clusters=C, rounds=sc.rounds,
            max_staleness=sc.max_staleness, peers=peers,
            leg_seconds=leg_seconds, send_seconds=send_seconds,
            commit=commit_cb, on_publish=publish_cb,
            leaves=sc.faults.leave_events(),
            joins=sc.faults.join_events(),
            initial_alive=[int(i) for i in np.flatnonzero(alive)],
            on_leave=on_leave, on_join=on_join)
        engine.run()

        if numeric and alive.any():
            final_params = {}
            for c in np.flatnonzero(alive):
                st = dump_one(int(c))
                if st is not None and st.get("params") is not None:
                    final_params[int(c)] = st["params"]
    finally:
        for h in handles.values():
            h.send({"type": "stop"})
        time.sleep(0.05)
        for h in handles.values():
            h.kill()
        server.close()
        for h in handles.values():
            try:
                h.proc.wait(timeout=10.0)
            except Exception:
                pass

    tl = Timeline(scenario={**sc.meta(), "backend": "proc"}, events=events)
    if final_params is not None:
        tl.final_params = final_params
    return tl
