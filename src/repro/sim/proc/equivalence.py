"""Equivalence harness: proc backend vs in-process simulator.

Two guarantees, checked per round on the same ``Scenario`` + seeds — for
EVERY topology (gather kinds and gossip kinds) and both the §2.3 delayed
and the synchronous (``delay=False``) round:

 1. **Numerics, bit-for-bit**: the proc backend's per-round outer state —
    hence every averaged/mixed pseudo-gradient Δ^t that produced it — must
    hash identically to the in-process simulator's
    (``RoundEvent.param_hash``, sha256 over raw float bytes).  This holds
    because both backends execute the same per-cluster compiled
    computations (``core.diloco.per_cluster_compress``, the per-cluster
    inner slice, ``membership.masked_cluster_mean`` /
    ``topology.mixing.mix_row``, the Nesterov outer update) — no
    tolerance, equality of bytes.  Under gossip the per-round hash is
    ``combine_row_hashes`` over the alive replicas (per-cluster params
    legitimately differ), so equality still certifies every replica.
 2. **Timing, within tolerance**: the proc backend's *measured* wall-clock
    round times must agree with the in-process *modeled* ones.  Rounds with
    rejoins are excluded (process spawn + XLA warmup is real time the clock
    model deliberately does not price).

``check_equivalence`` returns a JSON-able report; ``ok`` is the CI gate.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.sim.proc.coordinator import run_proc
from repro.sim.scenario import Scenario


def _leaves(tree):
    """Flatten a params pytree (nested dicts/lists) to leaves in sorted-key
    order — the scalar engine's flat dict and the pp engine's nested
    ``{"embed", "stages", ...}`` tree both pass through unchanged shape."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaves(tree[k])
    elif isinstance(tree, (list, tuple)):
        for x in tree:
            yield from _leaves(x)
    else:
        yield tree


def check_equivalence(sc: Scenario, problem=None, *,
                      time_rtol: float = 0.5, time_atol: float = 0.3,
                      crash_at: Optional[Dict[int, int]] = None
                      ) -> Dict[str, Any]:
    """Run both backends; compare structure bit-for-bit and timing within
    ``atol + rtol * modeled`` per round.  ``problem`` is a
    ``QuadraticSpec`` (None: timing-only).  ``crash_at`` injects crashes in
    the proc run only — then numeric equality is *expected to fail* and
    callers should not assert ``ok`` (used by the recovery tests)."""
    from repro.sim.simulator import simulate

    tl_proc = run_proc(sc, problem, crash_at=crash_at)
    tl_model = simulate(sc, numeric=problem.problem() if problem else None)

    numeric = problem is not None
    report: Dict[str, Any] = {
        "rounds": [], "ok": True,
        "structural_match": True,
        # None = not applicable (timing-only run has no numerics to hash);
        # never report bitwise equality that was not actually checked
        "hash_match": True if numeric else None,
        "timing_ok": True,
        "max_abs_time_err_s": 0.0, "max_rel_time_err": 0.0,
        "proc_fingerprint": tl_proc.structural_fingerprint(),
        "model_fingerprint": tl_model.structural_fingerprint(),
        # adaptive runs: the controller's decision trace must be identical
        # on both backends (per-round executed rank, and per-edge send
        # ranks under gossip)
        "rank_schedule_proc": tl_proc.rank_schedule(),
        "rank_schedule_model": tl_model.rank_schedule(),
        "rank_schedule_match": (
            tl_proc.rank_schedule() == tl_model.rank_schedule()
            and [e.ranks for e in tl_proc.events]
            == [e.ranks for e in tl_model.events]),
        # heterogeneous-H runs: the per-cluster local-step schedule the
        # coordinator broadcast must be identical to the in-process plan
        "h_schedule_proc": tl_proc.h_schedule(),
        "h_schedule_model": tl_model.h_schedule(),
        "h_schedule_match": tl_proc.h_schedule() == tl_model.h_schedule(),
        # inner-engine fields: both timelines must have replayed the same
        # engine ("scalar" single-replica vs "pp" sharded pipeline mesh) —
        # a pp hash compared against a scalar hash would be a vacuous gate
        "inner_engine_proc": tl_proc.scenario.get("inner_engine", "scalar"),
        "inner_engine_model": tl_model.scenario.get("inner_engine",
                                                    "scalar"),
        "inner_engine_match": (
            tl_proc.scenario.get("inner_engine", "scalar")
            == tl_model.scenario.get("inner_engine", "scalar")
            == sc.inner_engine),
    }
    if len(tl_proc.events) != len(tl_model.events):
        report["ok"] = report["structural_match"] = False
        report["error"] = (f"round count {len(tl_proc.events)} != "
                           f"{len(tl_model.events)}")
        return report

    for ep, em in zip(tl_proc.events, tl_model.events):
        row: Dict[str, Any] = {"round": ep.round}
        struct_ok = (ep.alive == em.alive and ep.rejoined == em.rejoined
                     and ep.h_steps == em.h_steps and ep.h_by == em.h_by
                     and ep.rank == em.rank
                     and ep.ranks == em.ranks
                     and ep.wire_bytes == em.wire_bytes
                     and ep.wire_bytes_total == em.wire_bytes_total
                     and ep.faults == em.faults
                     and ep.slowest_cluster == em.slowest_cluster
                     and ep.bottleneck_cluster == em.bottleneck_cluster)
        row["structural"] = struct_ok
        report["structural_match"] &= struct_ok

        row["param_hash_proc"] = ep.param_hash
        row["param_hash_model"] = em.param_hash
        if numeric:
            hash_ok = (ep.param_hash is not None
                       and ep.param_hash == em.param_hash)
            row["hash_match"] = hash_ok
            report["hash_match"] &= hash_ok
        else:
            row["hash_match"] = None

        row["t_round_measured_s"] = round(ep.t_round_s, 6)
        row["t_round_modeled_s"] = round(em.t_round_s, 6)
        if ep.rejoined:
            row["timing_checked"] = False     # spawn/warmup not modeled
        else:
            row["timing_checked"] = True
            err = abs(ep.t_round_s - em.t_round_s)
            rel = err / em.t_round_s if em.t_round_s > 0 else 0.0
            report["max_abs_time_err_s"] = max(
                report["max_abs_time_err_s"], round(err, 6))
            report["max_rel_time_err"] = max(
                report["max_rel_time_err"], round(rel, 6))
            if err > time_atol + time_rtol * em.t_round_s:
                row["timing_ok"] = False
                report["timing_ok"] = False
        report["rounds"].append(row)

    if numeric and not crash_at:
        fp = getattr(tl_proc, "final_params", None)
        fm = getattr(tl_model, "final_params", None)
        if sc.is_gossip:
            # proc: {cluster: row tree} for the finally-alive replicas;
            # model: the stacked tree — compare row-by-row (dead rows have
            # no worker to compare against and are masked out of every
            # mix/bootstrap anyway)
            fml = list(_leaves(fm)) if fm is not None else []
            same = fp is not None and fm is not None and len(fp) > 0
            for c, row in (fp or {}).items():
                rl = list(_leaves(row))
                same = same and len(rl) == len(fml) and all(
                    np.array_equal(np.asarray(a), np.asarray(b)[c])
                    for a, b in zip(rl, fml))
        else:
            fpl = list(_leaves(fp)) if fp is not None else []
            fml = list(_leaves(fm)) if fm is not None else []
            same = (fp is not None and fm is not None
                    and len(fpl) == len(fml) and all(
                        np.array_equal(np.asarray(a), np.asarray(b))
                        for a, b in zip(fpl, fml)))
        report["final_params_bitwise_equal"] = bool(same)
        report["hash_match"] &= bool(same)

    report["ok"] = (report["structural_match"] and report["timing_ok"]
                    and report["rank_schedule_match"]
                    and report["h_schedule_match"]
                    and report["inner_engine_match"]
                    and report["hash_match"] is not False)
    report["timelines"] = {"proc": tl_proc, "model": tl_model}
    return report


def format_report(report: Dict[str, Any]) -> str:
    lines = []
    for row in report["rounds"]:
        tick = {True: "==", False: "!=", None: "--"}[row["hash_match"]]
        t = ("  t_meas={:.3f}s t_model={:.3f}s{}".format(
            row["t_round_measured_s"], row["t_round_modeled_s"],
            "" if row.get("timing_checked") else " (rejoin: not checked)"))
        h = (row["param_hash_proc"] or "-")[:12]
        lines.append(f"round {row['round']:>3}: params[proc] {tick} "
                     f"params[model] ({h}){t}")
    bitwise = ("n/a (timing-only)" if report["hash_match"] is None
               else report["hash_match"])
    sched = report.get("rank_schedule_proc") or []
    if any(r is not None for r in sched):
        lines.append("rank schedule [proc]:  "
                     + " ".join("-" if r is None else str(r) for r in sched)
                     + f"  (match={report['rank_schedule_match']})")
    hsched = report.get("h_schedule_proc") or []
    if any(isinstance(h, list) for h in hsched):
        lines.append("H schedule [proc]:  "
                     + " ".join("/".join(str(v) for v in h)
                                if isinstance(h, list) else str(h)
                                for h in hsched)
                     + f"  (match={report['h_schedule_match']})")
    lines.append(
        "equivalence: structural={structural_match} bitwise={bitwise} "
        "timing={timing_ok} ranks={rank_schedule_match} "
        "h={h_schedule_match} engine={inner_engine_proc}"
        "({inner_engine_match}) "
        "(max err {max_abs_time_err_s:.3f}s / "
        "{max_rel_time_err:.1%})  => {verdict}".format(
            bitwise=bitwise,
            verdict="OK" if report["ok"] else "MISMATCH", **report))
    return "\n".join(lines)
