"""Peer-to-peer worker<->worker links for gossip topologies.

Under a gossip topology the outer-step payloads do NOT pass through the
coordinator: each worker ships its compressed pseudo-gradient directly to
its graph neighbors over TCP, throttled by ONE shared token bucket per
worker — its uplink: sends to different neighbors serialize on it, exactly
like the ``deg * wire / bw`` clock-model charge.

``PeerMesh`` owns:
 - a listening socket (opened before the worker says hello, so its port
   rides in the hello frame and the coordinator can hand out addresses);
 - a dial rule: for an edge (i, j) with i < j, *i* dials — deterministic,
   so both endpoints agree who connects without a rendezvous protocol;
 - per-peer *epochs* (the coordinator's spawn counter): a respawned
   neighbor gets a fresh epoch, which invalidates the cached link and
   triggers a re-dial / re-accept instead of talking to a dead socket;
 - per-link reader threads feeding one inbox queue, so a worker can keep
   receiving while its own sends are blocked in the token bucket (no
   distributed deadlock).

The coordinator never sees these frames; it only orchestrates membership
and faults (which peers exist this round, and at what rate/latency).
"""
from __future__ import annotations

import queue
import socket
import threading
import time
from typing import Any, Dict, Iterable, Optional, Tuple

from repro.sim.proc.transport import TokenBucket, recv_frame, send_frame


class PeerMesh:
    def __init__(self, my_id: int, host: str = "127.0.0.1"):
        self.my_id = int(my_id)
        self.host = host
        self._server = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._server.bind((host, 0))
        self._server.listen(16)
        self.port = self._server.getsockname()[1]
        self._links: Dict[int, Tuple[int, socket.socket]] = {}  # id->(epoch,
        self._lock = threading.Lock()                           #     sock)
        self._ready = threading.Condition(self._lock)
        self.inbox: "queue.Queue[Tuple[int, Any]]" = queue.Queue()
        self._stash: Dict[Tuple[int, int], Any] = {}  # (round, peer) -> msg
        self._bucket: Optional[TokenBucket] = None
        self.latency_s = 0.0
        self._send_lock = threading.Lock()
        self._closing = False
        threading.Thread(target=self._accept_loop, daemon=True).start()

    # ---- connection management -------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _ = self._server.accept()
            except OSError:
                return
            try:
                hello = recv_frame(conn, timeout=30.0)
                peer = int(hello["cluster"])
                epoch = int(hello.get("epoch", 0))
            except Exception:
                conn.close()
                continue
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._install(peer, epoch, conn)

    def _install(self, peer: int, epoch: int, conn: socket.socket) -> None:
        with self._ready:
            old = self._links.pop(peer, None)
            if old is not None:
                try:
                    old[1].close()
                except OSError:
                    pass
            self._links[peer] = (epoch, conn)
            self._ready.notify_all()
        threading.Thread(target=self._reader, args=(peer, conn),
                         daemon=True).start()

    def _reader(self, peer: int, conn: socket.socket) -> None:
        try:
            while True:
                self.inbox.put((peer, recv_frame(conn)))
        except (ConnectionError, OSError, ValueError, EOFError):
            with self._ready:
                if peer in self._links and self._links[peer][1] is conn:
                    del self._links[peer]
                self._ready.notify_all()

    def _dial(self, peer: int, host: str, port: int, epoch: int,
              my_epoch: int, timeout_s: float) -> None:
        deadline = time.monotonic() + timeout_s
        while True:
            try:
                conn = socket.create_connection((host, port), timeout=5.0)
                break
            except OSError:
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.05)
        conn.settimeout(None)
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        send_frame(conn, {"type": "p2p_hello", "cluster": self.my_id,
                          "epoch": my_epoch})
        self._install(peer, epoch, conn)

    def set_peers(self, peers: Dict[int, Tuple[str, int, int]],
                  my_epoch: int, timeout_s: float = 30.0) -> set:
        """Reconcile links with this round's peer set: {id: (host, port,
        epoch)}.  Stale epochs are dropped; missing links are dialed (by
        the lower id) or awaited (inbound, from the higher id).

        Best-effort, never raises: a peer that cannot be reached within
        the (shared) deadline — e.g. it crashed between the coordinator's
        round message and our dial — is simply absent from the returned
        ready set; the caller mixes zeros for its silence, exactly like a
        mid-round crash."""
        deadline = time.monotonic() + timeout_s
        ready = set()
        for peer, (host, port, epoch) in peers.items():
            peer = int(peer)
            with self._ready:
                cur = self._links.get(peer)
                if cur is not None and cur[0] != epoch:
                    try:
                        cur[1].close()
                    except OSError:
                        pass
                    del self._links[peer]
                    cur = None
                have = cur is not None
            if have:
                ready.add(peer)
            elif self.my_id < peer:
                try:
                    self._dial(peer, host, port, epoch, my_epoch,
                               max(0.0, deadline - time.monotonic()))
                    ready.add(peer)
                except OSError:
                    pass                    # crashed/unreachable: zeros
        # inbound side: wait (bounded) for the higher->me links
        with self._ready:
            for peer, (_, _, epoch) in peers.items():
                peer = int(peer)
                if self.my_id < peer or peer in ready:
                    continue
                while (peer not in self._links
                       or self._links[peer][0] != epoch):
                    left = deadline - time.monotonic()
                    if left <= 0 or not self._ready.wait(timeout=left):
                        break               # silent peer: zeros
                else:
                    ready.add(peer)
        return ready

    # ---- data plane -------------------------------------------------------

    def configure(self, rate_bytes_per_s: Optional[float],
                  latency_s: float = 0.0) -> None:
        """Per-round uplink model: ONE bucket shared by all peer sends."""
        self._bucket = (TokenBucket(rate_bytes_per_s)
                        if rate_bytes_per_s else None)
        self.latency_s = float(latency_s)

    def send(self, peer: int, obj: Any,
             charge_bytes: Optional[float] = None) -> float:
        """Charge the shared uplink bucket, then frame+send to ``peer``.
        Returns elapsed seconds.  Raises ConnectionError if the link is
        gone (caller decides whether that peer's silence is tolerable)."""
        with self._ready:
            link = self._links.get(int(peer))
        if link is None:
            raise ConnectionError(f"no link to peer c{peer}")
        t0 = time.monotonic()
        with self._send_lock:
            if self.latency_s > 0:
                time.sleep(self.latency_s)
            if self._bucket is not None and charge_bytes:
                self._bucket.consume(float(charge_bytes))
            send_frame(link[1], obj)
        return time.monotonic() - t0

    def gather(self, rnd: int, expect: Iterable[int],
               timeout_s: float) -> Dict[int, Any]:
        """Collect one ``{"type": "gossip", "round": rnd}`` frame from each
        expected peer.  A peer that stays silent past the deadline (crash)
        is simply absent from the result — the caller substitutes zeros.
        Frames for other rounds are stashed, never dropped."""
        expect = {int(p) for p in expect}
        got: Dict[int, Any] = {}
        # prune stale stash entries: a frame for a PAST round (a straggler
        # that missed its gather deadline) can never be consumed again —
        # dropping it bounds the stash to the current round's lookahead
        for key in [k for k in self._stash if k[0] < rnd]:
            del self._stash[key]
        for p in list(expect):
            msg = self._stash.pop((rnd, p), None)
            if msg is not None:
                got[p] = msg
        deadline = time.monotonic() + timeout_s
        while len(got) < len(expect):
            try:
                peer, msg = self.inbox.get(
                    timeout=max(0.0, deadline - time.monotonic()))
            except queue.Empty:
                break
            if msg.get("type") != "gossip":
                continue
            r = int(msg.get("round", -1))
            if r == rnd and peer in expect and peer not in got:
                got[peer] = msg
            elif r != rnd:
                self._stash[(r, peer)] = msg
        return got

    def close(self) -> None:
        self._closing = True
        try:
            self._server.close()
        except OSError:
            pass
        with self._ready:
            for _, conn in self._links.values():
                try:
                    conn.close()
                except OSError:
                    pass
            self._links.clear()
