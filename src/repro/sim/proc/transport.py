"""Socket transport for the multi-process backend.

Three layers, each separately testable:

 - **frame codec**: length-prefixed frames (``>I`` byte count + pickled
   body).  Pickle is acceptable here — both endpoints are processes *we*
   spawned on 127.0.0.1; nothing listens on external interfaces.
 - **TokenBucket**: classic token-bucket rate limiter over a monotonic
   clock.  ``consume(n)`` blocks until n tokens drained at
   ``rate_bytes_per_s`` (burst bounded by ``capacity_bytes``), so sustained
   measured throughput converges to the configured rate.
 - **RateLimitedLink**: a connected socket + bucket.  ``send`` charges the
   bucket with ``charge_bytes`` — by default the actual frame length, but
   the simulator passes the *modeled* wire bytes of the payload
   (``core.compression`` accounting): compression in this repo is
   value-faithful simulation, the pickled fp32 factors are bigger than the
   int4-packed wire format they stand for, and the link must price what the
   real wire would carry.
"""
from __future__ import annotations

import io
import pickle
import socket
import struct
import threading
import time
from typing import Any, List, Optional, Tuple

_LEN = struct.Struct(">I")
MAX_FRAME_BYTES = 1 << 30        # sanity bound against corrupt prefixes


# ---------------------------------------------------------------------------
# frame codec
# ---------------------------------------------------------------------------

def pack_frame(obj: Any) -> bytes:
    """Serialize one message to a length-prefixed frame."""
    body = pickle.dumps(obj, protocol=4)
    if len(body) > MAX_FRAME_BYTES:
        raise ValueError(f"frame too large: {len(body)} bytes")
    return _LEN.pack(len(body)) + body


def unpack_frames(buf: bytes) -> Tuple[List[Any], bytes]:
    """Decode every complete frame in ``buf``; returns (messages, rest).
    ``rest`` is the trailing partial frame (stream codec: callers may feed
    arbitrary chunk boundaries)."""
    msgs = []
    view = memoryview(buf)
    off = 0
    while len(view) - off >= _LEN.size:
        (n,) = _LEN.unpack_from(view, off)
        if n > MAX_FRAME_BYTES:
            raise ValueError(f"corrupt frame length {n}")
        if len(view) - off - _LEN.size < n:
            break
        body = bytes(view[off + _LEN.size:off + _LEN.size + n])
        msgs.append(pickle.loads(body))
        off += _LEN.size + n
    return msgs, bytes(view[off:])


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = io.BytesIO()
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed while reading frame")
        buf.write(chunk)
        got += len(chunk)
    return buf.getvalue()


def send_frame(sock: socket.socket, obj: Any) -> int:
    data = pack_frame(obj)
    sock.sendall(data)
    return len(data)


def recv_frame(sock: socket.socket, timeout: Optional[float] = None) -> Any:
    if timeout is not None:
        prev = sock.gettimeout()
        sock.settimeout(timeout)
    try:
        (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
        if n > MAX_FRAME_BYTES:
            raise ValueError(f"corrupt frame length {n}")
        return pickle.loads(_recv_exact(sock, n))
    finally:
        if timeout is not None:
            sock.settimeout(prev)    # a one-off timeout must not leak into
                                     # later blocking reads (idle waits
                                     # during a respawn can exceed it)


# ---------------------------------------------------------------------------
# token bucket
# ---------------------------------------------------------------------------

class TokenBucket:
    """Blocking token bucket: tokens accrue at ``rate_bytes_per_s`` up to
    ``capacity_bytes`` (default: 20 ms of rate — small, so short transfers
    can't ride a free burst and measured throughput tracks the rate)."""

    def __init__(self, rate_bytes_per_s: float,
                 capacity_bytes: Optional[float] = None):
        if rate_bytes_per_s <= 0:
            raise ValueError("rate must be positive")
        self.rate = float(rate_bytes_per_s)
        self.capacity = float(capacity_bytes if capacity_bytes is not None
                              else max(1.0, self.rate * 0.02))
        self._tokens = self.capacity
        self._stamp = time.monotonic()
        self._lock = threading.Lock()

    def _refill(self) -> None:
        now = time.monotonic()
        self._tokens = min(self.capacity,
                           self._tokens + (now - self._stamp) * self.rate)
        self._stamp = now

    def consume(self, n_bytes: float) -> float:
        """Drain ``n_bytes`` tokens, sleeping as needed; returns seconds
        blocked.  n may exceed capacity (drained in capacity-sized gulps)."""
        t0 = time.monotonic()
        remaining = float(n_bytes)
        with self._lock:
            while remaining > 0:
                self._refill()
                take = min(remaining, self._tokens)
                self._tokens -= take
                remaining -= take
                if remaining > 0:
                    need = min(remaining, self.capacity) - self._tokens
                    time.sleep(max(need / self.rate, 1e-4))
        return time.monotonic() - t0


# ---------------------------------------------------------------------------
# rate-limited link
# ---------------------------------------------------------------------------

class RateLimitedLink:
    """A connected socket whose sends are paced by a token bucket plus a
    fixed per-send latency.  ``configure()`` swaps rate/latency between
    rounds (link degradation = a smaller bucket rate — enforced by the
    transport, not by a clock model)."""

    def __init__(self, sock: socket.socket,
                 rate_bytes_per_s: Optional[float] = None,
                 latency_s: float = 0.0):
        self.sock = sock
        self.latency_s = float(latency_s)
        self._bucket = (TokenBucket(rate_bytes_per_s)
                        if rate_bytes_per_s else None)
        self._send_lock = threading.Lock()

    def configure(self, rate_bytes_per_s: Optional[float],
                  latency_s: float = 0.0) -> None:
        self._bucket = (TokenBucket(rate_bytes_per_s)
                        if rate_bytes_per_s else None)
        self.latency_s = float(latency_s)

    def send(self, obj: Any, charge_bytes: Optional[float] = None) -> float:
        """Frame + send ``obj``; charge the bucket ``charge_bytes`` (default:
        the actual frame length).  Returns elapsed seconds (throttle +
        latency + the send itself)."""
        data = pack_frame(obj)
        charge = len(data) if charge_bytes is None else float(charge_bytes)
        t0 = time.monotonic()
        with self._send_lock:
            if self.latency_s > 0:
                time.sleep(self.latency_s)
            if self._bucket is not None and charge > 0:
                self._bucket.consume(charge)
            self.sock.sendall(data)
        return time.monotonic() - t0

    def recv(self, timeout: Optional[float] = None) -> Any:
        return recv_frame(self.sock, timeout)

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()
