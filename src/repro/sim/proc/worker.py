"""Worker process for the multi-process backend: ONE virtual cluster.

Runs the real DiLoCoX round math for its cluster — the per-cluster slice of
``core/diloco.py``'s round, with ``core/compression.py`` payloads.  Four
modes, the cross product of overlap x topology:

 - **delay + gather** (the seed mode): a comm thread compresses LAST
   round's pending pseudo-gradient and pushes it to the coordinator
   through the token-bucket-limited socket while the main thread runs the
   H local AdamW steps (§2.3's one-step-delay overlap as two OS threads);
   the coordinator broadcasts the masked mean back.
 - **sync + gather** (``delay=False``, DiLoCo/OpenDiLoCo): train first,
   then compress THIS round's pseudo-gradient (with the carried error
   buffer), ship it, and apply the returned mean — nothing overlaps.
 - **delay/sync + gossip** (ring/torus/random topologies): payloads go
   over direct worker<->worker ``PeerMesh`` links instead of the
   coordinator; each worker mixes its own and its neighbors' compressed
   deltas through its row of the doubly-stochastic mixing matrix
   (``repro.topology.mixing.mix_row`` — the same unrolled multiply-add
   chain the in-process simulator runs, hence bit-identical rows).  The
   coordinator only orchestrates membership and faults.

Timing-only mode (``problem: null``) skips jax entirely (fast spawn) and
exercises membership/transport/timing, including the p2p exchange.

Invocation (by the coordinator): ``python -m repro.sim.proc.worker '<json>'``.
"""
from __future__ import annotations

import json
import os
import socket
import sys
import threading
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.sim.proc.p2p import PeerMesh
from repro.sim.proc.transport import RateLimitedLink
from repro.sim.timeline import tree_hash


def _connect(host: str, port: int, timeout_s: float = 30.0) -> socket.socket:
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            sock = socket.create_connection((host, port), timeout=5.0)
            sock.settimeout(None)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        except OSError:
            if time.monotonic() > deadline:
                raise
            time.sleep(0.05)


class _NumericRuntime:
    """The jitted per-cluster round functions + replicated state."""

    def __init__(self, cfg: Dict[str, Any]):
        import jax
        import jax.numpy as jnp

        from repro.core.compression import make_compressor
        from repro.optim import adamw, nesterov
        from repro.sim.problems import problem_from_dict
        from repro.topology.mixing import mix_row

        self.jax, self.jnp = jax, jnp
        self.nesterov = nesterov
        spec = problem_from_dict(cfg["problem"])
        self.n_clusters = int(cfg.get("n_clusters", spec.n_clusters))
        self.cluster = jnp.asarray(cfg["cluster"], jnp.int32)
        self.compressor = make_compressor(cfg["compressor"]["name"],
                                          **cfg["compressor"]["kw"])
        rank = cfg.get("rank")
        rank_scalar = None if rank is None else jnp.asarray(rank, jnp.int32)
        # adaptive compression: the coordinator broadcasts the controller's
        # per-round rank in the round header; compile the compressor once
        # with the rank as a TRACED argument so every decision reuses it
        self.dynamic_rank = bool(cfg.get("adaptive_rank"))
        warm = cfg.get("warm_rank")
        self.warm_rank = int(warm if warm is not None
                             else (rank if rank is not None
                                   else getattr(self.compressor, "rank", 64)))

        self.params = spec.init_params()
        self.inner_opt = adamw.init(self.params)
        self.outer_opt = nesterov.init(self.params)
        self.zeros = jax.tree.map(
            lambda x: jnp.zeros_like(x, jnp.float32), self.params)
        self.pending = self.zeros          # delay mode: delta^{t-1}
        self.error = self.zeros            # sync mode: carried EF buffer
        self.comp_state = self.compressor.init_state(self.params)

        # heterogeneous local-step scheduling: a round header carrying
        # "h_steps" means a heterogeneous round — run the masked
        # fixed-length scan (compiled once, H as a TRACED argument; the
        # same masked op sequence the in-process simulator vmaps over its
        # h_vec, hence bit-identical rows).  A header WITHOUT the key is a
        # uniform-at-budget round and runs the plain scalar-H program —
        # the masked program is a different compiled computation (XLA may
        # tile reductions differently around the selects), so the
        # dispatch must mirror the coordinator's exactly.
        self.dynamic_h = bool(cfg.get("dynamic_h"))
        self.h_max = int(spec.h_steps)
        self.inner_j = jax.jit(spec.one_cluster_fn())
        self.inner_h_j = (jax.jit(spec.one_cluster_fn_h())
                          if self.dynamic_h else None)
        if self.dynamic_rank:
            self.compress_j = jax.jit(
                lambda d, s, r: self.compressor.roundtrip(d, s, r))
        else:
            self.compress_j = jax.jit(
                lambda d, s: self.compressor.roundtrip(d, s, rank_scalar))

        def err_and_delta(pending, Delta, anchor, params_inner):
            # Alg. 2 error feedback vs the average actually applied:
            # e = δ^{t-1} − Δ, then next pending = (anchor − local) + e
            err = jax.tree.map(lambda d, D: d - D, pending, Delta)
            return jax.tree.map(
                lambda a, p, e: (a.astype(jnp.float32)
                                 - p.astype(jnp.float32)) + e,
                anchor, params_inner, err)

        self.ed_j = jax.jit(err_and_delta)
        # sync-mode pieces: raw pseudo-grad with carried error, then the
        # post-average error for the NEXT round
        self.raw_j = jax.jit(lambda a, p, e: jax.tree.map(
            lambda ai, pi, ei: (ai.astype(jnp.float32)
                                - pi.astype(jnp.float32)) + ei, a, p, e))
        self.err_j = jax.jit(lambda raw, D: jax.tree.map(
            lambda d, Di: d - Di, raw, D))
        self.outer_j = jax.jit(lambda D, o, p: nesterov.update(
            D, o, p, lr=spec.outer_lr, momentum=spec.outer_momentum))
        # gossip: this cluster's row of the mixing matrix applied to the
        # (zeros-padded) per-cluster payload list — the same unrolled chain
        # mix_stacked runs per row in the in-process simulator
        self.mix_j = jax.jit(lambda w_row, parts: mix_row(w_row, parts))

    def inner(self, params, opt, h: Optional[int]):
        """One inner leg; ``h`` present (heterogeneous round) runs the
        masked scan with ``h`` traced, ``h`` absent runs the plain
        scalar-H program."""
        if h is not None and self.inner_h_j is not None:
            hh = self.jnp.asarray(int(h), self.jnp.int32)
            return self.inner_h_j(params, opt, self.cluster, hh)
        return self.inner_j(params, opt, self.cluster)

    def warmup(self, gossip: bool) -> None:
        """Compile every jitted function on the real shapes so round 0's
        measured time is transport+sleep, not XLA compile."""
        jax = self.jax
        hat, _ = self.compress(self.pending, self.comp_state, self.warm_rank)
        p_inner, _, losses = self.inner(self.params, self.inner_opt, None)
        if self.inner_h_j is not None:
            jax.block_until_ready(
                self.inner(self.params, self.inner_opt, self.h_max))
        pend = self.ed_j(self.pending, hat, self.params, p_inner)
        raw = self.raw_j(self.params, p_inner, self.error)
        err = self.err_j(raw, hat)
        out = self.outer_j(hat, self.outer_opt, self.params)
        todo = [pend, raw, err, out]
        if gossip:
            w0 = self.jnp.zeros((self.n_clusters,), self.jnp.float32)
            todo.append(self.mix_j(w0, tuple([self.zeros]
                                             * self.n_clusters)))
        jax.block_until_ready(todo)

    def compress(self, tree, comp_state, rank: Optional[int]):
        """One compressor round-trip at ``rank`` (the coordinator's
        broadcast decision when adaptive; ignored otherwise — the static
        rank is baked into the compiled function)."""
        if self.dynamic_rank:
            r = self.jnp.asarray(int(rank if rank is not None
                                     else self.warm_rank), self.jnp.int32)
            return self.compress_j(tree, comp_state, r)
        return self.compress_j(tree, comp_state)

    def mix(self, w_row: np.ndarray, hats: Dict[int, Any], own_hat) -> Any:
        """Δ_row = Σ_j w_row[j] · hat_j with zeros for absent clusters."""
        jnp = self.jnp
        parts = []
        for j in range(self.n_clusters):
            if j == int(self.cluster):
                parts.append(own_hat)
            elif j in hats and hats[j] is not None:
                parts.append(self.jax.tree.map(jnp.asarray, hats[j]))
            else:
                parts.append(self.zeros)
        return self.mix_j(jnp.asarray(w_row, jnp.float32), tuple(parts))

    def load(self, params_np: Any, outer_np: Optional[Dict[str, Any]]):
        """Bootstrap a (re)spawned worker from the coordinator's replica
        (gather: a surviving replica's state; gossip: the masked mean of
        the survivors): current params + outer momentum; inner/compressor
        state stays freshly initialized (a rejoiner missed the interim)."""
        jax, jnp = self.jax, self.jnp
        self.params = jax.tree.map(jnp.asarray, params_np)
        if outer_np is not None:
            self.outer_opt = self.nesterov.NesterovState(
                step=jnp.asarray(outer_np["step"]),
                momentum=jax.tree.map(jnp.asarray, outer_np["momentum"]))


def _to_np(tree: Any) -> Any:
    if tree is None:
        return None
    import jax
    return jax.tree.map(lambda x: np.asarray(x), tree)


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    cfg = json.loads(argv[0])
    cluster = int(cfg["cluster"])
    crash_at = cfg.get("crash_at_round")
    delay = bool(cfg.get("delay", True))
    gossip = bool(cfg.get("gossip", False))
    # bounded-stale async rounds: the coordinator's weighted mean mixes
    # STALE peer deltas, so error feedback must be the classic
    # compressor-local form e = δ − C(δ) (vs Alg. 2's δ − Δ, whose I − W
    # error iteration diverges under partial/stale mixing — the same
    # reasoning as the gossip arm below)
    classic_ef = bool(cfg.get("classic_ef", False))
    report_pending = bool(cfg.get("report_pending", False))
    my_epoch = int(cfg.get("epoch", 0))

    if cfg.get("problem") is not None:
        # pp problems run their inner loop on a faked ("data","model")
        # device mesh: the device count must be forced BEFORE the first
        # jax import (jax locks it at init), i.e. before _NumericRuntime.
        # The count comes from the raw problem dict, jax-free.
        from repro.sim.problems import xla_device_count
        n_dev = xla_device_count(cfg["problem"])
        flags = os.environ.get("XLA_FLAGS", "")
        if n_dev > 1 and "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count={n_dev}"
            ).strip()

    mesh = PeerMesh(cluster) if gossip else None
    rt = _NumericRuntime(cfg) if cfg.get("problem") is not None else None
    if rt is not None:
        rt.warmup(gossip)

    sock = _connect(cfg.get("host", "127.0.0.1"), int(cfg["port"]))
    link = RateLimitedLink(sock)
    link.send({"type": "hello", "cluster": cluster, "pid": os.getpid(),
               "p2p_port": mesh.port if mesh else None})
    boot = link.recv(timeout=60.0)
    assert boot["type"] == "bootstrap", boot
    if rt is not None and boot.get("params") is not None:
        rt.load(boot["params"], boot.get("outer_opt"))

    def exchange_p2p(msg: Dict[str, Any], r: int, payload) -> Dict[int, Any]:
        """Ship own compressed delta to every alive neighbor (each send
        charged ``charge_bytes`` on the shared uplink bucket), then collect
        theirs.  A silent/crashed/unreachable neighbor yields no frame —
        the caller mixes zeros in its place (tolerated, flagged upstream).
        Every wait in here is bounded by the round's ``p2p_timeout_s``."""
        timeout = float(msg.get("p2p_timeout_s", 30.0))
        peers = {int(j): tuple(addr) for j, addr in msg["peers"].items()}
        ready = mesh.set_peers(peers, my_epoch, timeout_s=timeout)
        got: Dict[int, Any] = {}
        for j in sorted(ready):
            try:
                mesh.send(j, {"type": "gossip", "round": r,
                              "cluster": cluster, "hat": payload},
                          charge_bytes=msg.get("charge_bytes"))
            except (ConnectionError, OSError):
                pass
        # gather only from peers with a live link: a neighbor that could
        # not be reached at all can never deliver a frame, and waiting the
        # full timeout for it would stall every survivor in a crash round
        frames = mesh.gather(r, ready, timeout_s=timeout)
        for j, fr in frames.items():
            got[j] = fr.get("hat")
        return got

    def state_msg() -> Dict[str, Any]:
        """The replicated outer state, as the coordinator dumps it (to
        bootstrap a respawning worker, or the final params)."""
        state = {"type": "state", "params": None, "outer_opt": None}
        if rt is not None:
            state["params"] = _to_np(rt.params)
            state["outer_opt"] = {
                "step": np.asarray(rt.outer_opt.step),
                "momentum": _to_np(rt.outer_opt.momentum)}
        return state

    while True:
        msg = link.recv()
        if msg["type"] == "stop":
            break
        if msg["type"] == "dump":
            link.send(state_msg())
            continue
        assert msg["type"] == "round", msg
        r = int(msg["round"])
        if crash_at is not None and r == int(crash_at):
            os._exit(17)          # injected hard crash, before any send

        link.configure(msg.get("rate_bytes_per_s") if not gossip else None,
                       msg.get("latency_s", 0.0) if not gossip else 0.0)
        if mesh is not None:
            mesh.configure(msg.get("rate_bytes_per_s"),
                           msg.get("latency_s", 0.0))
        comm_out: Dict[str, Any] = {"t_comm": 0.0}

        # measured phase spans (obs/trace.py taxonomy), relative to the
        # round's own start; shipped in the done report.  list.append is
        # GIL-atomic, so the overlapped comm thread can record too; the
        # coordinator sorts the merged list deterministically.
        t0_round = time.monotonic()
        spans = []

        def _span(name: str, start: float, end: float) -> None:
            spans.append((name, cluster, round(start - t0_round, 6),
                          round(max(0.0, end - start), 6)))

        def compute_leg():
            t0 = time.monotonic()
            out = {"p_inner": None, "inner_new": None, "loss": None}
            if rt is not None:
                p_inner, inner_new, losses = rt.inner(
                    rt.params, rt.inner_opt, msg.get("h_steps"))
                rt.jax.block_until_ready(p_inner)
                out.update(p_inner=p_inner, inner_new=inner_new,
                           loss=float(np.mean(np.asarray(losses))))
            t_inner_end = time.monotonic()
            _span("inner", t0, t_inner_end)
            pad = float(msg.get("compute_target_s", 0.0)) \
                - (time.monotonic() - t0)
            if pad > 0:
                time.sleep(pad)
            # always record idle (dur 0 when there was no pad) so the span
            # structure stays deterministic across runs
            _span("idle", t_inner_end, time.monotonic())
            out["t_compute"] = time.monotonic() - t0
            return out

        def comm_leg(pending_tree):
            """Compress + ship (delay mode: runs overlapped with compute).
            Returns nothing; results land in comm_out — including any
            exception, so the overlapped thread's root cause resurfaces on
            the main thread instead of a downstream KeyError/timeout."""
            t0 = time.monotonic()
            try:
                if rt is not None:
                    hat, comp_new = rt.compress(pending_tree, rt.comp_state,
                                                msg.get("rank"))
                    comm_out["hat"] = hat
                    comm_out["comp_state"] = comp_new
                    payload = _to_np(hat)
                    _span("compress", t0, time.monotonic())
                else:
                    comm_out["hat"] = None
                    payload = None
                t_wire0 = time.monotonic()
                if gossip:
                    comm_out["peer_hats"] = exchange_p2p(msg, r, payload)
                else:
                    link.send({"type": "delta", "round": r,
                               "cluster": cluster, "hat": payload},
                              charge_bytes=msg.get("charge_bytes"))
                _span("wire", t_wire0, time.monotonic())
            except BaseException as e:
                comm_out["error"] = e
                raise
            comm_out["t_comm"] = time.monotonic() - t0

        param_hash = None
        raw = None
        if delay:
            # ---- §2.3 overlap: ship δ^{t-1} while training this round
            tx = threading.Thread(target=comm_leg,
                                  args=(rt.pending if rt else None,),
                                  daemon=True)
            tx.start()
            cmp_ = compute_leg()
            tx.join()
            if comm_out.get("error") is not None:
                raise comm_out["error"]
        else:
            # ---- synchronous round: train, then sync THIS round's delta
            cmp_ = compute_leg()
            if rt is not None:
                raw = rt.raw_j(rt.params, cmp_["p_inner"], rt.error)
            comm_leg(raw)

        t_mix0 = time.monotonic()
        if gossip:
            Delta = (rt.mix(msg["w_row"], comm_out["peer_hats"],
                            comm_out["hat"]) if rt is not None else None)
        else:
            avg = link.recv()
            # bounded-stale mode parks the worker here between its publish
            # (delta shipped at leg finish) and its commit: serve state
            # dumps meanwhile — rt.params is still the pre-commit anchor,
            # exactly the row the in-process executor's consensus
            # bootstrap reads from a gate-blocked peer — and exit cleanly
            # on a stop that lands mid-park
            while avg["type"] == "dump":
                link.send(state_msg())
                avg = link.recv()
            if avg["type"] == "stop":
                break
            assert avg["type"] == "avg", avg
            Delta = (rt.jax.tree.map(rt.jnp.asarray, avg["delta"])
                     if rt is not None else None)
        # mix = neighbor mixing (gossip) or wait-for + apply the broadcast
        # average (gather): the worker-side tail of the outer sync
        _span("mix", t_mix0, time.monotonic())

        if rt is not None:
            t_outer0 = time.monotonic()
            anchor = rt.params
            # gossip: classic compressor-local EF (e = δ − C(δ)) — see
            # core.diloco._error_feedback for why Alg. 2's δ − Δ form is
            # unstable under partial mixing
            err_ref = comm_out["hat"] if (gossip or classic_ef) else Delta
            if delay:
                rt.pending = rt.ed_j(rt.pending, err_ref, anchor,
                                     cmp_["p_inner"])
            else:
                rt.error = rt.err_j(raw, err_ref)
            rt.params, rt.outer_opt = rt.outer_j(Delta, rt.outer_opt,
                                                 anchor)
            rt.inner_opt = cmp_["inner_new"]
            rt.comp_state = comm_out["comp_state"]
            param_hash = tree_hash(rt.params)
            _span("outer", t_outer0, time.monotonic())

        done = {"type": "done", "round": r, "cluster": cluster,
                "t_compute": cmp_["t_compute"],
                "t_comm": comm_out["t_comm"],
                "spans": spans,
                "missing": (sorted(set(int(j) for j in msg["peers"])
                                   - set(comm_out.get("peer_hats", {})))
                            if gossip else []),
                "param_hash": param_hash, "loss": cmp_["loss"]}
        if report_pending and rt is not None and delay:
            # spectral adaptive feedback: the post-round pending delta is
            # the controller's rank signal.  Control-plane telemetry, not
            # modeled wire — charge the bucket nothing for it.
            done["pending"] = _to_np(rt.pending)
            link.send(done, charge_bytes=0)
        else:
            link.send(done)

    if mesh is not None:
        mesh.close()
    link.close()


if __name__ == "__main__":
    main()
