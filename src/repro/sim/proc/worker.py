"""Worker process for the multi-process backend: ONE virtual cluster.

Runs the real DiLoCoX round math for its cluster — the per-cluster slice of
``core/diloco.py``'s delayed round, with ``core/compression.py`` payloads:

 - **comm thread**: compress last round's pending pseudo-gradient
   (``compressor.roundtrip``, warm-started) and push it to the coordinator
   through the token-bucket-limited socket.  This literally runs while the
   inner steps run — the §2.3 one-step-delay overlap as two OS threads, not
   a clock model.
 - **train thread** (main): H local AdamW steps from the current global
   params, then sleep-padded to the round's modeled compute target (the
   quadratic problem is microseconds; the pad is what makes stragglers
   *actually* slow).
 - **join**: receive the masked cluster mean Δ, compute Alg. 2 error
   feedback (e = δ − Δ), the next pending delta, and apply the Nesterov
   outer update locally — every worker holds an identical replica of
   (params, outer momentum), asserted round-by-round via param hashes.

Timing-only mode (``problem: null``) skips jax entirely (fast spawn) and
exercises just membership/transport/timing.

Invocation (by the coordinator): ``python -m repro.sim.proc.worker '<json>'``.
"""
from __future__ import annotations

import json
import os
import socket
import sys
import threading
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.sim.proc.transport import RateLimitedLink
from repro.sim.timeline import tree_hash


def _connect(host: str, port: int, timeout_s: float = 30.0) -> socket.socket:
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            sock = socket.create_connection((host, port), timeout=5.0)
            sock.settimeout(None)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        except OSError:
            if time.monotonic() > deadline:
                raise
            time.sleep(0.05)


class _NumericRuntime:
    """The jitted per-cluster round functions + replicated state."""

    def __init__(self, cfg: Dict[str, Any]):
        import jax
        import jax.numpy as jnp

        from repro.core.compression import make_compressor
        from repro.optim import adamw, nesterov
        from repro.sim.quadratic import QuadraticSpec

        self.jax, self.jnp = jax, jnp
        self.nesterov = nesterov
        spec = QuadraticSpec.from_dict(cfg["problem"])
        self.cluster = jnp.asarray(cfg["cluster"], jnp.int32)
        self.compressor = make_compressor(cfg["compressor"]["name"],
                                          **cfg["compressor"]["kw"])
        rank = cfg.get("rank")
        rank_scalar = None if rank is None else jnp.asarray(rank, jnp.int32)

        self.params = spec.init_params()
        self.inner_opt = adamw.init(self.params)
        self.outer_opt = nesterov.init(self.params)
        self.pending = jax.tree.map(
            lambda x: jnp.zeros_like(x, jnp.float32), self.params)
        self.comp_state = self.compressor.init_state(self.params)

        one_cluster = spec.one_cluster_fn()
        self.inner_j = jax.jit(one_cluster)
        self.compress_j = jax.jit(
            lambda d, s: self.compressor.roundtrip(d, s, rank_scalar))

        def err_and_delta(pending, Delta, anchor, params_inner):
            # Alg. 2 error feedback vs the global average: e = δ^{t-1} − Δ
            err = jax.tree.map(lambda d, D: d - D, pending, Delta)
            return jax.tree.map(
                lambda a, p, e: (a.astype(jnp.float32)
                                 - p.astype(jnp.float32)) + e,
                anchor, params_inner, err)

        self.ed_j = jax.jit(err_and_delta)
        self.outer_j = jax.jit(lambda D, o, p: nesterov.update(
            D, o, p, lr=spec.outer_lr, momentum=spec.outer_momentum))

    def warmup(self) -> None:
        """Compile every jitted function on the real shapes so round 0's
        measured time is transport+sleep, not XLA compile."""
        jax = self.jax
        hat, _ = self.compress_j(self.pending, self.comp_state)
        p_inner, _, losses = self.inner_j(self.params, self.inner_opt,
                                          self.cluster)
        pend = self.ed_j(self.pending, hat, self.params, p_inner)
        out = self.outer_j(hat, self.outer_opt, self.params)
        jax.block_until_ready((pend, out))

    def load(self, params_np: Any, outer_np: Optional[Dict[str, Any]]):
        """Bootstrap a (re)spawned worker from the coordinator's replica:
        current global params + outer momentum; inner/compressor state stays
        freshly initialized (a rejoining cluster missed the interim)."""
        jax, jnp = self.jax, self.jnp
        self.params = jax.tree.map(jnp.asarray, params_np)
        if outer_np is not None:
            self.outer_opt = self.nesterov.NesterovState(
                step=jnp.asarray(outer_np["step"]),
                momentum=jax.tree.map(jnp.asarray, outer_np["momentum"]))


def _to_np(tree: Any) -> Any:
    if tree is None:
        return None
    import jax
    return jax.tree.map(lambda x: np.asarray(x), tree)


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    cfg = json.loads(argv[0])
    cluster = int(cfg["cluster"])
    crash_at = cfg.get("crash_at_round")

    rt = _NumericRuntime(cfg) if cfg.get("problem") is not None else None
    if rt is not None:
        rt.warmup()

    sock = _connect(cfg.get("host", "127.0.0.1"), int(cfg["port"]))
    link = RateLimitedLink(sock)
    link.send({"type": "hello", "cluster": cluster, "pid": os.getpid()})
    boot = link.recv(timeout=60.0)
    assert boot["type"] == "bootstrap", boot
    if rt is not None and boot.get("params") is not None:
        rt.load(boot["params"], boot.get("outer_opt"))

    while True:
        msg = link.recv()
        if msg["type"] == "stop":
            break
        if msg["type"] == "dump":
            # coordinator wants the replicated outer state (to bootstrap a
            # respawning worker); reply and keep waiting for the next round
            state = {"type": "state", "params": None, "outer_opt": None}
            if rt is not None:
                state["params"] = _to_np(rt.params)
                state["outer_opt"] = {
                    "step": np.asarray(rt.outer_opt.step),
                    "momentum": _to_np(rt.outer_opt.momentum)}
            link.send(state)
            continue
        assert msg["type"] == "round", msg
        r = int(msg["round"])
        if crash_at is not None and r == int(crash_at):
            os._exit(17)          # injected hard crash, before any send

        link.configure(msg.get("rate_bytes_per_s"),
                       msg.get("latency_s", 0.0))
        comm_out: Dict[str, Any] = {}

        def comm_leg():
            t0 = time.monotonic()
            if rt is not None:
                hat, comp_new = rt.compress_j(rt.pending, rt.comp_state)
                comm_out["comp_state"] = comp_new
                payload = _to_np(hat)
            else:
                payload = None
            link.send({"type": "delta", "round": r, "cluster": cluster,
                       "hat": payload},
                      charge_bytes=msg.get("charge_bytes"))
            comm_out["t_comm"] = time.monotonic() - t0

        tx = threading.Thread(target=comm_leg, daemon=True)
        tx.start()

        t0 = time.monotonic()
        loss = None
        p_inner = inner_new = None
        if rt is not None:
            p_inner, inner_new, losses = rt.inner_j(rt.params, rt.inner_opt,
                                                    rt.cluster)
            rt.jax.block_until_ready(p_inner)
            loss = float(np.mean(np.asarray(losses)))
        pad = float(msg.get("compute_target_s", 0.0)) \
            - (time.monotonic() - t0)
        if pad > 0:
            time.sleep(pad)
        t_compute = time.monotonic() - t0

        tx.join()
        avg = link.recv()
        assert avg["type"] == "avg", avg

        param_hash = None
        if rt is not None:
            jnp = rt.jnp
            Delta = rt.jax.tree.map(jnp.asarray, avg["delta"])
            anchor = rt.params
            rt.pending = rt.ed_j(rt.pending, Delta, anchor, p_inner)
            rt.params, rt.outer_opt = rt.outer_j(Delta, rt.outer_opt,
                                                 anchor)
            rt.inner_opt = inner_new
            rt.comp_state = comm_out["comp_state"]
            param_hash = tree_hash(rt.params)

        link.send({"type": "done", "round": r, "cluster": cluster,
                   "t_compute": t_compute, "t_comm": comm_out["t_comm"],
                   "param_hash": param_hash, "loss": loss})

    link.close()


if __name__ == "__main__":
    main()
