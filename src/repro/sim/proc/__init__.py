"""Multi-process simulator backend (``backend="proc"``).

Each virtual cluster is a real OS process; outer-step payloads move over
localhost TCP sockets wrapped in a token-bucket rate limiter, so
``LinkProfile`` bandwidth/latency and ``FaultSchedule`` events (straggler
sleep, link throttle, leave/join by killing and respawning workers) are
enforced by the *transport*, not a clock model.  The numeric round math is
the same ``core/diloco.py`` / ``core/compression.py`` code the in-process
simulator runs — per-round outer state is bit-identical between the two
backends (see ``equivalence.py``).

Topologies: gather kinds (star/full) route payloads through the
coordinator's masked mean; gossip kinds (ring/torus/random) exchange them
over direct worker<->worker ``PeerMesh`` links (``p2p.py``) along the
topology's edges — the coordinator only orchestrates membership and
faults.  Both the §2.3 delayed round and the synchronous ``delay=False``
round are supported on every topology.
"""
from repro.sim.proc.coordinator import run_proc
from repro.sim.proc.equivalence import check_equivalence
from repro.sim.proc.p2p import PeerMesh
from repro.sim.proc.transport import (RateLimitedLink, TokenBucket,
                                      pack_frame, recv_frame, send_frame,
                                      unpack_frames)

__all__ = [
    "run_proc", "check_equivalence", "PeerMesh",
    "RateLimitedLink", "TokenBucket",
    "pack_frame", "unpack_frames", "send_frame", "recv_frame",
]
