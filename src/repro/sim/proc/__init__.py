"""Multi-process simulator backend (``backend="proc"``).

Each virtual cluster is a real OS process; outer-step payloads move over
localhost TCP sockets wrapped in a token-bucket rate limiter, so
``LinkProfile`` bandwidth/latency and ``FaultSchedule`` events (straggler
sleep, link throttle, leave/join by killing and respawning workers) are
enforced by the *transport*, not a clock model.  The numeric round math is
the same ``core/diloco.py`` / ``core/compression.py`` code the in-process
simulator runs — per-round outer state is bit-identical between the two
backends (see ``equivalence.py``).
"""
from repro.sim.proc.coordinator import run_proc
from repro.sim.proc.equivalence import check_equivalence
from repro.sim.proc.transport import (RateLimitedLink, TokenBucket,
                                      pack_frame, recv_frame, send_frame,
                                      unpack_frames)

__all__ = [
    "run_proc", "check_equivalence",
    "RateLimitedLink", "TokenBucket",
    "pack_frame", "unpack_frames", "send_frame", "recv_frame",
]
