import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run (spec deliverable e).

Lowers + compiles every runnable (architecture x input-shape) combination on
the single-pod (16,16) and multi-pod (2,16,16) production meshes, printing
``memory_analysis()`` and ``cost_analysis()`` and parsing collective bytes
from the compiled HLO — the inputs to EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape train_4k [--multi-pod] [--all] [--out results.json]

The XLA_FLAGS line above MUST stay the first statement: jax locks the
device count on first init, and only the dry-run wants 512 host devices.
"""
import argparse
import dataclasses
import json
import math
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, ModelConfig, SHAPES, get_config,
                                get_shape, supports_shape)
from repro.core import mesh_compression as mc
from repro.launch import mesh as mesh_lib
from repro.launch import steps
from repro.models import model as M
from repro.parallel import sharding as sh

# ---------------------------------------------------------------------------
# hardware constants (TPU v5e targets; DESIGN.md §5)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s/link (intra-pod)
DCN_BW = 0.125e9             # 1 Gbps decentralized link (paper's scenario)

COLLECTIVE_RE = re.compile(
    r"= (f8|f16|f32|f64|bf16|u8|s8|u32|s32|pred)\[([\d,]*)\]\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^\n]*)")

GROUPS_LITERAL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=(\[[\d,]+\])?(?:T\(([\d,]+)\))?")
SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)")
PAIR_RE = re.compile(r"\{(\d+),(\d+)\}")


def _first_group(attrs: str):
    """First replica group as a list of device ids (literal or iota form)."""
    m = GROUPS_LITERAL_RE.search(attrs)
    if m:
        return [int(x) for x in m.group(1).split(",") if x.strip()]
    m = GROUPS_IOTA_RE.search(attrs)
    if m:
        import numpy as _np
        n_groups, g_size = int(m.group(1)), int(m.group(2))
        n = n_groups * g_size
        ids = _np.arange(n)
        if m.group(3):
            dims = [int(x) for x in m.group(3).strip("[]").split(",")]
            ids = ids.reshape(dims)
            if m.group(4):
                perm = [int(x) for x in m.group(4).split(",")]
                ids = ids.transpose(perm)
            ids = ids.reshape(-1)
        return list(ids.reshape(n_groups, g_size)[0])
    m = SOURCE_TARGET_RE.search(attrs)
    if m:
        # a permute "crosses" if ANY pair crosses; return the widest pair
        pairs = [(int(a), int(b)) for a, b in PAIR_RE.findall(m.group(1))]
        if pairs:
            widest = max(pairs, key=lambda ab: abs(ab[0] - ab[1]))
            return list(widest)
    return None


def _crosses_cluster(group, cluster_size: int) -> bool:
    if not group:
        return False
    return len({d // cluster_size for d in group}) > 1

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
               "u8": 1, "s8": 1, "u32": 4, "s32": 4, "pred": 1}


def parse_collective_bytes(hlo_text: str,
                           cluster_size: int = 0) -> Dict[str, Any]:
    """Sum output-operand sizes of collective ops in the (post-SPMD) HLO.
    When cluster_size > 0, traffic whose replica groups span clusters is
    reported separately (that is the 1 Gbps decentralized boundary)."""
    out: Dict[str, Any] = {}
    cross = 0
    cross_by_dtype: Dict[str, int] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, kind, attrs = m.group(1), m.group(2), m.group(3), m.group(4)
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        nbytes = n * DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0) + nbytes
        if cluster_size:
            grp = _first_group(attrs)
            if _crosses_cluster(grp, cluster_size):
                cross += nbytes
                cross_by_dtype[dt] = cross_by_dtype.get(dt, 0) + nbytes
    if cluster_size:
        out["_cross_cluster_bytes"] = cross
        out["_cross_cluster_by_dtype"] = cross_by_dtype
    return out


def production_dtypes(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, param_dtype="bfloat16",
                               compute_dtype="bfloat16")


def lower_one(arch: str, shape_name: str, *, multi_pod: bool,
              rank: int = 128, include_outer: bool = True,
              mode: str = "gspmd", verbose: bool = True) -> Dict[str, Any]:
    cfg = production_dtypes(get_config(arch))
    shape = get_shape(shape_name)
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    base = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    res: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "multi_pod": multi_pod, "mode": mode,
                           "mesh": mesh_lib.describe(base)}
    t0 = time.time()
    try:
        if shape.kind == "train" and mode == "pipeline":
            res.update(_lower_train_pipeline(cfg, shape, base))
        elif shape.kind == "train":
            res.update(_lower_train(cfg, shape, base, rank, include_outer,
                                    mode))
        elif shape.kind == "prefill":
            res.update(_lower_prefill(cfg, shape, base))
        else:
            res.update(_lower_decode(cfg, shape, base))
        res["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — report, don't crash the matrix
        res["status"] = "fail"
        res["error"] = f"{type(e).__name__}: {str(e)[:500]}"
    res["lower_compile_s"] = round(time.time() - t0, 1)
    if verbose:
        print(json.dumps(res)[:2000])
    return res


def _analyze(compiled, n_chips: int, cluster_size: int = 0):
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo, cluster_size)
    cross = coll.pop("_cross_cluster_bytes", 0)
    cross_dt = coll.pop("_cross_cluster_by_dtype", {})
    coll_total = sum(coll.values())
    out = {
        "per_device_memory_bytes": int(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll,
        "collective_total_bytes": coll_total,
        "cross_cluster_bytes": cross,
        "cross_cluster_by_dtype": cross_dt,
        # roofline terms (seconds), per device
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_acc / HBM_BW,
        "t_collective_ici": (coll_total - cross) / ICI_BW,
        "t_collective_dcn_1gbps": cross / DCN_BW,
    }
    return out


def _train_shardings(cfg, mesh, n_clusters, p_specs, o_specs, b_specs):
    ps = sh.param_shardings(p_specs, mesh, cluster_stacked=True)
    os_ = jax.tree.map(
        lambda x: (NamedSharding(mesh, P())
                   if x.ndim <= 1 else None), o_specs)
    # opt m/v mirror params; step counters replicated
    m_sh = sh.param_shardings(p_specs, mesh, cluster_stacked=True)
    opt_sh = type(o_specs)(step=jax.tree.map(
        lambda _: NamedSharding(mesh, P("clusters")), o_specs.step),
        m=m_sh, v=m_sh)
    bs = sh.batch_shardings(b_specs, mesh, cluster_stacked=True)
    return ps, opt_sh, bs


def _lower_train(cfg, shape, base, rank, include_outer, mode):
    n_clusters = 2 if base.devices.ndim == 3 else 2
    mesh = mesh_lib.make_cluster_mesh(base, n_clusters=n_clusters)
    n_chips = base.devices.size

    p_specs = steps.params_specs(cfg, n_clusters=n_clusters)
    o_specs = steps.opt_specs(p_specs)
    b_specs = steps.input_specs(cfg, shape, n_clusters=n_clusters)
    ps, opt_sh, bs = _train_shardings(cfg, mesh, n_clusters, p_specs,
                                      o_specs, b_specs)

    train_step = steps.make_train_step(cfg)
    M.set_activation_sharder(sh.make_activation_sharder(mesh))
    lowered = jax.jit(
        train_step,
        in_shardings=(ps, opt_sh, bs),
        out_shardings=(ps, opt_sh, NamedSharding(mesh, P())),
    ).lower(p_specs, o_specs, b_specs)
    compiled = lowered.compile()
    cluster_size = base.devices.size // n_clusters
    out = {"train": _analyze(compiled, n_chips, cluster_size)}
    print("memory_analysis:", compiled.memory_analysis())
    ca = compiled.cost_analysis()
    print("cost_analysis: flops=%.3e bytes=%.3e"
          % (ca.get("flops", 0), ca.get("bytes accessed", 0)))

    if include_outer:
        ccfg = mc.MeshCompressionConfig(rank=rank)
        ost_specs = jax.eval_shape(
            lambda pp: steps.init_outer_state(pp, n_clusters, ccfg),
            steps.params_specs(cfg))
        outer_step = steps.make_outer_step(cfg, ccfg)
        p_unstacked = steps.params_specs(cfg)
        ps_un = sh.param_shardings(p_unstacked, mesh, cluster_stacked=False)
        ost_sh = steps.OuterState(
            anchor=ps_un,
            outer_opt=jax.eval_shape(lambda: None) if False else
            _nesterov_shardings(p_unstacked, mesh),
            delta_pending=sh.param_shardings(p_specs, mesh,
                                             cluster_stacked=True),
            error=sh.param_shardings(p_specs, mesh, cluster_stacked=True),
            q_state=_qstate_shardings(ost_specs.q_state, mesh),
        )
        lowered_o = jax.jit(
            outer_step,
            in_shardings=(ps, ost_sh, NamedSharding(mesh, P())),
            out_shardings=(ps, ost_sh),
        ).lower(p_specs, ost_specs,
                jax.ShapeDtypeStruct((), jnp.int32))
        compiled_o = lowered_o.compile()
        out["outer"] = _analyze(compiled_o, n_chips, cluster_size)
        print("outer memory_analysis:", compiled_o.memory_analysis())
    return out


def _nesterov_shardings(p_specs, mesh):
    from repro.optim import nesterov as nv
    st = jax.eval_shape(nv.init, p_specs)
    mom = sh.param_shardings(p_specs, mesh, cluster_stacked=False)
    return type(st)(step=NamedSharding(mesh, P()), momentum=mom)


def _qstate_shardings(q_specs, mesh):
    def build(leaf):
        if leaf.ndim <= 1:
            return NamedSharding(mesh, P())
        dims = [None] * leaf.ndim
        dims[0] = "clusters" if leaf.shape[0] % mesh.shape["clusters"] == 0 \
            else None
        # shard the n dim (second to last) over data, like params
        if leaf.ndim >= 3 and leaf.shape[-2] % mesh.shape["data"] == 0:
            dims[-2] = "data"
        return NamedSharding(mesh, P(*dims))
    return jax.tree.map(build, q_specs)


def _lower_train_pipeline(cfg, shape, base, n_micro=16):
    """Mode B: paper-faithful PP over the "model" axis (shard_map +
    ppermute GPipe loop), dense decoder archs. One inner step =
    grad(pp_loss) + AdamW."""
    import jax.numpy as jnp
    from repro.optim import adamw
    from repro.parallel import pipeline as PP

    n_clusters = 2
    mesh = mesh_lib.make_cluster_mesh(base, n_clusters=n_clusters)
    n_chips = base.devices.size
    n_stages = mesh.shape["model"]
    pcfg = PP.PipelineConfig(n_stages=n_stages, n_micro=n_micro)
    lps, pad = PP.layers_per_stage(cfg, pcfg)

    p1 = jax.eval_shape(lambda k: PP.init_pp_params(cfg, k, pcfg),
                        jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_specs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_clusters,) + x.shape, x.dtype), p1)
    o_specs = jax.eval_shape(jax.vmap(adamw.init), p_specs)
    Bc = shape.global_batch // n_clusters
    t_specs = jax.ShapeDtypeStruct((n_clusters, Bc, shape.seq_len),
                                   jnp.int32)
    loss_fn = PP.make_pp_loss(cfg, mesh, pcfg, cluster_stacked=True)

    def train_step(params, opt, tokens):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens)
        grads = dict(grads)
        grads["active"] = jnp.zeros_like(grads["active"])
        new_params, opt = jax.vmap(
            lambda p_, g_, o_: adamw.update(g_, o_, p_, lr=1e-4,
                                            weight_decay=0.0))(
            params, grads, opt)
        new_params = dict(new_params)
        new_params["active"] = params["active"]
        return new_params, opt, loss

    specs_in = PP.pp_param_specs(p_specs, mesh, cluster_stacked=False)
    # pp_param_specs built for unstacked; rebuild with the cluster dim
    def to_sharding(tree_specs):
        return jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree_specs)

    pspec_tree = PP.pp_param_specs(p1, mesh, cluster_stacked=False)
    def add_cluster(sp):
        return P(*(("clusters",) + tuple(sp)))
    pspec_tree = jax.tree.map(add_cluster, pspec_tree,
                              is_leaf=lambda x: isinstance(x, P))
    psh = to_sharding(pspec_tree)
    osh = jax.eval_shape(jax.vmap(adamw.init), p_specs)
    osh = type(o_specs)(
        step=NamedSharding(mesh, P("clusters")),
        m=psh, v=psh)
    tsh = NamedSharding(mesh, P("clusters", "data", None))
    lowered = jax.jit(train_step,
                      in_shardings=(psh, osh, tsh),
                      out_shardings=(psh, osh, NamedSharding(mesh, P()))
                      ).lower(p_specs, o_specs, t_specs)
    compiled = lowered.compile()
    print("memory_analysis:", compiled.memory_analysis())
    out = {"train": _analyze(compiled, n_chips,
                             base.devices.size // n_clusters)}
    out["pipeline"] = {"n_stages": n_stages, "layers_per_stage": lps,
                       "padded_layers": pad, "n_micro": n_micro,
                       "bubble_frac": (n_stages - 1)
                       / (n_micro + n_stages - 1)}
    return out


def pp_inner_smoke(arch: str, *, n_stages: int = 8, data_parallel: int = 1,
                   n_micro: int = 8, batch: int = 16, seq_len: int = 512,
                   verbose: bool = True) -> Dict[str, Any]:
    """``--inner pp``: shape-check the FULL-SIZE model through the sharded
    pipeline-parallel inner engine (parallel/inner_engine.py) on the faked
    devices — pure ``jax.eval_shape``, no lowering or compute, so even
    qwen1.5-107b (78 layers, d_model 8192) passes in seconds.  Certifies
    that one inner train step is a shape fixed-point of the
    ``DiLoCoTrainState`` params, that ``state_shardings`` resolves a
    placement rule for every leaf, and that ``extract_delta`` yields an
    fp32 tree congruent with the params (what the outer compress/mix layer
    consumes)."""
    from repro.parallel import inner_engine as IE
    from repro.parallel import pipeline as PP

    cfg = production_dtypes(get_config(arch))
    res: Dict[str, Any] = {"arch": arch, "shape": f"pp_inner_b{batch}",
                           "multi_pod": False, "mode": "pp_inner",
                           "n_stages": n_stages,
                           "data_parallel": data_parallel}
    t0 = time.time()
    try:
        pcfg = PP.PipelineConfig(n_stages=n_stages, n_micro=n_micro)
        lps, pad = PP.layers_per_stage(cfg, pcfg)
        mesh = IE.unit_mesh(pcfg, data_parallel)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        state = jax.eval_shape(lambda k: IE.init_train_state(cfg, pcfg, k),
                               key)
        shardings = IE.state_shardings(state, mesh)
        n_sharded = len(jax.tree.leaves(shardings))
        n_leaves = len(jax.tree.leaves(state))
        assert n_sharded == n_leaves, (n_sharded, n_leaves)

        train_step = IE.make_pp_train_step(cfg, mesh, pcfg, inner_lr=1e-4)
        toks = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        p2, o2, loss = jax.eval_shape(train_step, state.params,
                                      state.inner_opt, toks)
        sd = lambda t: jax.tree.map(lambda a: (a.shape, str(a.dtype)), t)
        assert sd(p2) == sd(state.params), "inner step not a shape fixed-point"
        assert sd(o2) == sd(state.inner_opt)
        assert loss.shape == ()

        delta = jax.eval_shape(IE.extract_delta, state.params, state)
        assert jax.tree.structure(delta) == jax.tree.structure(state.params)
        assert all(x.dtype == jnp.float32 for x in jax.tree.leaves(delta))

        n_params = sum(int(math.prod(x.shape))
                       for x in jax.tree.leaves(state.params))
        res.update({
            "status": "ok", "layers_per_stage": lps, "padded_layers": pad,
            "n_micro": n_micro, "param_count": n_params,
            "state_bytes": sum(
                int(math.prod(x.shape)) * x.dtype.itemsize
                for x in jax.tree.leaves(state)),
            "bubble_frac": (n_stages - 1) / (n_micro + n_stages - 1),
        })
        print(f"PP-INNER-SMOKE-OK arch={arch} stages={n_stages} "
              f"layers_per_stage={lps} params={n_params}")
    except Exception as e:  # noqa: BLE001 — report, don't crash the matrix
        res["status"] = "fail"
        res["error"] = f"{type(e).__name__}: {str(e)[:500]}"
    res["lower_compile_s"] = round(time.time() - t0, 1)
    if verbose:
        print(json.dumps(res)[:2000])
    return res


def _lower_prefill(cfg, shape, base):
    mesh = mesh_lib.make_serving_mesh(base)
    n_chips = base.devices.size
    p_specs = steps.params_specs(cfg)
    b_specs = steps.input_specs(cfg, shape)
    ps = sh.param_shardings(p_specs, mesh, cluster_stacked=False)
    bs = sh.batch_shardings(b_specs, mesh, cluster_stacked=False)
    prefill = steps.make_prefill_step(cfg)
    M.set_activation_sharder(sh.make_activation_sharder(mesh))
    lowered = jax.jit(prefill, in_shardings=(ps, bs)).lower(
        p_specs, b_specs)
    compiled = lowered.compile()
    print("memory_analysis:", compiled.memory_analysis())
    ca = compiled.cost_analysis()
    print("cost_analysis: flops=%.3e bytes=%.3e"
          % (ca.get("flops", 0), ca.get("bytes accessed", 0)))
    return {"prefill": _analyze(compiled, n_chips)}


def _lower_decode(cfg, shape, base):
    import math
    mesh = mesh_lib.make_serving_mesh(base)
    n_chips = base.devices.size
    p_specs = steps.params_specs(cfg)
    s_specs = steps.decode_state_specs(cfg, shape)
    b_specs = steps.input_specs(cfg, shape)
    # [hillclimb D, REFUTED]: TP-only weight sharding for decode predicted
    # killing the 1.2 GB/token all-gathers (assumed FSDP weight gathers).
    # Measured: ICI -1.6% (the gathers are KV-cache/head-layout resharding)
    # and temp memory 0.85 -> 8.7 GB (activations replicated over "data").
    # 2-D weights stay the serving default; flag kept for experiments.
    serve_tp_only = os.environ.get("REPRO_SERVE_TP_ONLY", "0") == "1"
    ps = sh.param_shardings(p_specs, mesh, cluster_stacked=False,
                            serve=serve_tp_only)
    seq_shard = shape.global_batch < mesh.shape["data"]
    ss = sh.decode_state_shardings(s_specs, mesh, seq_shard=seq_shard)
    bs = sh.batch_shardings(b_specs, mesh, cluster_stacked=False)
    serve = steps.make_serve_step(cfg)
    M.set_activation_sharder(sh.make_activation_sharder(mesh))
    lowered = jax.jit(serve, in_shardings=(ps, ss, bs["tokens"]),
                      out_shardings=(bs["tokens"], ss)).lower(
        p_specs, s_specs, b_specs["tokens"])
    compiled = lowered.compile()
    print("memory_analysis:", compiled.memory_analysis())
    ca = compiled.cost_analysis()
    print("cost_analysis: flops=%.3e bytes=%.3e"
          % (ca.get("flops", 0), ca.get("bytes accessed", 0)))
    return {"decode": _analyze(compiled, n_chips),
            "seq_sharded_cache": bool(seq_shard),
            "serve_tp_only_weights": bool(serve_tp_only)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="full matrix: every arch x shape x both meshes")
    ap.add_argument("--no-outer", action="store_true")
    ap.add_argument("--rank", type=int, default=128)
    ap.add_argument("--mode", default="gspmd", choices=["gspmd", "pipeline"])
    ap.add_argument("--inner", default="gspmd", choices=["gspmd", "pp"],
                    help="pp: eval_shape the arch through the sharded "
                         "pipeline-parallel inner engine instead of "
                         "lowering the mesh step (fast, no compute)")
    ap.add_argument("--pp-stages", type=int, default=8)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    results = []
    if args.inner == "pp":
        results.append(pp_inner_smoke(args.arch, n_stages=args.pp_stages))
    elif args.all:
        for arch in [a for a in ARCH_IDS
                     if a not in ("opt-1.3b", "qwen1.5-107b")]:
            for shape in SHAPES:
                for mp in (False, True):
                    results.append(lower_one(
                        arch, shape, multi_pod=mp, rank=args.rank,
                        include_outer=(shape == "train_4k"
                                       and not args.no_outer)))
    else:
        results.append(lower_one(args.arch, args.shape,
                                 multi_pod=args.multi_pod, rank=args.rank,
                                 include_outer=not args.no_outer,
                                 mode=args.mode))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"DRYRUN SUMMARY ok={n_ok} skipped={n_skip} fail={n_fail}")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
