"""Mesh-level step functions (Mode A, pjit/GSPMD).

Cluster semantics: params carry a leading ``n_clusters`` dim sharded over
the "clusters" mesh axis; the inner step is vmapped over it, so dataflow
cannot mix clusters during local training (DESIGN.md §3). The outer step is
the only function whose collectives cross the cluster (1 Gbps) boundary,
and they carry the packed int4 payload (core.mesh_compression).

Functions are pure and jit-ready; ``launch/dryrun.py`` lowers them with
ShapeDtypeStructs, ``launch/train.py`` executes them on small meshes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import mesh_compression as mc
from repro.models import model as M
from repro.optim import adamw, nesterov


# ---------------------------------------------------------------------------
# inner train step (per-cluster, vmapped over the cluster dim)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, *, inner_lr: float = 1e-4,
                    per_cluster_h: bool = False):
    """(params_stacked, opt_stacked, batch_stacked) -> (params', opt', loss).
    One inner AdamW step per cluster; no cross-cluster collectives by
    construction (vmap over the stacked cluster dim).

    ``per_cluster_h=True`` returns the heterogeneous-local-step variant
    ``(params, opt, batch, active) -> (params', opt', loss)``: ``active``
    is a (C,) bool mask and inactive clusters' params/optimizer pass
    through unchanged (bitwise — a select, not an arithmetic no-op), which
    is how the driver realizes a per-cluster H schedule (cluster c sits
    out steps ``h >= h_c`` of the round while the fast ones finish their
    budget); the loss is the mean over active clusters only."""

    def one_cluster(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt = adamw.update(grads, opt, params, lr=inner_lr)
        return params, opt, loss

    if not per_cluster_h:
        def train_step(params_stacked, opt_stacked, batch_stacked):
            params, opt, loss = jax.vmap(one_cluster)(
                params_stacked, opt_stacked, batch_stacked)
            return params, opt, loss.mean()

        return train_step

    def one_cluster_masked(params, opt, batch, active):
        new_p, new_o, loss = one_cluster(params, opt, batch)
        keep = lambda n, o: jnp.where(active, n, o)
        params = jax.tree.map(keep, new_p, params)
        opt = jax.tree.map(keep, new_o, opt)
        return params, opt, jnp.where(active, loss, 0.0)

    def train_step_h(params_stacked, opt_stacked, batch_stacked, active):
        params, opt, losses = jax.vmap(one_cluster_masked)(
            params_stacked, opt_stacked, batch_stacked, active)
        n = jnp.maximum(active.astype(jnp.float32).sum(), 1.0)
        return params, opt, losses.sum() / n

    return train_step_h


# ---------------------------------------------------------------------------
# outer DiLoCoX step (the cross-cluster sync)
# ---------------------------------------------------------------------------

class OuterState(NamedTuple):
    anchor: Any          # theta^{t-1} (unstacked, global)
    outer_opt: Any       # Nesterov momentum
    delta_pending: Any   # cluster-stacked pseudo-grads (previous round)
    error: Any           # cluster-stacked EF buffers
    q_state: Any         # cluster-stacked PowerSGD warm starts


def init_outer_state(params, n_clusters: int,
                     ccfg: mc.MeshCompressionConfig) -> OuterState:
    stack = lambda tree: jax.tree.map(
        lambda x: jnp.zeros((n_clusters,) + x.shape, jnp.float32), tree)
    q0 = mc.init_q_state(params, ccfg)
    q_stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_clusters,) + x.shape).copy(), q0)
    return OuterState(anchor=params, outer_opt=nesterov.init(params),
                      delta_pending=stack(params), error=stack(params),
                      q_state=q_stacked)


def make_outer_step(cfg: ModelConfig, ccfg: mc.MeshCompressionConfig, *,
                    outer_lr: float = 0.7, outer_momentum: float = 0.9):
    """(params_stacked_postH, outer_state, rank_scalar) ->
    (params_stacked_next, outer_state'). Implements Alg. 2's communicate +
    delayed outer update with the one-step-delay schedule."""

    def outer_step(params_stacked, st: OuterState, rank_scalar):
        # communicate: compress + gather + mean LAST round's pseudo-grads
        Delta, q_new = mc.compress_gather_mean(
            st.delta_pending, st.q_state, rank_scalar, ccfg)
        # Alg. 2 error feedback: e = delta^{t-1} - Delta^{t-1}
        err = jax.tree.map(lambda d, D: d - D[None].astype(d.dtype),
                           st.delta_pending, Delta)
        # next pending: (anchor - theta_inner) + e
        delta_new = jax.tree.map(
            lambda a, p, e: (a.astype(jnp.float32)[None]
                             - p.astype(jnp.float32)) + e,
            st.anchor, params_stacked, err)
        # delayed outer update on the anchor
        params_new, outer_opt = nesterov.update(
            Delta, st.outer_opt, st.anchor,
            lr=outer_lr, momentum=outer_momentum)
        # replicas restart from the outer-updated params
        C = jax.tree.leaves(params_stacked)[0].shape[0]
        params_stacked_new = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (C,) + p.shape).astype(p.dtype),
            params_new)
        return params_stacked_new, OuterState(
            anchor=params_new, outer_opt=outer_opt,
            delta_pending=delta_new, error=err, q_state=q_new)

    return outer_step


# ---------------------------------------------------------------------------
# serving steps (no cluster dim; serving mesh ("data","model"))
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    """Forward over the full sequence, returns last-position logits (the
    inference-prefill workload)."""

    def prefill_step(params, batch):
        h, _ = M.forward_hidden(params, cfg, batch, remat=True)
        return M.logits_fn(params, cfg, h[:, -1:])[:, 0]

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, eos_id: Optional[int] = None):
    """One decode step: token + caches -> next token (greedy) + caches.

    With ``eos_id`` set the returned function takes and returns a
    per-sequence ``finished`` bool mask: rows already finished keep
    emitting ``eos_id`` (so everything past the first EOS is masked in
    the decoded output) and the mask absorbs rows whose new token is EOS.
    Callers must reset the mask across prefill-by-decode steps — those
    outputs are prompt-forced and must not trip EOS."""

    def serve_step(params, state, tokens):
        logits, state = M.decode_step(params, cfg, state, tokens)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, state

    if eos_id is None:
        return serve_step

    def serve_step_eos(params, state, tokens, finished):
        nxt, state = serve_step(params, state, tokens)
        nxt = jnp.where(finished[:, None], jnp.int32(eos_id), nxt)
        finished = finished | (nxt[:, 0] == eos_id)
        return nxt, state, finished

    return serve_step_eos


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                n_clusters: int = 1) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        assert B % n_clusters == 0
        Bc = B // n_clusters
        batch = {"tokens": sds((n_clusters, Bc, S), jnp.int32)}
        if cfg.modality != "text":
            batch["frontend"] = sds(
                (n_clusters, Bc, cfg.n_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32)}
        if cfg.modality != "text":
            batch["frontend"] = sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                    jnp.dtype(cfg.compute_dtype))
        return batch
    # decode: one new token against an S-long cache
    return {"tokens": sds((B, 1), jnp.int32)}


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig):
    """eval_shape of init_decode_state (no allocation)."""
    return jax.eval_shape(
        lambda: M.init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                    dtype=jnp.dtype(cfg.compute_dtype)))


def params_specs(cfg: ModelConfig, *, n_clusters: int = 0):
    """eval_shape of init_params (+ optional cluster stacking)."""
    p = jax.eval_shape(lambda k: M.init_params(cfg, k),
                       jax.ShapeDtypeStruct((2,), jnp.uint32))
    if n_clusters:
        p = jax.tree.map(
            lambda x: sds((n_clusters,) + x.shape, x.dtype), p)
    return p


def opt_specs(params_stacked_specs):
    """vmapped init => per-cluster step counters (C,) and stacked m/v."""
    return jax.eval_shape(jax.vmap(adamw.init), params_stacked_specs)


def outer_state_specs(cfg: ModelConfig, n_clusters: int,
                      ccfg: mc.MeshCompressionConfig):
    p = params_specs(cfg)
    return jax.eval_shape(
        lambda pp: init_outer_state(pp, n_clusters, ccfg), p)
