"""Virtual decentralized-cluster simulator entrypoint.

Replays DiLoCoX outer rounds over N simulated clusters on modeled WAN
links, with injectable faults, and prints the event timeline:

  # 4 clusters, 1 Gbps, cluster 1 straggling 3x for rounds 5-10,
  # cluster 2 leaves at round 8 and rejoins at round 14:
  python -m repro.launch.sim --clusters 4 --rounds 20 --h-steps 30 \
      --straggler 1:5:10:3 --leave 2:8 --join 2:14

  # same faults, but actually TRAIN through them (tiny quadratic problem
  # running the real core/diloco.py round loop):
  python -m repro.launch.sim ... --numeric

  # the paper's Fig. 4 method comparison under this link/fault profile:
  python -m repro.launch.sim --clusters 2 --h-steps 125 --rounds 4 \
      --params 107e9 --t-step 10.3 --rank 2048 --compare

Fault grammar (repeatable flags):
  --straggler C:START:END:SLOWDOWN      step time x SLOWDOWN on cluster C
  --degrade START:END:FACTOR[:C]        bandwidth x FACTOR (all links or C)
  --leave C:ROUND / --join C:ROUND      membership churn
"""
from __future__ import annotations

import argparse
import json


def parse_faults(args, ap):
    from repro.sim import (FaultSchedule, Join, Leave, LinkDegradation,
                           Straggler)
    ev = []
    try:
        for s in args.straggler or []:
            c, a, b, x = s.split(":")
            ev.append(Straggler(int(c), int(a), int(b), float(x)))
        for s in args.degrade or []:
            parts = s.split(":")
            a, b, f = int(parts[0]), int(parts[1]), float(parts[2])
            c = int(parts[3]) if len(parts) > 3 else None
            ev.append(LinkDegradation(a, b, f, c))
        for s in args.leave or []:
            c, r = s.split(":")
            ev.append(Leave(int(c), int(r)))
        for s in args.join or []:
            c, r = s.split(":")
            ev.append(Join(int(c), int(r)))
    except ValueError as e:
        ap.error(f"bad fault spec ({e}); grammar: --straggler C:START:END:X"
                 "  --degrade START:END:F[:C]  --leave C:R  --join C:R")
    for e in ev:
        if getattr(e, "cluster", None) is not None and \
                not (0 <= e.cluster < args.clusters):
            ap.error(f"fault names cluster {e.cluster} but --clusters is "
                     f"{args.clusters}")
    return FaultSchedule(tuple(ev))


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--h-steps", type=int, default=30)
    ap.add_argument("--t-step", type=float, default=1.0,
                    help="local step seconds (paper §2.4.1: 1.0)")
    ap.add_argument("--gbps", type=float, default=1.0,
                    help="link bandwidth in Gbps")
    ap.add_argument("--latency-ms", type=float, default=0.0,
                    help="per-hop latency")
    ap.add_argument("--jitter", type=float, default=0.0,
                    help="fractional sigma of step/bandwidth noise")
    ap.add_argument("--params", type=float, default=1e9,
                    help="model size the wire accounting models (e.g. 107e9)")
    ap.add_argument("--compressor", default="diloco_x",
                    choices=["identity", "fp16", "quant", "diloco_x",
                             "topk", "random_sparse", "cocktail"])
    ap.add_argument("--rank", type=int, default=64)
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the §2.3 one-step-delay overlap")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler", action="append", metavar="C:START:END:X")
    ap.add_argument("--degrade", action="append", metavar="START:END:F[:C]")
    ap.add_argument("--leave", action="append", metavar="C:ROUND")
    ap.add_argument("--join", action="append", metavar="C:ROUND")
    ap.add_argument("--numeric", action="store_true",
                    help="run the real diloco_round per simulated round "
                         "(tiny quadratic problem) and record losses")
    ap.add_argument("--compare", action="store_true",
                    help="run the Fig. 4 method comparison on this scenario")
    ap.add_argument("--json", default="",
                    help="also dump the timeline JSON to this path")
    args = ap.parse_args()

    from repro.sim import (LinkProfile, Scenario, compare_methods,
                           make_quadratic_problem, simulate)

    kw = {"rank": args.rank} if args.compressor in ("diloco_x",) else {}
    sc = Scenario(
        n_clusters=args.clusters, rounds=args.rounds, h_steps=args.h_steps,
        t_step_s=args.t_step,
        link=LinkProfile(bytes_per_s=args.gbps * 0.125e9,
                         latency_s=args.latency_ms * 1e-3,
                         jitter=args.jitter),
        faults=parse_faults(args, ap), compressor=args.compressor,
        compressor_kw=kw, delay=not args.no_overlap,
        n_params=args.params, seed=args.seed)

    if args.compare:
        cmp = compare_methods(sc, rank=args.rank)
        print(f"{'method':>12} {'tokens_per_s':>14} {'x_vs_allreduce':>15}")
        for name, tps in cmp["tokens_per_s"].items():
            print(f"{name:>12} {tps:>14.1f} "
                  f"{cmp['speedup_vs_allreduce'][name]:>15.1f}")
        if args.json:
            blob = {k: tl.to_dict() for k, tl in cmp["timelines"].items()}
            with open(args.json, "w") as f:
                json.dump(blob, f, indent=1)
            print(f"wrote {args.json}")
        return

    numeric = None
    if args.numeric:
        numeric = make_quadratic_problem(args.clusters,
                                         h_steps=args.h_steps,
                                         seed=args.seed)
    tl = simulate(sc, numeric=numeric)
    print(tl.table())
    print(f"timeline fingerprint: {tl.fingerprint()[:16]}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(tl.to_dict(), f, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
