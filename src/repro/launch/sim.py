"""Virtual decentralized-cluster simulator entrypoint.

Replays DiLoCoX outer rounds over N simulated clusters on modeled WAN
links, with injectable faults, and prints the event timeline:

  # 4 clusters, 1 Gbps, cluster 1 straggling 3x for rounds 5-10,
  # cluster 2 leaves at round 8 and rejoins at round 14:
  python -m repro.launch.sim --clusters 4 --rounds 20 --h-steps 30 \
      --straggler 1:5:10:3 --leave 2:8 --join 2:14

  # same faults, but actually TRAIN through them (tiny quadratic problem
  # running the real core/diloco.py round loop):
  python -m repro.launch.sim ... --numeric

  # the paper's Fig. 4 method comparison under this link/fault profile:
  python -m repro.launch.sim --clusters 2 --h-steps 125 --rounds 4 \
      --params 107e9 --t-step 10.3 --rank 2048 --compare

  # REAL processes + rate-limited sockets (repro.sim.proc): one OS process
  # per cluster, straggler sleeps / token-bucket throttling / kill+respawn
  # enforced by the transport; defaults scale down to wall-clock seconds
  # and, with no fault flags, inject a demo straggler + leave/join:
  python -m repro.launch.sim --backend proc --clusters 2

  # ... and assert it against the in-process backend: per-round outer
  # params bit-for-bit, measured vs modeled timeline within tolerance:
  python -m repro.launch.sim --backend proc --clusters 2 --check-equivalence

  # NON-HUB outer sync: ring gossip — each cluster mixes compressed
  # pseudo-gradients with its graph neighbors only (NoLoCo-style).  On the
  # proc backend the payloads move over direct worker<->worker p2p links;
  # the coordinator only orchestrates membership/faults:
  python -m repro.launch.sim --backend proc --clusters 4 --topology ring \
      --check-equivalence

  # §2.4 ADAPTIVE compression (spectral | bandwidth | hybrid): the
  # controller anneals the per-round rank from the pseudo-gradient
  # spectrum and/or the measured link; on the proc backend the decision is
  # broadcast in the round header and the equivalence gate also asserts
  # identical rank schedules:
  python -m repro.launch.sim --backend proc --clusters 2 --adaptive hybrid \
      --degrade 2:4:0.25:1 --check-equivalence

  # HETEROGENEOUS local-step scheduling: --h-policy balance sets each
  # cluster's per-round H from its modeled step time (slow sites do fewer
  # local steps, so fast ones stop idling at the barrier); the per-cluster
  # H schedule is broadcast in the proc round header and gated bit-for-bit
  # by the equivalence harness:
  python -m repro.launch.sim --backend proc --clusters 3 \
      --h-policy balance --straggler 1:1:4:3 --check-equivalence

Fault grammar (repeatable flags):
  --straggler C:START:END:SLOWDOWN      step time x SLOWDOWN on cluster C
  --degrade START:END:FACTOR[:C]        bandwidth x FACTOR (all links or C)
  --leave C:ROUND / --join C:ROUND      membership churn
"""
from __future__ import annotations

import argparse
import json
import sys

# per-backend defaults: the model backend replays the paper's operating
# point (simulated seconds are free); the proc backend runs real wall-clock
# processes, so it defaults to a seconds-scale scenario that still exposes
# every behavior (straggler barrier, throttled link, churn).
_DEFAULTS = {
    "model": dict(rounds=20, h_steps=30, t_step=1.0, gbps=1.0,
                  params=1e9, rank=64),
    "proc": dict(rounds=6, h_steps=4, t_step=0.05, gbps=4e-4,
                 params=2e5, rank=8),
}


def parse_faults(args, ap):
    from repro.sim import (FaultSchedule, Join, Leave, LinkDegradation,
                           Straggler)
    ev = []
    try:
        for s in args.straggler or []:
            c, a, b, x = s.split(":")
            ev.append(Straggler(int(c), int(a), int(b), float(x)))
        for s in args.degrade or []:
            parts = s.split(":")
            a, b, f = int(parts[0]), int(parts[1]), float(parts[2])
            c = int(parts[3]) if len(parts) > 3 else None
            ev.append(LinkDegradation(a, b, f, c))
        for s in args.leave or []:
            c, r = s.split(":")
            ev.append(Leave(int(c), int(r)))
        for s in args.join or []:
            c, r = s.split(":")
            ev.append(Join(int(c), int(r)))
    except ValueError as e:
        ap.error(f"bad fault spec ({e}); grammar: --straggler C:START:END:X"
                 "  --degrade START:END:F[:C]  --leave C:R  --join C:R")
    for e in ev:
        if getattr(e, "cluster", None) is not None and \
                not (0 <= e.cluster < args.clusters):
            ap.error(f"fault names cluster {e.cluster} but --clusters is "
                     f"{args.clusters}")
    return FaultSchedule(tuple(ev))


def emit_obs(args, tl, modeled=None) -> None:
    """Overlap-ledger summary + optional trace / metrics exports for a
    finished run (either backend).  Strictly read-only consumers of the
    timeline — nothing here can perturb the round math."""
    from repro.obs import (MetricsRegistry, OverlapLedger, get_logger,
                           ledger as obs_ledger, trace as obs_trace)
    log = get_logger("launch.sim")

    led = OverlapLedger.from_timeline(tl)
    log.info(led.summary(), **led.to_dict()["summary"])
    if modeled is not None:
        d = obs_ledger.drift(tl, modeled)
        log.info(f"modeled-vs-measured drift: {d['final_drift_s']:+.3f}s "
                 f"({100 * d['final_drift_frac']:+.1f}%) over "
                 f"{len(d['per_round_s'])} rounds",
                 final_drift_s=d["final_drift_s"],
                 final_drift_frac=d["final_drift_frac"],
                 cumulative_s=d["cumulative_s"])
    if args.trace:
        trace = obs_trace.timeline_trace(tl)
        errs = obs_trace.validate_chrome_trace(trace)
        if errs:    # the exporter must never emit an invalid trace
            log.warning(f"trace failed its own schema check: {errs[:3]}")
        obs_trace.save(trace, args.trace)
        log.info(f"wrote {args.trace} (trace fingerprint "
                 f"{obs_trace.trace_fingerprint(trace)[:16]})")
    if args.metrics_out:
        reg = MetricsRegistry(run_meta=tl.scenario)
        reg.observe_timeline(tl)
        reg.write_jsonl(args.metrics_out + ".jsonl")
        reg.write_prometheus(args.metrics_out + ".prom")
        log.info(f"wrote {args.metrics_out}.jsonl and "
                 f"{args.metrics_out}.prom")


def run_proc_cli(args, sc) -> None:
    """Drive the multi-process backend (real sockets, token-bucket links)."""
    from repro.obs import get_logger
    from repro.sim import QuadraticSpec
    from repro.sim.proc import check_equivalence, run_proc
    from repro.sim.proc.equivalence import format_report

    log = get_logger("launch.sim")
    spec = None
    if not args.timing_only:
        spec = QuadraticSpec(n_clusters=args.clusters, d=args.problem_d,
                             n_mats=2, h_steps=args.h_steps, seed=args.seed)

    if args.check_equivalence:
        report = check_equivalence(sc, spec)
        log.info(format_report(report))
        timelines = report.pop("timelines")
        emit_obs(args, timelines["proc"], modeled=timelines["model"])
        log.info("proc structural fingerprint: "
                 f"{report['proc_fingerprint']}",
                 fingerprint=report["proc_fingerprint"])
        if args.json:
            blob = {"report": report,
                    "proc": timelines["proc"].to_dict(),
                    "model": timelines["model"].to_dict()}
            with open(args.json, "w") as f:
                json.dump(blob, f, indent=1)
            log.info(f"wrote {args.json}")
        if not report["ok"]:
            sys.exit(1)
        return

    tl = run_proc(sc, spec)
    log.info(tl.table())
    emit_obs(args, tl)
    log.info(f"proc structural fingerprint: {tl.structural_fingerprint()}",
             fingerprint=tl.structural_fingerprint())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(tl.to_dict(), f, indent=1)
        log.info(f"wrote {args.json}")


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--backend", choices=["model", "proc"], default="model",
                    help="model: in-process clock-model replay; proc: real "
                         "OS processes + rate-limited localhost sockets")
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--h-steps", type=int, default=None)
    ap.add_argument("--t-step", type=float, default=None,
                    help="local step seconds (paper §2.4.1: 1.0)")
    ap.add_argument("--gbps", type=float, default=None,
                    help="link bandwidth in Gbps")
    ap.add_argument("--latency-ms", type=float, default=0.0,
                    help="per-hop latency")
    ap.add_argument("--jitter", type=float, default=0.0,
                    help="fractional sigma of step/bandwidth noise")
    ap.add_argument("--params", type=float, default=None,
                    help="model size the wire accounting models (e.g. 107e9)")
    ap.add_argument("--compressor", default="diloco_x",
                    choices=["identity", "fp16", "quant", "diloco_x",
                             "topk", "random_sparse", "cocktail"])
    ap.add_argument("--rank", type=int, default=None)
    ap.add_argument("--adaptive", default="off",
                    choices=["off", "spectral", "bandwidth", "hybrid"],
                    help="§2.4 adaptive compression controller: spectral = "
                         "Alg. 3 rank annealing from the pseudo-gradient "
                         "spectrum; bandwidth = largest rank whose outer "
                         "sync fits the overlap budget on the measured "
                         "link; hybrid = min of both.  Under gossip "
                         "topologies the rank is per-EDGE (a degraded "
                         "uplink compresses harder on its own edges only). "
                         "Works on both backends; the rank schedule is "
                         "covered by the equivalence gate")
    ap.add_argument("--adaptive-window", type=int, default=3,
                    help="Alg. 3 window c (spectral warm-up rounds)")
    ap.add_argument("--adaptive-rmin", type=int, default=2,
                    help="adaptive rank floor r_min")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the §2.3 one-step-delay overlap")
    ap.add_argument("--h-policy", default="global",
                    choices=["global", "balance"],
                    help="per-cluster local-step scheduling: global = the "
                         "paper's uniform H (every cluster runs --h-steps; "
                         "fast sites idle at the barrier); balance = each "
                         "cluster's H follows its modeled step time so all "
                         "clusters land near the barrier together (slow "
                         "sites do fewer local steps), clamped under "
                         "gossip by the mixing matrix's spectral gap.  "
                         "Works on both backends; the H schedule is "
                         "covered by the equivalence gate")
    ap.add_argument("--h-min", type=int, default=1,
                    help="balance policy: per-cluster local-step floor")
    ap.add_argument("--topology-seeds", default="",
                    help="comma-separated per-round seed schedule for the "
                         "random topology: round r draws a FRESH k-regular "
                         "graph from seeds[r %% len] (NoLoCo-style fresh "
                         "partners; model backend only)")
    ap.add_argument("--topology", default="star",
                    choices=["ring", "torus", "random", "star", "full"],
                    help="outer-sync pattern: star/full = exact global "
                         "average (hub/all-gather, the paper's setting); "
                         "ring/torus/random = neighbor gossip mixing")
    ap.add_argument("--topology-degree", type=int, default=0,
                    help="random topology: k of the k-regular graph "
                         "(0 = auto)")
    ap.add_argument("--sync", default="barrier",
                    choices=["barrier", "bounded_stale"],
                    help="outer-sync policy: barrier = lockstep rounds "
                         "(the paper's setting); bounded_stale = "
                         "event-driven async rounds on per-cluster clocks "
                         "(no delta older than --max-staleness mixed in)")
    ap.add_argument("--max-staleness", type=int, default=2,
                    help="bounded_stale: staleness bound in rounds "
                         "(0 = barrier cadence on local clocks)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timing-only", action="store_true",
                    help="proc backend: workers skip jax (membership/"
                         "transport/timing only)")
    ap.add_argument("--no-faults", action="store_true",
                    help="proc backend: suppress the default demo "
                         "straggler + leave/join when no fault flag given")
    ap.add_argument("--check-equivalence", action="store_true",
                    help="proc backend: also run the in-process simulator "
                         "and assert bit-for-bit outer state + timing "
                         "tolerance (exit 1 on mismatch)")
    ap.add_argument("--problem-d", type=int, default=8,
                    help="proc backend: quadratic problem matrix dim")
    ap.add_argument("--straggler", action="append", metavar="C:START:END:X")
    ap.add_argument("--degrade", action="append", metavar="START:END:F[:C]")
    ap.add_argument("--leave", action="append", metavar="C:ROUND")
    ap.add_argument("--join", action="append", metavar="C:ROUND")
    ap.add_argument("--numeric", action="store_true",
                    help="run the real diloco_round per simulated round "
                         "(tiny quadratic problem) and record losses")
    ap.add_argument("--compare", action="store_true",
                    help="run the Fig. 4 method comparison on this scenario")
    ap.add_argument("--json", default="",
                    help="also dump the timeline JSON to this path")
    ap.add_argument("--trace", default="",
                    help="write the per-round phase spans as Chrome-trace-"
                         "event JSON (load in chrome://tracing or "
                         "ui.perfetto.dev); modeled spans on the model "
                         "backend, measured wall clock on proc")
    ap.add_argument("--metrics-out", default="",
                    help="metrics export prefix: writes PREFIX.jsonl (one "
                         "record per round) and PREFIX.prom (Prometheus "
                         "text exposition)")
    ap.add_argument("--log-json", action="store_true",
                    help="also emit machine-readable JSON log lines on "
                         "stderr (stdout output is unchanged)")
    args = ap.parse_args()
    for k, v in _DEFAULTS[args.backend].items():
        if getattr(args, k) is None:
            setattr(args, k, v)

    # human-readable lines go to stdout exactly as the old print()s did
    # (CI greps the fingerprint line there); --log-json adds structured
    # JSON records on stderr
    from repro.obs import configure_logging, get_logger
    configure_logging(stream=sys.stdout,
                      json_stream=(sys.stderr if args.log_json else None))
    log = get_logger("launch.sim")

    from repro.sim import (FaultSchedule, Join, Leave, LinkProfile,
                           Scenario, Straggler, compare_methods,
                           make_quadratic_problem, simulate)

    faults = parse_faults(args, ap)
    if (args.backend == "proc" and not faults.events and not args.no_faults
            and args.clusters >= 2 and args.rounds >= 4):
        # the proc backend exists to exercise faults through the transport;
        # default to a demo straggler + leave/join unless told otherwise
        faults = FaultSchedule((
            Straggler(1, 1, min(3, args.rounds - 1), 2.5),
            Leave(1, args.rounds // 2), Join(1, args.rounds - 1)))
        log.info(f"(no fault flags: demo faults "
                 f"{[e.describe() for e in faults.events]}; --no-faults to "
                 f"disable)")

    adaptive_spec = None
    if args.adaptive != "off":
        if args.compressor != "diloco_x":
            ap.error("--adaptive anneals the low-rank stage; it needs "
                     "--compressor diloco_x")
        from repro.core.adaptive import AdaptiveSpec
        adaptive_spec = AdaptiveSpec(
            mode=args.adaptive, window=args.adaptive_window,
            r1=args.rank, h1=args.h_steps, r_min=args.adaptive_rmin)
        if (args.backend == "model" and adaptive_spec.needs_spectral
                and not args.numeric):
            log.info(f"(--adaptive {args.adaptive} needs the realized "
                     "pseudo-gradient spectrum: enabling --numeric)")
            args.numeric = True
        if (args.backend == "proc" and adaptive_spec.needs_spectral
                and args.timing_only):
            ap.error(f"--adaptive {args.adaptive} needs numeric workers "
                     "for the spectral rank signal; drop --timing-only or "
                     "use --adaptive bandwidth")

    h_spec = None
    if args.h_policy != "global":
        from repro.core.adaptive import HSpec
        h_spec = HSpec(policy=args.h_policy, h_min=args.h_min)

    topo_seeds = None
    if args.topology_seeds:
        if args.topology != "random":
            ap.error("--topology-seeds redraws the random k-regular graph "
                     "per round; it needs --topology random")
        topo_seeds = tuple(int(s) for s in args.topology_seeds.split(","))

    if args.sync == "bounded_stale":
        if args.check_equivalence:
            ap.error("--check-equivalence compares modeled vs wall-clock "
                     "round timing, which async workers (run flat-out) "
                     "don't expose; the bounded_stale cross-backend gate "
                     "is the structural-fingerprint + param-hash test in "
                     "tests/test_sim_proc.py")
        if args.compare:
            ap.error("--compare replays the paper's barrier methods; "
                     "drop --sync bounded_stale")
        if args.adaptive != "off" or args.h_policy != "global":
            ap.error("--sync bounded_stale has no controller step "
                     "(no global round to decide at); drop --adaptive/"
                     "--h-policy")
        if topo_seeds is not None:
            ap.error("--sync bounded_stale gates on a fixed peer set; "
                     "drop --topology-seeds")

    kw = {"rank": args.rank} if args.compressor in ("diloco_x",) else {}
    if args.backend == "proc" and args.compressor == "diloco_x":
        # the numeric problem tree is problem_d x problem_d; let the
        # low-rank stage engage on it
        kw["min_dim_for_lowrank"] = min(8, args.problem_d)
    sc = Scenario(
        n_clusters=args.clusters, rounds=args.rounds, h_steps=args.h_steps,
        t_step_s=args.t_step,
        link=LinkProfile(bytes_per_s=args.gbps * 0.125e9,
                         latency_s=args.latency_ms * 1e-3,
                         jitter=args.jitter),
        faults=faults, compressor=args.compressor,
        compressor_kw=kw, delay=not args.no_overlap,
        rank=(args.rank if args.compressor == "diloco_x" else None),
        adaptive=adaptive_spec, h_spec=h_spec,
        topology=args.topology, topology_degree=args.topology_degree,
        topology_seed=args.seed, topology_seed_schedule=topo_seeds,
        sync=args.sync, max_staleness=args.max_staleness,
        n_params=args.params, seed=args.seed)

    if args.backend == "proc":
        run_proc_cli(args, sc)
        return

    if args.compare:
        if args.topology not in ("star", "full"):
            ap.error("--compare replays the paper's hub-based methods; "
                     "use benchmarks/gossip_vs_gather.py for the "
                     "gossip-vs-gather comparison")
        cmp = compare_methods(sc, rank=args.rank)
        log.info(f"{'method':>12} {'tokens_per_s':>14} "
                 f"{'x_vs_allreduce':>15}")
        for name, tps in cmp["tokens_per_s"].items():
            log.info(f"{name:>12} {tps:>14.1f} "
                     f"{cmp['speedup_vs_allreduce'][name]:>15.1f}",
                     method=name, tokens_per_s=tps,
                     x_vs_allreduce=cmp["speedup_vs_allreduce"][name])
        if args.json:
            blob = {k: tl.to_dict() for k, tl in cmp["timelines"].items()}
            with open(args.json, "w") as f:
                json.dump(blob, f, indent=1)
            log.info(f"wrote {args.json}")
        return

    numeric = None
    if args.numeric:
        numeric = make_quadratic_problem(args.clusters,
                                         h_steps=args.h_steps,
                                         seed=args.seed)
    tl = simulate(sc, numeric=numeric)
    log.info(tl.table())
    emit_obs(args, tl)
    log.info(f"timeline fingerprint: {tl.fingerprint()[:16]}",
             fingerprint=tl.fingerprint())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(tl.to_dict(), f, indent=1)
        log.info(f"wrote {args.json}")


if __name__ == "__main__":
    main()
