"""Production meshes.

``make_production_mesh`` is exactly the spec'd function (a FUNCTION, not a
module-level constant — importing this module never touches jax device
state). ``make_cluster_mesh`` derives the DiLoCoX view of the same devices:
a leading "clusters" axis (the 1 Gbps decentralized boundary — the pod axis
when multi-pod, a split of the data axis when single-pod) plus the intra-
cluster ("data", "model") axes.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cluster_mesh(base: Mesh, n_clusters: Optional[int] = None) -> Mesh:
    """DiLoCoX view: ("clusters", "data", "model").

    multi-pod base (pod, data, model): clusters = pods (slow links cross
    pods only). single-pod base (data, model): the data axis is split into
    (clusters, data) — by default 2 clusters x 8-way intra-cluster DP,
    matching the paper's several-clusters-per-site topology.
    """
    devs = base.devices
    if devs.ndim == 3:              # multi-pod
        if n_clusters not in (None, devs.shape[0]):
            raise ValueError("multi-pod clusters == pods")
        return Mesh(devs, ("clusters", "data", "model"))
    n_clusters = n_clusters or 2
    d_total, m = devs.shape
    if d_total % n_clusters:
        raise ValueError(f"data axis {d_total} not divisible by "
                         f"{n_clusters} clusters")
    reshaped = devs.reshape(n_clusters, d_total // n_clusters, m)
    return Mesh(reshaped, ("clusters", "data", "model"))


def make_serving_mesh(base: Mesh) -> Mesh:
    """Serving has no cluster boundary: flatten pods into the batch axis."""
    devs = base.devices
    if devs.ndim == 3:
        p, d, m = devs.shape
        return Mesh(devs.reshape(p * d, m), ("data", "model"))
    return Mesh(devs, ("data", "model"))


def describe(mesh: Mesh) -> str:
    return f"{dict(zip(mesh.axis_names, mesh.devices.shape))}"
