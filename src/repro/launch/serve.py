"""Batched serving driver: prefill-by-decode + greedy generation loop on a
host-device mesh, using the same serve_step the dry-run lowers.

  python -m repro.launch.serve --arch gemma3-1b --smoke --devices 4 \
      --batch 4 --prompt-len 16 --gen-len 16
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.configs.base import get_config
    from repro.launch import steps
    from repro.models import model as M
    from repro.parallel import sharding as sh

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    mesh = jax.make_mesh((args.data, args.model), ("data", "model"))
    M.set_activation_sharder(sh.make_activation_sharder(mesh))

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    s_max = args.prompt_len + args.gen_len
    state = M.init_decode_state(cfg, args.batch, s_max)
    if cfg.is_encdec:
        fe = jax.random.normal(jax.random.PRNGKey(7),
                               (args.batch, cfg.n_frontend_tokens,
                                cfg.d_model)) * 0.02
        mem = M.prefill_encoder(params, cfg, fe)
        state = M.fill_cross_caches(params, cfg, state, mem)

    serve_step = jax.jit(steps.make_serve_step(cfg))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    # prefill by decode (correct for every family incl. SSM state)
    tok = prompt[:, :1]
    t0 = time.time()
    for t in range(args.prompt_len):
        nxt, state = serve_step(params, state, prompt[:, t:t + 1])
    generated = [int(x) for x in np.asarray(nxt[:, 0])]
    outs = [nxt]
    for t in range(args.gen_len - 1):
        nxt, state = serve_step(params, state, nxt)
        outs.append(nxt)
    gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
    dt = time.time() - t0
    toks = args.batch * (args.prompt_len + args.gen_len - 1)
    print(f"generated shape {gen.shape}; {toks / dt:.1f} tok/s "
          f"({dt:.2f}s total)")
    print("sample:", gen[0][:12].tolist())
    print("SERVE-DRIVER-OK")


if __name__ == "__main__":
    main()
