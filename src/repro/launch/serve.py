"""Serving driver: dense greedy loop (legacy, every family) or the paged
continuous-batching engine (``--paged``; dense/moe GQA stacks), loading
DiLoCoX-trainer checkpoints via ``repro.checkpoint``.

  python -m repro.launch.serve --arch gemma3-1b --smoke --devices 4 \
      --batch 4 --prompt-len 16 --gen-len 16 [--paged] [--ckpt DIR|PATH]

Throughput is reported per phase — prefill tok/s (prompt tokens absorbed
into the cache) and decode tok/s (tokens actually generated) — plus the
combined line CI greps. EOS handling: generation stops early once every
sequence has emitted ``cfg.eos_id`` (override with ``--eos``, disable
with ``--eos -1``), and post-EOS positions are masked to the EOS id in
the sample output.
"""
import argparse
import contextlib
import os
import sys


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--ckpt", default="",
                    help="checkpoint path (or dir: latest) from "
                         "launch/train.py --ckpt-dir; both the unstacked "
                         "and cluster-stacked params layouts load")
    ap.add_argument("--eos", type=int, default=None,
                    help="EOS token id (default: cfg.eos_id; -1 disables)")
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--paged", action="store_true",
                   help="serve on the paged continuous-batching engine")
    g.add_argument("--dense", action="store_true",
                   help="legacy fixed-batch dense loop (the default)")
    ap.add_argument("--requests", type=int, default=0,
                    help="paged: number of requests (default: --batch)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="paged: physical page pool size (default: "
                         "batch * pages-per-seq, i.e. dense-equivalent)")
    ap.add_argument("--policy", default="continuous",
                    choices=["continuous", "static"])
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--trace", default="",
                    help="write a Chrome-trace of the serve phases here")
    ap.add_argument("--metrics-out", default="",
                    help="write repro_serve_* metrics (Prometheus text)")
    ap.add_argument("--log-json", action="store_true")
    return ap.parse_args()


def _load_params(path, params_like, log):
    """Restore the ``{"params": ...}`` tree saved by launch/train.py.
    Accepts the pp path (unstacked) and the GSPMD path (cluster-stacked:
    every row is identical post-round, row 0 is taken)."""
    import json

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import checkpoint as ckpt_lib

    if os.path.isdir(path):
        found = ckpt_lib.latest(path)
        if found is None:
            raise FileNotFoundError(f"no checkpoints under {path!r}")
        path = found
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_like)
    with np.load(path + ".npz") as data:
        leaves = []
        for p, ref in flat:
            key = "['params']" + jax.tree_util.keystr(p)
            arr = data[key]
            if arr.shape != tuple(ref.shape):
                if arr.shape[1:] == tuple(ref.shape):
                    arr = arr[0]          # cluster-stacked -> row 0
                else:
                    raise ValueError(f"{key}: checkpoint shape {arr.shape} "
                                     f"vs model {tuple(ref.shape)}")
            leaves.append(jnp.asarray(arr).astype(ref.dtype))
    with open(path + ".json") as f:
        step = json.load(f)["step"]
    log.info(f"restored params from {path} (round {step})")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def main() -> None:
    args = _parse_args()
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import get_config
    from repro.launch import steps
    from repro.models import model as M
    from repro.obs import (MetricsRegistry, Tracer, configure_logging,
                           get_logger)
    from repro.parallel import sharding as sh

    configure_logging(stream=sys.stdout,
                      json_stream=(sys.stderr if args.log_json else None))
    log = get_logger("launch.serve")
    tracer = Tracer("serve-driver") if args.trace else None
    if tracer is not None:
        span = tracer.span
    else:
        def span(name, **kw):
            return contextlib.nullcontext()
    metrics = MetricsRegistry() if args.metrics_out else None

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    eos = cfg.eos_id if args.eos is None else (
        None if args.eos < 0 else args.eos)
    mesh = jax.make_mesh((args.data, args.model), ("data", "model"))
    M.set_activation_sharder(sh.make_activation_sharder(mesh))

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        params = _load_params(args.ckpt, params, log)

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (max(args.batch, args.requests or 0),
                                 args.prompt_len), 0, cfg.vocab_size)

    if args.paged:
        _run_paged(args, cfg, params, np.asarray(prompt), eos, log, span,
                   metrics)
    else:
        _run_dense(args, cfg, params, prompt, eos, log, span, metrics)

    if tracer is not None:
        tracer.write(args.trace)
        log.info(f"wrote {args.trace}")
    if metrics is not None:
        metrics.write_prometheus(args.metrics_out)
        log.info(f"wrote {args.metrics_out}")


def _run_dense(args, cfg, params, prompt, eos, log, span, metrics) -> None:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch import steps
    from repro.models import model as M

    B = args.batch
    prompt = prompt[:B]
    s_max = args.prompt_len + args.gen_len
    state = M.init_decode_state(cfg, B, s_max)
    if cfg.is_encdec:
        fe = jax.random.normal(jax.random.PRNGKey(7),
                               (B, cfg.n_frontend_tokens,
                                cfg.d_model)) * 0.02
        mem = M.prefill_encoder(params, cfg, fe)
        state = M.fill_cross_caches(params, cfg, state, mem)

    serve_step = jax.jit(steps.make_serve_step(cfg, eos_id=eos))
    finished = jnp.zeros((B,), bool)

    def call(tokens):
        nonlocal state, finished
        if eos is None:
            nxt, state = serve_step(params, state, tokens)
        else:
            nxt, state, finished = serve_step(params, state, tokens,
                                              finished)
        return nxt

    t0 = time.time()
    with span("prefill", tokens=B * args.prompt_len):
        for t in range(args.prompt_len):
            nxt = call(prompt[:, t:t + 1])
            if eos is not None and t < args.prompt_len - 1:
                finished = jnp.zeros((B,), bool)  # prompt-forced outputs
    t1 = time.time()
    outs = [nxt]
    with span("decode"):
        for t in range(args.gen_len - 1):
            if eos is not None and bool(finished.all()):
                log.info(f"all sequences hit EOS after {t + 1} tokens")
                break
            nxt = call(nxt)
            outs.append(nxt)
    gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
    t2 = time.time()

    # prefill absorbs prompt tokens; decode generates gen.shape[1] tokens
    # per row, the first of which came out of the last prefill step
    prefill_toks = B * args.prompt_len
    decode_toks = B * (gen.shape[1] - 1)
    print(f"prefill: {prefill_toks / max(t1 - t0, 1e-9):.1f} tok/s "
          f"({prefill_toks} tokens, {t1 - t0:.2f}s)")
    print(f"decode: {decode_toks / max(t2 - t1, 1e-9):.1f} tok/s "
          f"({decode_toks} tokens, {t2 - t1:.2f}s)")
    print(f"generated shape {gen.shape}; "
          f"{(prefill_toks + decode_toks) / max(t2 - t0, 1e-9):.1f} tok/s "
          f"({t2 - t0:.2f}s total)")
    print("sample:", gen[0][:12].tolist())
    if metrics is not None:
        metrics.counter("repro_serve_prefill_tokens").inc(prefill_toks)
        metrics.counter("repro_serve_decode_tokens").inc(decode_toks)
    print("SERVE-DRIVER-OK")


def _run_paged(args, cfg, params, prompts, eos, log, span, metrics) -> None:
    from repro.serve.engine import ServeEngine, supports_paged

    ok, why = supports_paged(cfg)
    if not ok:
        print(f"SERVE-DRIVER-UNSUPPORTED: {args.arch}: {why}")
        sys.exit(2)

    ps = args.page_size
    max_new = args.gen_len
    max_pages = -(-(args.prompt_len + max_new) // ps)
    n_pages = args.pool_pages or args.batch * max_pages
    engine = ServeEngine(params, cfg, max_seqs=args.batch, page_size=ps,
                         n_pages=n_pages, max_pages_per_seq=max_pages,
                         backend=args.backend, eos_id=eos,
                         policy=args.policy, metrics=metrics, span=span)
    n_req = args.requests or args.batch
    for r in range(n_req):
        engine.submit(prompts[r].tolist(), max_new, arrival=0)
    st = engine.run()

    print(f"paged engine: {st['requests_done']} requests in {st['steps']} "
          f"steps ({args.policy}, backend={args.backend}, "
          f"pool={n_pages}x{ps} pages)")
    print(f"prefill: {st['prefill_tok_s']:.1f} tok/s "
          f"({st['prefill_tokens']} tokens)")
    print(f"decode: {st['decode_tok_s']:.1f} tok/s "
          f"({st['decode_tokens']} tokens, "
          f"{st['decode_tok_per_step']:.2f} tok/step)")
    total = st["prefill_tokens"] + st["decode_tokens"]
    print(f"generated shape ({st['requests_done']}, {max_new}); "
          f"{total / max(st['wall_s'], 1e-9):.1f} tok/s "
          f"({st['wall_s']:.2f}s total)")
    print(f"ttft p50/p99: {st['ttft_steps_p50']:.0f}/"
          f"{st['ttft_steps_p99']:.0f} steps; per-token p50/p99: "
          f"{st['per_token_ms_p50']:.2f}/{st['per_token_ms_p99']:.2f} ms")
    print(f"kv bytes: pool {st['kv_pool_bytes']} (peak resident "
          f"{st['kv_peak_bytes']}) vs dense {st['dense_equiv_bytes']}")
    done = sorted(engine.sched.done, key=lambda r: r.rid)
    print("sample:", done[0].generated[:12] if done else [])
    print(f"admission fingerprint: {st['admission_fingerprint']}")
    print("SERVE-DRIVER-OK")


if __name__ == "__main__":
    main()
