"""End-to-end DiLoCoX training driver on a (small, CPU-hostable) mesh.

This is the *executable* counterpart of the dry-run: the same mesh-level
step functions (launch/steps.py), run for real on
``--devices`` host devices, with the full DiLoCoX round structure:

    for outer step t:  H x train_step (per-cluster, vmapped)
                       outer_step     (compress -> gather -> Nesterov,
                                       one-step-delay semantics)
                       AdaGradCmp     (Alg. 3 host-side controller)

Usage (8 simulated devices, 2 clusters x 2 data x 2 model):
  python -m repro.launch.train --arch granite-3-8b --smoke \
      --devices 8 --clusters 2 --rounds 8 --h-steps 10

``--inner pp`` switches the inner loop to the sharded pipeline-parallel
engine (parallel/inner_engine.py): the mesh becomes
(clusters, data, --pp-stages), every cluster's H AdamW steps run through
the shard_map GPipe loss, the whole round state lives in one
cluster-stacked ``DiLoCoTrainState`` placed by ``state_shardings``, and
the outer compress -> mean -> Nesterov round consumes the gathered delta
from ``extract_delta`` — the same code path the sim gates certify on the
unit mesh:
  python -m repro.launch.train --arch granite-3-8b --smoke \
      --inner pp --devices 8 --clusters 2 --data 2 --pp-stages 2
"""
import argparse
import contextlib
import dataclasses
import os
import sys


def _setup_obs(args):
    """Logger (stdout, byte-stable lines) + optional wall-clock tracer.
    Returns ``(log, tracer, span)`` where ``span`` is a no-op context
    factory when ``--trace`` is off."""
    from repro.obs import Tracer, configure_logging, get_logger
    configure_logging(stream=sys.stdout,
                      json_stream=(sys.stderr if args.log_json else None))
    log = get_logger("launch.train")
    tracer = Tracer("train-driver") if args.trace else None
    if tracer is not None:
        span = tracer.span
    else:
        def span(name, **kw):
            return contextlib.nullcontext()
    return log, tracer, span


def _finish_obs(args, log, tracer) -> None:
    if tracer is not None:
        tracer.write(args.trace)
        log.info(f"wrote {args.trace}")


def _run_pp(args) -> None:
    """DiLoCoX rounds with the pipeline-parallel inner engine on a
    cluster-stacked (clusters, data, model) mesh.

    Each cluster row holds its own full replica of the round state — local
    params, inner AdamW moments, outer Nesterov momentum, EF residual —
    exactly as the paper's decentralized clusters do (no parameter
    server); the outer rows stay identical because every cluster applies
    the same averaged delta.  The comm leg here runs sequentially after
    the inner steps (it's a driver, not the overlap-scheduled runtime),
    but the DELAYED round arithmetic matches ``core.diloco.diloco_round``:
    round t averages delta^{t-1} and the outer update lands on the
    anchor."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import get_config
    from repro.core import diloco
    from repro.core.compression import make_compressor, tree_shapes
    from repro.data.synthetic import SyntheticLM
    from repro.optim import adamw, nesterov
    from repro.parallel import inner_engine as IE
    from repro.parallel import pipeline as PP

    if args.adaptive or args.h_policy != "global":
        raise SystemExit("--inner pp supports the static round schedule "
                         "only (no --adaptive / --h-policy balance yet)")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    C = args.clusters
    assert C * args.data * args.pp_stages == args.devices, (
        "--devices must equal clusters * data * pp-stages")
    Bc = args.global_batch // C
    assert Bc % args.pp_micro == 0, (
        "per-cluster batch (global-batch/clusters) must divide --pp-micro")

    mesh = jax.make_mesh((C, args.data, args.pp_stages),
                         ("clusters", "data", "model"))
    pcfg = PP.PipelineConfig(n_stages=args.pp_stages, n_micro=args.pp_micro)

    # one cluster's state, broadcast to a (C,)-stacked DiLoCoTrainState and
    # placed by the explicit sharding rules (stage dim -> "model", leading
    # replica dim -> "clusters")
    st1 = IE.init_train_state(cfg, pcfg, jax.random.PRNGKey(0))
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (C,) + x.shape).copy(), t)
    state = IE.DiLoCoTrainState(params=stack(st1.params),
                                inner_opt=stack(st1.inner_opt),
                                outer_opt=stack(st1.outer_opt),
                                error=stack(st1.error))
    state = IE.shard_train_state(state, mesh, cluster_stacked=True)
    anchor = state.params
    delta_pending = jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), state.params)

    compressor = make_compressor("diloco_x", rank=args.rank)
    comp1 = compressor.init_state(st1.params)
    comp_state = jax.tree.map(
        lambda x: (jnp.broadcast_to(x, (C,) + x.shape).copy()
                   if hasattr(x, "shape") else x), comp1)

    train_step = jax.jit(IE.make_pp_train_step(
        cfg, mesh, pcfg, inner_lr=args.inner_lr, cluster_stacked=True))

    def outer_round(state, anchor, delta_pending, comp_state):
        # comm leg: average LAST round's pseudo-grads (one-step delay)
        delta_hat, comp_state = diloco.per_cluster_compress(
            compressor, delta_pending, comp_state,
            jnp.asarray(args.rank, jnp.int32))
        Delta = jax.tree.map(lambda x: x.mean(0), delta_hat)
        Delta_rows = jax.tree.map(
            lambda D, d: jnp.broadcast_to(D[None], d.shape), Delta,
            delta_pending)
        # Alg. 2 error feedback: e = delta - Delta (vs the applied average)
        err = jax.tree.map(lambda d, Dr: d - Dr, delta_pending, Delta_rows)
        # next round's pending delta, gathered from the sharded state
        delta_new = IE.extract_delta(anchor, state._replace(error=err))
        # delayed outer Nesterov on the anchor, applied row-wise (rows
        # stay identical: same Delta everywhere)
        params_new, outer_opt = nesterov.update(
            Delta_rows, state.outer_opt, anchor,
            lr=args.outer_lr, momentum=args.outer_momentum)
        state = IE.DiLoCoTrainState(params=params_new,
                                    inner_opt=state.inner_opt,
                                    outer_opt=outer_opt, error=err)
        return state, params_new, delta_new, comp_state

    outer_jit = jax.jit(outer_round)

    data = [SyntheticLM(cfg.vocab_size, args.seq_len, Bc, seed=0,
                        data_shard=i) for i in range(C)]
    tok_sharding = NamedSharding(mesh, P("clusters", "data", None))
    wire = compressor.wire_bytes(tree_shapes(st1.params))

    log, tracer, span = _setup_obs(args)
    from repro.obs import profile as prof
    from repro.checkpoint import checkpoint as ckpt_lib
    with prof.capture("train-pp"):
        for r in range(args.rounds):
            with span("round", round=r):
                losses = []
                with span("inner", round=r):
                    for h in range(args.h_steps):
                        toks = jnp.stack(
                            [d.next_batch()["tokens"] for d in data])
                        toks = jax.device_put(toks, tok_sharding)
                        params, inner_opt, loss = train_step(
                            state.params, state.inner_opt, toks)
                        state = state._replace(params=params,
                                               inner_opt=inner_opt)
                        losses.append(float(loss) / C)
                with span("outer", round=r):
                    state, anchor, delta_pending, comp_state = outer_jit(
                        state, anchor, delta_pending, comp_state)
            log.info(f"round {r}: mean_loss={np.mean(losses):.4f} "
                     f"H={args.h_steps} wire_per_cluster={wire/1e6:.2f}MB",
                     round=r, mean_loss=float(np.mean(losses)),
                     h_steps=args.h_steps, wire_bytes=wire)
            if args.ckpt_dir:
                ckpt_lib.save(os.path.join(args.ckpt_dir, f"round_{r:04d}"),
                              {"params": state.params}, step=r,
                              meta={"arch": args.arch, "inner": "pp"})
    log.info("TRAIN-DRIVER-OK")
    _finish_obs(args, log, tracer)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--h-steps", type=int, default=10)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--inner-lr", type=float, default=1e-3)
    ap.add_argument("--outer-lr", type=float, default=0.5)
    ap.add_argument("--outer-momentum", type=float, default=0.7)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--adaptive", action="store_true")
    ap.add_argument("--h-policy", default="global",
                    choices=["global", "balance"],
                    help="per-cluster local-step scheduling: balance gives "
                         "each cluster its own H from --step-times so slow "
                         "sites do fewer local steps per round")
    ap.add_argument("--step-times", default="",
                    help="comma-separated per-cluster step seconds "
                         "(measured on the real sites) for --h-policy "
                         "balance; default: uniform (== global)")
    ap.add_argument("--h-min", type=int, default=1)
    ap.add_argument("--inner", default="gspmd", choices=["gspmd", "pp"],
                    help="inner engine: gspmd = the vmapped cluster-stacked "
                         "step (launch/steps.py); pp = the sharded "
                         "pipeline-parallel engine "
                         "(parallel/inner_engine.py) with --pp-stages "
                         "stages per cluster")
    ap.add_argument("--pp-stages", type=int, default=2)
    ap.add_argument("--pp-micro", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--trace", default="",
                    help="write a wall-clock Chrome-trace JSON of the "
                         "driver's round/inner/outer spans here")
    ap.add_argument("--log-json", action="store_true",
                    help="mirror log lines as JSON objects on stderr")
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

    if args.inner == "pp":
        _run_pp(args)
        return

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.configs.base import get_config
    from repro.core import adaptive
    from repro.core import mesh_compression as mc
    from repro.data.synthetic import SyntheticLM, with_frontend
    from repro.launch import steps
    from repro.models import model as M
    from repro.optim import adamw
    from repro.parallel import sharding as sh
    from repro import checkpoint as _  # noqa: F401

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    C = args.clusters
    assert C * args.data * args.model == args.devices

    mesh = jax.make_mesh((C, args.data, args.model),
                         ("clusters", "data", "model"))
    M.set_activation_sharder(sh.make_activation_sharder(mesh))

    rng = jax.random.PRNGKey(0)
    params1 = M.init_params(cfg, rng)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (C,) + x.shape).copy(), params1)
    opt = jax.vmap(adamw.init)(params)
    ccfg = mc.MeshCompressionConfig(rank=args.rank)
    outer_state = steps.init_outer_state(params1, C, ccfg)

    # shardings
    ps = sh.param_shardings(jax.eval_shape(lambda: params), mesh,
                            cluster_stacked=True)
    params = jax.device_put(params, ps)

    balance_h = args.h_policy == "balance"
    step_times = ([float(s) for s in args.step_times.split(",")]
                  if args.step_times else [1.0] * C)
    assert len(step_times) == C, "--step-times needs one entry per cluster"

    def plan_round_h(h_budget):
        h_map = adaptive.plan_h(
            adaptive.HSpec(policy="balance", h_min=args.h_min),
            h_budget, np.asarray(step_times), np.ones(C, bool))
        return [h_map[c] for c in range(C)]

    # uniform-at-budget rounds run the plain train step (bitwise the
    # global path); only genuinely heterogeneous rounds use the masked
    # variant — the same dispatch rule the sim backends and trainer apply
    train_step = jax.jit(steps.make_train_step(cfg, inner_lr=args.inner_lr))
    train_step_h = (jax.jit(steps.make_train_step(
        cfg, inner_lr=args.inner_lr, per_cluster_h=True))
        if balance_h else None)
    outer_step = jax.jit(steps.make_outer_step(
        cfg, ccfg, outer_lr=args.outer_lr,
        outer_momentum=args.outer_momentum))

    Bc = args.global_batch // C
    data = [SyntheticLM(cfg.vocab_size, args.seq_len, Bc, seed=0,
                        data_shard=i) for i in range(C)]
    ada_cfg = adaptive.AdaGradCmpConfig(r1=args.rank, h1=args.h_steps,
                                        mode="overlap")
    ada = adaptive.AdaGradCmpState.create(ada_cfg)
    bsh = sh.batch_shardings(
        {"tokens": jax.ShapeDtypeStruct((C, Bc, args.seq_len), jnp.int32)},
        mesh, cluster_stacked=True)

    log, tracer, span = _setup_obs(args)
    from repro.obs import profile as prof
    from repro.checkpoint import checkpoint as ckpt_lib
    # static (non-adaptive) budgets have a round-invariant schedule —
    # plan it once outside the loop
    h_vec_static = plan_round_h(args.h_steps) if balance_h else None
    prof_cm = contextlib.ExitStack()
    prof_cm.enter_context(prof.capture("train-gspmd"))
    for r in range(args.rounds):
        # pre-observe controller state = what this round executes (same
        # accounting rule as train/trainer.py: the post-observe state is
        # round r+1's budget and must not be logged as this round's)
        h_t = ada.h_t if args.adaptive else args.h_steps
        r_exec = ada.r_t
        if balance_h:
            h_vec = plan_round_h(h_t) if args.adaptive else h_vec_static
        else:
            h_vec = [h_t] * C
        het_round = any(hc != h_t for hc in h_vec)
        losses = []
        with span("round", round=r):
            with span("inner", round=r):
                for h in range(max(h_vec)):
                    toks = jnp.stack([d.next_batch()["tokens"]
                                      for d in data])
                    batch = {"tokens": jax.device_put(toks, bsh["tokens"])}
                    if cfg.modality != "text":
                        fe = jax.random.normal(
                            jax.random.fold_in(rng, r * 1000 + h),
                            (C, Bc, cfg.n_frontend_tokens,
                             cfg.d_model)) * 0.02
                        batch["frontend"] = fe
                    if het_round:
                        active = jnp.asarray([h < hc for hc in h_vec],
                                             bool)
                        params, opt, loss = train_step_h(params, opt,
                                                         batch, active)
                    else:
                        params, opt, loss = train_step(params, opt, batch)
                    losses.append(float(loss))
            with span("outer", round=r):
                rank_scalar = jnp.asarray(r_exec, jnp.int32)
                params, outer_state = outer_step(params, outer_state,
                                                 rank_scalar)
        wire = mc.wire_bytes_tree(params1, ccfg,
                                  rank=r_exec if args.adaptive else None)
        h_str = (f"H={h_t}" if not het_round
                 else "H=" + "/".join(str(hc) for hc in h_vec))
        log.info(f"round {r}: mean_loss={np.mean(losses):.4f} "
                 f"{h_str} r={r_exec} wire_per_cluster={wire/1e6:.2f}MB",
                 round=r, mean_loss=float(np.mean(losses)), h=h_vec,
                 rank=int(r_exec), wire_bytes=wire)
        if args.adaptive:
            ada = adaptive.observe_mean_pseudo_grad(
                ada, jax.tree.map(lambda x: x.mean(0),
                                  outer_state.delta_pending), ada_cfg)
        if args.ckpt_dir:
            ckpt_lib.save(os.path.join(args.ckpt_dir, f"round_{r:04d}"),
                          {"params": params, "outer": outer_state._asdict()},
                          step=r, meta={"arch": args.arch})
    prof_cm.close()
    log.info("TRAIN-DRIVER-OK")
    _finish_obs(args, log, tracer)


if __name__ == "__main__":
    main()
