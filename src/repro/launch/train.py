"""End-to-end DiLoCoX training driver on a (small, CPU-hostable) mesh.

This is the *executable* counterpart of the dry-run: the same mesh-level
step functions (launch/steps.py), run for real on
``--devices`` host devices, with the full DiLoCoX round structure:

    for outer step t:  H x train_step (per-cluster, vmapped)
                       outer_step     (compress -> gather -> Nesterov,
                                       one-step-delay semantics)
                       AdaGradCmp     (Alg. 3 host-side controller)

Usage (8 simulated devices, 2 clusters x 2 data x 2 model):
  python -m repro.launch.train --arch granite-3-8b --smoke \
      --devices 8 --clusters 2 --rounds 8 --h-steps 10
"""
import argparse
import dataclasses
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--h-steps", type=int, default=10)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--inner-lr", type=float, default=1e-3)
    ap.add_argument("--outer-lr", type=float, default=0.5)
    ap.add_argument("--outer-momentum", type=float, default=0.7)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--adaptive", action="store_true")
    ap.add_argument("--h-policy", default="global",
                    choices=["global", "balance"],
                    help="per-cluster local-step scheduling: balance gives "
                         "each cluster its own H from --step-times so slow "
                         "sites do fewer local steps per round")
    ap.add_argument("--step-times", default="",
                    help="comma-separated per-cluster step seconds "
                         "(measured on the real sites) for --h-policy "
                         "balance; default: uniform (== global)")
    ap.add_argument("--h-min", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.configs.base import get_config
    from repro.core import adaptive
    from repro.core import mesh_compression as mc
    from repro.data.synthetic import SyntheticLM, with_frontend
    from repro.launch import steps
    from repro.models import model as M
    from repro.optim import adamw
    from repro.parallel import sharding as sh
    from repro import checkpoint as _  # noqa: F401

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    C = args.clusters
    assert C * args.data * args.model == args.devices

    mesh = jax.make_mesh((C, args.data, args.model),
                         ("clusters", "data", "model"))
    M.set_activation_sharder(sh.make_activation_sharder(mesh))

    rng = jax.random.PRNGKey(0)
    params1 = M.init_params(cfg, rng)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (C,) + x.shape).copy(), params1)
    opt = jax.vmap(adamw.init)(params)
    ccfg = mc.MeshCompressionConfig(rank=args.rank)
    outer_state = steps.init_outer_state(params1, C, ccfg)

    # shardings
    ps = sh.param_shardings(jax.eval_shape(lambda: params), mesh,
                            cluster_stacked=True)
    params = jax.device_put(params, ps)

    balance_h = args.h_policy == "balance"
    step_times = ([float(s) for s in args.step_times.split(",")]
                  if args.step_times else [1.0] * C)
    assert len(step_times) == C, "--step-times needs one entry per cluster"

    def plan_round_h(h_budget):
        h_map = adaptive.plan_h(
            adaptive.HSpec(policy="balance", h_min=args.h_min),
            h_budget, np.asarray(step_times), np.ones(C, bool))
        return [h_map[c] for c in range(C)]

    # uniform-at-budget rounds run the plain train step (bitwise the
    # global path); only genuinely heterogeneous rounds use the masked
    # variant — the same dispatch rule the sim backends and trainer apply
    train_step = jax.jit(steps.make_train_step(cfg, inner_lr=args.inner_lr))
    train_step_h = (jax.jit(steps.make_train_step(
        cfg, inner_lr=args.inner_lr, per_cluster_h=True))
        if balance_h else None)
    outer_step = jax.jit(steps.make_outer_step(
        cfg, ccfg, outer_lr=args.outer_lr,
        outer_momentum=args.outer_momentum))

    Bc = args.global_batch // C
    data = [SyntheticLM(cfg.vocab_size, args.seq_len, Bc, seed=0,
                        data_shard=i) for i in range(C)]
    ada_cfg = adaptive.AdaGradCmpConfig(r1=args.rank, h1=args.h_steps,
                                        mode="overlap")
    ada = adaptive.AdaGradCmpState.create(ada_cfg)
    bsh = sh.batch_shardings(
        {"tokens": jax.ShapeDtypeStruct((C, Bc, args.seq_len), jnp.int32)},
        mesh, cluster_stacked=True)

    from repro.checkpoint import checkpoint as ckpt_lib
    # static (non-adaptive) budgets have a round-invariant schedule —
    # plan it once outside the loop
    h_vec_static = plan_round_h(args.h_steps) if balance_h else None
    for r in range(args.rounds):
        # pre-observe controller state = what this round executes (same
        # accounting rule as train/trainer.py: the post-observe state is
        # round r+1's budget and must not be logged as this round's)
        h_t = ada.h_t if args.adaptive else args.h_steps
        r_exec = ada.r_t
        if balance_h:
            h_vec = plan_round_h(h_t) if args.adaptive else h_vec_static
        else:
            h_vec = [h_t] * C
        het_round = any(hc != h_t for hc in h_vec)
        losses = []
        for h in range(max(h_vec)):
            toks = jnp.stack([d.next_batch()["tokens"] for d in data])
            batch = {"tokens": jax.device_put(toks, bsh["tokens"])}
            if cfg.modality != "text":
                fe = jax.random.normal(
                    jax.random.fold_in(rng, r * 1000 + h),
                    (C, Bc, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
                batch["frontend"] = fe
            if het_round:
                active = jnp.asarray([h < hc for hc in h_vec], bool)
                params, opt, loss = train_step_h(params, opt, batch,
                                                 active)
            else:
                params, opt, loss = train_step(params, opt, batch)
            losses.append(float(loss))
        rank_scalar = jnp.asarray(r_exec, jnp.int32)
        params, outer_state = outer_step(params, outer_state, rank_scalar)
        wire = mc.wire_bytes_tree(params1, ccfg,
                                  rank=r_exec if args.adaptive else None)
        h_str = (f"H={h_t}" if not het_round
                 else "H=" + "/".join(str(hc) for hc in h_vec))
        print(f"round {r}: mean_loss={np.mean(losses):.4f} "
              f"{h_str} r={r_exec} wire_per_cluster={wire/1e6:.2f}MB")
        if args.adaptive:
            ada = adaptive.observe_mean_pseudo_grad(
                ada, jax.tree.map(lambda x: x.mean(0),
                                  outer_state.delta_pending), ada_cfg)
        if args.ckpt_dir:
            ckpt_lib.save(os.path.join(args.ckpt_dir, f"round_{r:04d}"),
                          {"params": params, "outer": outer_state._asdict()},
                          step=r, meta={"arch": args.arch})
    print("TRAIN-DRIVER-OK")


if __name__ == "__main__":
    main()
