"""Sharded pipeline-parallel inner engine: one virtual cluster = one real
jax mesh slice.

The simulator's clusters historically ran *single-replica* inner steps
(``sim/quadratic.py``, ``train/trainer.py``): fine for certifying the outer
DiLoCoX round loop, but the paper's headline result — 107B pre-training
over 1 Gbps — rests on Pipeline Parallelism *inside* each cluster (§2.2)
with the Dual Optimizer and one-step-delay overlap layered on top.  This
module runs the H inner AdamW steps through ``parallel/pipeline.py``'s
shard_map GPipe loss under ``parallel/sharding.py``-style explicit
shardings, on devices faked via ``--xla_force_host_platform_device_count``,
and hands the *gathered* per-cluster pseudo-gradient to the existing outer
compress/mix layer unchanged.

Two mesh flavors:

 - **unit mesh** (``("data", "model")``, one cluster): the canonical
   engine.  The proc backend's ``worker.py`` and the in-process
   simulator's ``inner_fn`` (a python-level unroll over clusters — same
   discipline as ``core.diloco.per_cluster_compress``) execute the *same*
   compiled per-cluster program with the cluster index as a traced arg,
   which is what keeps proc ≡ in-process bitwise (the equivalence gate).
 - **cluster-stacked mesh** (``("clusters", "data", "model")``): the
   ``launch/train.py --inner pp`` production driver, where all clusters
   live in one program and bitwise cross-backend identity is not a goal.

State is held in a ``DiLoCoTrainState`` (the drjax-placements /
DemoYeti-maxtext idiom): params + inner AdamW moments + outer Nesterov
replica + error-feedback residual in one pytree with one sharding rule, so
a single ``jax.device_put`` (or ``in_shardings``) places the whole round
state.

Numerics contract (mirrors the PR 5 masked-dispatch lesson):

 - pp proc ≡ pp in-process: **bitwise** — identical jitted programs per
   cluster on identical unit meshes.
 - pp ≡ scalar (single-replica): **tolerance**, not bitwise — the pipeline
   loss computes the same math as the sequential model through a different
   op schedule (ppermute ticks, chunked CE, sharded reductions), so per
   round the params agree only to the pipeline-equivalence tolerance
   (``tests/test_pipeline.py``: loss 1e-4, grads 1e-3), compounding over
   H steps and rounds.  ``tests/test_inner_engine.py`` states the budget.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import diloco
from repro.optim import adamw, nesterov
from repro.parallel import pipeline as PP


class DiLoCoTrainState(NamedTuple):
    """One cluster's full DiLoCoX round state as a single sharded pytree.

    ``params`` is the *local* (inner-loop) replica; the outer anchor
    θ_anchor is passed separately to ``extract_delta`` because in the
    one-step-delay round it is the previous round's global params, owned
    by the outer layer, not the engine.
    """
    params: Any        # pp param tree {"embed","final_norm","stages",
                       #   "active"[,"head"]}; stages: (n_stages, lps, ...)
    inner_opt: Any     # adamw.AdamWState — moments mirror params' sharding
    outer_opt: Any     # nesterov.NesterovState — fp32 momentum replica
    error: Any         # EF residual, fp32, param-shaped


# ---------------------------------------------------------------------------
# mesh + state construction
# ---------------------------------------------------------------------------

def unit_mesh(pcfg: PP.PipelineConfig, data_parallel: int = 1) -> Mesh:
    """The single-cluster ("data","model") mesh. Requires the process to
    have been started with ``--xla_force_host_platform_device_count >=
    data_parallel * n_stages`` (jax locks the device count at first init)."""
    need = data_parallel * pcfg.n_stages
    if jax.device_count() < need:
        raise RuntimeError(
            f"pp inner engine needs {need} devices "
            f"(data_parallel={data_parallel} x n_stages={pcfg.n_stages}) "
            f"but jax sees {jax.device_count()}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before jax "
            f"initializes")
    return jax.make_mesh((data_parallel, pcfg.n_stages), ("data", "model"))


def init_train_state(cfg: ModelConfig, pcfg: PP.PipelineConfig,
                     rng) -> DiLoCoTrainState:
    """Round-0 state for one cluster (unstacked). Error/moments start at
    zero, the outer Nesterov momentum replica at zero — matching
    ``diloco.init_state`` row semantics."""
    params = PP.init_pp_params(cfg, rng, pcfg)
    return DiLoCoTrainState(
        params=params,
        inner_opt=adamw.init(params),
        outer_opt=nesterov.init(params),
        error=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                           params),
    )


def state_shardings(state: DiLoCoTrainState, mesh: Mesh, *,
                    cluster_stacked: bool = False) -> DiLoCoTrainState:
    """NamedShardings for every leaf of a DiLoCoTrainState: params and all
    param-shaped companions (AdamW m/v, Nesterov momentum, EF residual)
    share ``pp_param_specs`` (stage dim -> "model"); step counters are
    replicated (or "clusters"-sharded when stacked).  This is the "explicit
    shardings" half of the tentpole: the whole round state is placed by
    one tree of rules, so the outer layer's gathered delta is just a
    device_get away."""

    def pshard(tree):
        specs = PP.pp_param_specs(tree, mesh, cluster_stacked=cluster_stacked)
        return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                            is_leaf=lambda x: isinstance(x, P))

    scalar = NamedSharding(mesh, P("clusters") if cluster_stacked else P())
    return DiLoCoTrainState(
        params=pshard(state.params),
        inner_opt=type(state.inner_opt)(
            step=scalar, m=pshard(state.inner_opt.m),
            v=pshard(state.inner_opt.v)),
        outer_opt=type(state.outer_opt)(
            step=scalar, momentum=pshard(state.outer_opt.momentum)),
        error=pshard(state.error),
    )


def shard_train_state(state: DiLoCoTrainState, mesh: Mesh, *,
                      cluster_stacked: bool = False) -> DiLoCoTrainState:
    """Place a host-built state onto the mesh under ``state_shardings``."""
    return jax.device_put(
        state, state_shardings(state, mesh, cluster_stacked=cluster_stacked))


# ---------------------------------------------------------------------------
# delta extraction (the outer-layer boundary)
# ---------------------------------------------------------------------------

def extract_delta(anchor, state: DiLoCoTrainState):
    """Gathered per-cluster pseudo-gradient δ = (θ_anchor − θ_local) + e,
    fp32, from the sharded train state (``core.diloco.pseudo_grad`` does
    the arithmetic — one implementation for the scalar and pp engines).

    The ``active`` stage mask is not a trainable parameter: its delta is
    pinned to exactly zero, so it stays zero through compression (zero in
    → zero out in LowRankQuant) and the outer Nesterov momentum row for it
    never moves."""
    delta = diloco.pseudo_grad(anchor, state.params, state.error)
    delta = dict(delta)
    delta["active"] = jnp.zeros_like(delta["active"])
    return delta


def apply_delta(anchor, delta, error=None):
    """Inverse of ``extract_delta`` (up to fp rounding): local params such
    that extraction from them reproduces ``delta``.  θ_local =
    θ_anchor − (δ − e); the ``active`` mask is carried from the anchor
    (it was excluded from the delta).  Used by the round-trip property
    test; exactness is a stated tolerance, not bitwise — ``a − (a − p)``
    re-rounds unless Sterbenz applies."""
    if error is None:
        error = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                             anchor)
    local = jax.tree.map(
        lambda a, d, e: (a.astype(jnp.float32) - (d - e)).astype(a.dtype),
        anchor, delta, error)
    local = dict(local)
    local["active"] = anchor["active"]
    return local


# ---------------------------------------------------------------------------
# the inner step / inner loop
# ---------------------------------------------------------------------------

def make_pp_train_step(cfg: ModelConfig, mesh: Mesh,
                       pcfg: PP.PipelineConfig, *, inner_lr: float,
                       cluster_stacked: bool = False) -> Callable:
    """One inner AdamW step through the pipelined loss:
    ``train_step(params, opt, tokens) -> (params', opt', loss)``.

    The ``active`` mask's gradient is zeroed before the update and the
    mask itself carried through unchanged (the dry-run's Mode B pattern) —
    AdamW weight decay would otherwise shrink the mask."""
    loss_fn = PP.make_pp_loss(cfg, mesh, pcfg,
                              cluster_stacked=cluster_stacked)

    from repro.obs import profile as _prof

    def train_step(params, opt, tokens):
        # named scope shows up in REPRO_PROFILE captures / XLA HLO names;
        # a nullcontext when profiling is off (identical trace either way)
        with _prof.scope("pp_train_step"):
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens)
            grads = dict(grads)
            grads["active"] = jnp.zeros_like(grads["active"])
            if cluster_stacked:
                new_params, opt = jax.vmap(
                    lambda p_, g_, o_: adamw.update(g_, o_, p_,
                                                    lr=inner_lr))(
                    params, grads, opt)
            else:
                new_params, opt = adamw.update(grads, opt, params,
                                               lr=inner_lr)
            new_params = dict(new_params)
            new_params["active"] = params["active"]
            return new_params, opt, loss

    return train_step


def make_pp_one_cluster(cfg: ModelConfig, pcfg: PP.PipelineConfig,
                        mesh: Mesh, *, inner_lr: float, h_steps: int,
                        batch_fn: Callable) -> Tuple[Callable, Callable]:
    """Per-cluster H-step inner loops on the unit mesh.

    ``batch_fn(c, i) -> tokens (B, S)`` with *traced* cluster index ``c``
    and inner-step index ``i`` — and nothing else.  The proc worker calls
    the returned function with no round index (its contract since PR 2),
    so pp data must be round-invariant; trainers that want per-round data
    fold the round into their own batch_fn closure instead of using this.

    Returns ``(one_cluster, one_cluster_h)``:
      one_cluster(params, opt, c)      -> (params_H, opt', losses[(H,)])
      one_cluster_h(params, opt, c, h) -> (params_H, opt', mean_loss)
    — the exact signatures ``sim/quadratic.QuadraticSpec`` exposes, so the
    worker and simulator wire pp identically to scalar.  ``one_cluster_h``
    is the masked fixed-length scan (``diloco.masked_local_steps``); per
    the PR 5 dispatch rule, uniform-at-budget rounds must route to
    ``one_cluster``."""
    train_step = make_pp_train_step(cfg, mesh, pcfg, inner_lr=inner_lr,
                                    cluster_stacked=False)

    def step_body(carry, i, c):
        params, opt = carry
        tokens = batch_fn(c, i)
        params, opt, loss = train_step(params, opt, tokens)
        return (params, opt), loss

    def one_cluster(params, opt, c):
        (params, opt), losses = jax.lax.scan(
            lambda carry, i: step_body(carry, i, c), (params, opt),
            jnp.arange(h_steps))
        return params, opt, losses

    def one_cluster_h(params, opt, c, h):
        (params, opt), mean_loss = diloco.masked_local_steps(
            lambda carry, i: step_body(carry, i, c), (params, opt),
            h_steps, h)
        return params, opt, mean_loss

    return one_cluster, one_cluster_h


def make_pp_inner_fns(one_cluster: Callable, one_cluster_h: Callable,
                      n_clusters: int) -> Tuple[Callable, Callable]:
    """Lift the per-cluster loops to the ``NumericProblem.inner_fn``
    signature ``(params, inner_opt_stacked, round_idx) -> (params_stacked,
    opt_stacked, aux)`` by a python-level UNROLL over clusters — not vmap.

    vmap would batch the pipeline's matmuls and ppermutes into one program
    whose accumulation order differs from a lone worker's by ~1 ulp (the
    ``per_cluster_compress`` lesson); unrolling executes the identical
    per-cluster op sequence the proc worker jits, which is what the
    bitwise proc≡in-process gate certifies.  The round index is accepted
    and ignored: pp batches are round-invariant (see
    ``make_pp_one_cluster``)."""

    def _stack(trees):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    def inner_fn(params, opt_stacked, t):
        del t
        outs = [one_cluster(params, diloco.take_row(opt_stacked, c),
                            jnp.asarray(c, jnp.int32))
                for c in range(n_clusters)]
        return (_stack([o[0] for o in outs]), _stack([o[1] for o in outs]),
                _stack([o[2] for o in outs]))

    def inner_fn_h(params, opt_stacked, t, h_vec):
        del t
        outs = [one_cluster_h(params, diloco.take_row(opt_stacked, c),
                              jnp.asarray(c, jnp.int32), h_vec[c])
                for c in range(n_clusters)]
        return (_stack([o[0] for o in outs]), _stack([o[1] for o in outs]),
                _stack([o[2] for o in outs]))

    return inner_fn, inner_fn_h
