"""Mode B: paper-faithful Pipeline Parallelism (§2.2) as a shard_map over
the "model" axis.

Each device holds one *stage* (layers_per_stage stacked decoder layers);
microbatches flow stage-to-stage via ``lax.ppermute`` in a GPipe-style loop
of n_micro + n_stages - 1 ticks. The whole loop is differentiable (the
transpose of ppermute is the reversed permute; shard_map's VMA tracking
inserts the data-parallel grad psums). Embedding + head run replicated per
stage-column; only stage 0's embedding and the last stage's head feed the
dataflow.

Scope: uniform decoder-only stacks (dense family). MoE/hybrid/enc-dec keep
Mode A (DESIGN.md §Arch-applicability): their stages are either memory-
infeasible without intra-stage tensor sharding (MoE experts) or break the
sequential stage chain (cross-attention).

Layer padding: n_layers is padded up to a multiple of n_stages; padded
slots carry an ``active`` flag and pass activations through untouched (the
waste is layers_pad/n_layers and is reported by the dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import apply_norm, embed_init, dense_init, init_norm, split


def _shard_map(f, *, mesh, in_specs, out_specs):
    """shard_map across jax versions: jax>=0.6 exposes ``jax.shard_map``
    (``check_vma=``); 0.4.x only has ``jax.experimental.shard_map.shard_map``
    (``check_rep=``). Replication checking is off in both spellings — the
    per-stage loss masking here is deliberately "unreplicated"."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


@dataclass(frozen=True)
class PipelineConfig:
    n_stages: int = 4
    n_micro: int = 16             # microbatches per (cluster, data) column


def layers_per_stage(cfg: ModelConfig, pcfg: PipelineConfig) -> Tuple[int, int]:
    lps = math.ceil(cfg.n_layers / pcfg.n_stages)
    pad = lps * pcfg.n_stages - cfg.n_layers
    return lps, pad


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_pp_params(cfg: ModelConfig, rng, pcfg: PipelineConfig):
    """{"embed","head","final_norm","stages","active"}; stages leaves are
    (n_stages, layers_per_stage, ...)."""
    assert cfg.family in ("dense", "vlm") and not cfg.global_every, \
        "Mode B supports uniform decoder stacks (DESIGN.md)"
    dt = jnp.dtype(cfg.param_dtype)
    lps, pad = layers_per_stage(cfg, pcfg)
    seg = M.build_segments(cfg)[0]          # uniform => single segment
    keys = split(rng, 4)
    unit_keys = jax.random.split(keys[2], pcfg.n_stages * lps).reshape(
        pcfg.n_stages, lps, -1)
    stages = jax.vmap(jax.vmap(seg.init_unit))(unit_keys)
    # float mask (not bool) so the tree stays jax.grad-able; padded slots
    # contribute exactly zero gradient through the lerp in stage_fn
    active = (jnp.arange(pcfg.n_stages * lps) < cfg.n_layers).reshape(
        pcfg.n_stages, lps).astype(jnp.float32)
    params = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dt),
        "stages": stages,
        "active": active,
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)
    return params


def pp_param_specs(params, mesh: Mesh, *, cluster_stacked: bool):
    """in_specs for shard_map: stage dim -> "model"; everything else
    replicated within the cluster (embed/head/norm live on every stage)."""
    lead = ("clusters",) if cluster_stacked else ()

    def spec(path, x):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        nlead = len(lead)
        if any(n in ("stages", "active") for n in names):
            return P(*lead, "model", *([None] * (x.ndim - nlead - 1)))
        return P(*lead, *([None] * (x.ndim - nlead)))

    return jax.tree_util.tree_map_with_path(spec, params)


# ---------------------------------------------------------------------------
# the pipelined loss
# ---------------------------------------------------------------------------

def make_pp_loss(cfg: ModelConfig, mesh: Mesh, pcfg: PipelineConfig, *,
                 cluster_stacked: bool = True, loss_scale_clusters: bool = True):
    """Returns loss_fn(params, tokens) running the GPipe loop inside a
    shard_map over (clusters, data, model). tokens: (C, Bc, S) (or (B, S) if
    not cluster_stacked). Loss returned is the SUM over clusters of the
    per-cluster mean NLL (so per-cluster grads match independent training)."""
    seg = M.build_segments(cfg)[0]
    lps, _ = layers_per_stage(cfg, pcfg)
    n_stages = pcfg.n_stages
    axes = ("clusters", "data", "model") if cluster_stacked else \
        ("data", "model")

    def stage_fn(stage_params, active, x, ctx):
        def layer(x, pa):
            p, a = pa
            y, _ = seg.apply_unit(p, x, ctx)
            a = a.astype(y.dtype)
            return y * a + x * (1.0 - a), None

        x, _ = jax.lax.scan(layer, x, (stage_params, active))
        return x

    def per_device(params, tokens):
        # squeeze shard_map's size-1 sharded dims
        sq = (lambda t: jax.tree.map(lambda a: a[0], t))
        if cluster_stacked:
            params = sq(params)
            tokens = tokens[0]
        stage_params = sq({"s": params["stages"]})["s"]   # (lps, ...)
        active = params["active"][0]
        tokens = tokens[0] if False else tokens           # (B_loc, S)

        B, S = tokens.shape
        m = pcfg.n_micro
        assert B % m == 0, (B, m)
        mb = B // m
        stage = jax.lax.axis_index("model")
        cd = jnp.dtype(cfg.compute_dtype)

        x_all = params["embed"].astype(cd)[tokens]        # (B,S,d)
        micro = x_all.reshape(m, mb, S, -1)
        ctx = M.make_ctx(cfg, mb, S)
        chk_stage = jax.checkpoint(
            lambda sp, act, xx: stage_fn(sp, act, xx, ctx))

        perm = [(i, i + 1) for i in range(n_stages - 1)]
        T = m + n_stages - 1

        def tick(carry, t):
            recv = carry
            idx = jnp.clip(t, 0, m - 1)
            first_in = jax.lax.dynamic_index_in_dim(micro, idx, axis=0,
                                                    keepdims=False)
            my_in = jnp.where(stage == 0, first_in, recv)
            out = chk_stage(stage_params, active, my_in)
            recv_next = jax.lax.ppermute(out, "model", perm)
            return recv_next, out

        _, outs = jax.lax.scan(tick, jnp.zeros_like(micro[0]),
                               jnp.arange(T))
        # valid last-stage outputs are ticks [n_stages-1, n_stages-1+m)
        outs_valid = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, m,
                                                  axis=0)
        h = outs_valid.reshape(B, S, -1)
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        tgt = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
        msk = jnp.concatenate(
            [jnp.ones((B, S - 1), jnp.float32),
             jnp.zeros((B, 1), jnp.float32)], axis=1)

        # chunked head+CE: the (B,S,V) f32 logits of the replicated head
        # were ~50 GB of temp at vocab 49k (hillclimb C iter 2); per-chunk
        # logits are ~1.6 GB and backward recomputes under checkpoint.
        def ce_chunk(h_c, tgt_c, m_c):
            hc = apply_norm(params["final_norm"], h_c, cfg.norm)
            lg = (hc @ w.astype(hc.dtype)).astype(jnp.float32)
            lse = jax.nn.logsumexp(lg, axis=-1)
            iota_v = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
            tl = jnp.sum(jnp.where(iota_v == tgt_c[..., None], lg, 0.0), -1)
            return jnp.sum((lse - tl) * m_c)

        lc = 512 if S % 512 == 0 and S > 512 else S
        n_ch = S // lc
        hs = h.reshape(B, n_ch, lc, -1).transpose(1, 0, 2, 3)
        ts = tgt.reshape(B, n_ch, lc).transpose(1, 0, 2)
        ms = msk.reshape(B, n_ch, lc).transpose(1, 0, 2)
        ce = jax.checkpoint(ce_chunk)
        sums = jax.lax.map(lambda a: ce(*a), (hs, ts, ms))
        nll_sum = sums.sum()
        cnt = msk.sum()
        # only the last stage's numbers are real
        is_last = (stage == n_stages - 1).astype(jnp.float32)
        nll_sum = nll_sum * is_last
        cnt = cnt * is_last
        # per-cluster mean: reduce over data+model; SUM over clusters
        nll_sum = jax.lax.psum(nll_sum, ("data", "model"))
        cnt = jax.lax.psum(cnt, ("data", "model"))
        loss_c = nll_sum / jnp.maximum(cnt, 1.0)
        if cluster_stacked:
            loss_c = jax.lax.psum(loss_c, "clusters")
        return loss_c

    in_specs = (pp_param_specs(
        jax.eval_shape(lambda: None) if False else _dummy_params_tree(cfg, pcfg),
        mesh, cluster_stacked=cluster_stacked),
        P(*( ("clusters", "data", None) if cluster_stacked
             else ("data", None))))
    loss_sm = _shard_map(per_device, mesh=mesh, in_specs=in_specs,
                         out_specs=P())
    return loss_sm


def _dummy_params_tree(cfg: ModelConfig, pcfg: PipelineConfig):
    """Structure-only params tree for building in_specs (eval_shape)."""
    return jax.eval_shape(
        lambda k: init_pp_params(cfg, k, pcfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
