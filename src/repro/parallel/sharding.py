"""GSPMD sharding rules (Mode A): 2-D weight sharding = FSDP over "data" x
tensor-parallel over "model", per parameter family. Cluster-stacked leaves
get a leading "clusters" axis.

Rules are path+shape based:
  - expert-stacked weights (path contains 'experts'): expert dim -> "model"
    (expert parallelism), d_model dim -> "data".
  - 2-D weights (d_in, d_out): the *larger* of the two trailing dims gets
    "model" (keeps TP on the fat dim: ff/heads/vocab), the other "data".
  - scanned-layer leading dims and 1-D params: replicated.
Activations: batch over ("clusters","data") [train] or ("data",) [serve];
long-context (batch=1) decode shards the KV-cache sequence dim over "data".

A dim is only sharded if divisible by the axis size — otherwise left
replicated (keeps every (arch x shape) lowering valid; the dry-run reports
what actually sharded).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _divisible(n: int, mesh: Mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


def spec_for_param(path_names, shape: Tuple[int, ...], mesh: Mesh, *,
                   cluster_stacked: bool, n_scan_dims: int) -> P:
    """Build a PartitionSpec for one parameter leaf.

    n_scan_dims: number of leading stacked dims that are scan/cluster dims
    (cluster dim first if cluster_stacked, then segment-stack dim)."""
    names = [str(n) for n in path_names]
    entries: list = []
    lead = []
    if cluster_stacked:
        lead.append("clusters" if _divisible(shape[0], mesh, "clusters")
                    else None)
    while len(lead) < n_scan_dims:
        lead.append(None)
    body_shape = shape[n_scan_dims:]
    is_expert = any("experts" in n for n in names)
    if len(body_shape) == 0:
        entries = lead
    elif len(body_shape) == 1:
        entries = lead + [None]
    elif is_expert and len(body_shape) >= 3:
        # (E, d_in, d_out): expert parallel + FSDP on d_in
        e, din, dout = body_shape[-3], body_shape[-2], body_shape[-1]
        entries = lead + [None] * (len(body_shape) - 3)
        entries += ["model" if _divisible(e, mesh, "model") else None,
                    "data" if _divisible(din, mesh, "data") else None,
                    None]
    else:
        # generic 2D+ weight: fat trailing dim -> model, other -> data
        din, dout = body_shape[-2], body_shape[-1]
        mid = [None] * (len(body_shape) - 2)
        if dout >= din:
            a = "data" if _divisible(din, mesh, "data") else None
            b = "model" if _divisible(dout, mesh, "model") else None
        else:
            a = "model" if _divisible(din, mesh, "model") else None
            b = "data" if _divisible(dout, mesh, "data") else None
        entries = lead + mid + [a, b]
    return P(*entries)


def param_shardings(params_shape_tree, mesh: Mesh, *,
                    cluster_stacked: bool, serve: bool = False) -> Any:
    """Tree of NamedShardings matching an (optionally cluster-stacked)
    param pytree of ShapeDtypeStructs.

    serve=True: weights shard over "model" ONLY (no FSDP dim) when the
    model fits that way — decode is latency-bound and per-token FSDP
    all-gathers dominated the decode ICI term (§Perf hillclimb D). Callers
    pass serve=True only when params_bytes/model_axis fits HBM."""

    def build(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        shape = leaf.shape
        # embedding table: d_model -> "model" (gather stays local in vocab;
        # the head-side use is resharded by the "head_w" activation rule)
        if any(n == "embed" for n in names):
            lead = (["clusters"] if cluster_stacked
                    and shape[0] % mesh.shape["clusters"] == 0 else
                    [None] * (1 if cluster_stacked else 0))
            spec = P(*lead, None,
                     "model" if _divisible(shape[-1], mesh, "model") else None)
            return NamedSharding(mesh, spec)
        # infer scan dims: cluster dim (if stacked) + segment-stack dim for
        # leaves under 'segments' (they carry a leading n_units dim)
        n_scan = (1 if cluster_stacked else 0)
        if any("segments" in str(n) for n in names):
            n_scan += 1
        n_scan = min(n_scan, max(0, len(shape) - 1))
        spec = spec_for_param(names, shape, mesh,
                              cluster_stacked=cluster_stacked,
                              n_scan_dims=n_scan)
        if serve:   # drop the "data" (FSDP) dim; keep tensor parallelism
            spec = P(*[e if e != "data" else None for e in tuple(spec)])
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(build, params_shape_tree)


def make_activation_sharder(mesh: Mesh):
    """Named activation constraints used inside model code (installed via
    models.model.set_activation_sharder). Specs are ranked for the
    *unbatched* value (vmap-over-clusters lifts them)."""
    from jax.sharding import NamedSharding

    def sharder(name: str, x):
        shape = x.shape
        if name == "act" and len(shape) == 3:        # (B,S,d)
            spec = P("data" if _divisible(shape[0], mesh, "data") else None,
                     None, None)
        elif name == "act4" and len(shape) == 4:     # (B,S,heads,dh)-like
            spec = P("data" if _divisible(shape[0], mesh, "data") else None,
                     None, None, None)
        elif name == "moe_buf" and len(shape) == 4:  # (B,E,C,d): EP on E
            spec = P("data" if _divisible(shape[0], mesh, "data") else None,
                     "model" if _divisible(shape[1], mesh, "model") else None,
                     None, None)
        elif name == "ctx4" and len(shape) == 4:     # keys: S over "model"
            spec = P("data" if _divisible(shape[0], mesh, "data") else None,
                     "model" if _divisible(shape[1], mesh, "model") else None,
                     None, None)
        elif name == "ctx3" and len(shape) == 3:     # gate prefixes (B,S,nh)
            spec = P("data" if _divisible(shape[0], mesh, "data") else None,
                     "model" if _divisible(shape[1], mesh, "model") else None,
                     None)
        elif name == "logits" and len(shape) == 3:   # (B,S,V)
            spec = P("data" if _divisible(shape[0], mesh, "data") else None,
                     None,
                     "model" if _divisible(shape[2], mesh, "model") else None)
        elif name == "head_w" and len(shape) == 2:   # (d,V)
            spec = P("data" if _divisible(shape[0], mesh, "data") else None,
                     "model" if _divisible(shape[1], mesh, "model") else None)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return sharder


def batch_shardings(batch_shape_tree, mesh: Mesh, *,
                    cluster_stacked: bool) -> Any:
    """Tokens/labels/frontend: leading (cluster,) batch dims sharded."""

    def build(leaf):
        dims: list = []
        if cluster_stacked:
            dims.append("clusters")
        dims.append("data" if _divisible(leaf.shape[len(dims)], mesh, "data")
                    else None)
        dims += [None] * (len(leaf.shape) - len(dims))
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(build, batch_shape_tree)


def decode_state_shardings(state_tree, mesh: Mesh, *, seq_shard: bool) -> Any:
    """KV caches / SSM states for serving. Batched decode shards batch over
    "data"; long-context (batch=1) shards the cache sequence dim over "data"
    instead (context parallelism). Heads/state dims go to "model".

    Cache leaves look like (n_units, B, S, KV, hd) / (n_units, B, S, lora)
    / SSM (n_units, B, nh, hd, ds) / conv (n_units, B, k, C)."""

    def build(path, leaf):
        shape = leaf.shape
        if not hasattr(leaf, "shape") or len(shape) == 0:
            return NamedSharding(mesh, P())
        dims: list = [None] * len(shape)
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if len(shape) >= 2:
            # dim 1 is batch for unit-stacked caches
            bdim = 1 if len(shape) >= 3 else 0
            if not seq_shard and _divisible(shape[bdim], mesh, "data"):
                dims[bdim] = "data"
            if seq_shard and len(shape) >= 4 and "pos" not in names[-1:]:
                # (units, B, S, ...): shard S over data
                if _divisible(shape[2], mesh, "data"):
                    dims[2] = "data"
            # shard a heads-like dim over model if present & divisible
            for di in range(len(shape) - 1, 1, -1):
                if dims[di] is None and _divisible(shape[di], mesh, "model") \
                        and shape[di] >= mesh.shape["model"] and di != 2:
                    dims[di] = "model"
                    break
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(build, state_tree)


def replicated(tree, mesh: Mesh) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
