"""Outer optimizer: Nesterov momentum over *pseudo-gradients* (DiLoCo /
DiLoCoX §2.2). The pseudo-gradient Δ is (θ_anchor − θ_local) averaged across
clusters; the outer step is SGD with Nesterov momentum in fp32.

State is param-shaped and inherits param sharding — the "distributed outer
optimizer" half of the Dual Optimizer Policy.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class NesterovState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


def init(params) -> NesterovState:
    return NesterovState(
        step=jnp.zeros((), jnp.int32),
        momentum=jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params))


def update(pseudo_grads, state: NesterovState, params, *, lr=0.7,
           momentum=0.9):
    """θ ← θ − lr·(μ·v_new + Δ), v_new = μ·v + Δ  (Nesterov form used by
    DiLoCo). pseudo_grads point in the *descent* direction already
    (θ_anchor − θ_local ≈ η·Σ grads)."""
    def upd(p, g, v):
        g = g.astype(jnp.float32)
        v_new = momentum * v + g
        step_dir = momentum * v_new + g
        return ((p.astype(jnp.float32) - lr * step_dir).astype(p.dtype),
                v_new)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(pseudo_grads)
    flat_v = jax.tree.leaves(state.momentum)
    out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    return (treedef.unflatten([o[0] for o in out]),
            NesterovState(step=state.step + 1,
                          momentum=treedef.unflatten([o[1] for o in out])))
