"""Inner optimizer: AdamW (the paper's inner optimizer, §2.2/Lemma 3.4).

Functional, pytree-based, optax-free (only jax+numpy are available offline).
State is param-shaped (m, v) and inherits the param sharding — this is the
"distributed inner optimizer" half of the Dual Optimizer Policy.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=z,
                      v=jax.tree.map(jnp.copy, z))


def update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state). `lr` may be a scalar or callable of
    step."""
    step = state.step + 1
    if callable(lr):
        lr_t = lr(step)
    else:
        lr_t = lr
    if grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)) + 1e-16)
        scale = jnp.minimum(1.0, grad_clip / gnorm)
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mh = m_new / b1t
        vh = v_new / b2t
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
