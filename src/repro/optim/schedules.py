"""Learning-rate schedules (warmup + cosine, the usual pretraining shape)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)
    return lr


def constant(lr_value: float):
    def lr(step):
        return lr_value
    return lr
