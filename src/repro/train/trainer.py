"""Single-host DiLoCoX trainer: D clusters simulated by a vmap'd leading
axis (the same algebra the mesh runtime uses with the cluster dim sharded
over the "pod"/"data" axis — see DESIGN.md §3 and launch/train.py).

Drives the paper's convergence experiments (Fig. 3, Table 1): AllReduce,
OpenDiLoCo-style, CocktailSGD and DiLoCoX all run through ``diloco_round``
with different RoundConfig/Compressor settings.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adaptive, diloco
from repro.core.compression import Compressor, make_compressor, tree_shapes
from repro.data.synthetic import SyntheticLM, with_frontend
from repro.models import model as M
from repro.obs import get_logger
from repro.optim import adamw

# debug-level per-round telemetry: silent under the default ("info")
# threshold, so library output stays empty unless the host opts in via
# obs.configure_logging(level="debug")
_log = get_logger("train.trainer")


@dataclass
class TrainConfig:
    n_clusters: int = 2           # D (paper's decentralized clusters)
    local_batch: int = 8
    seq_len: int = 64
    inner_lr: float = 1e-3
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    h_steps: int = 20             # H (local steps per round)
    compressor: str = "diloco_x"
    compressor_kw: Dict[str, Any] = field(default_factory=dict)
    delay: bool = True
    compress: bool = True
    error_feedback: bool = True
    adaptive: bool = False        # run AdaGradCmp (Alg. 3)
    adaptive_mode: str = "paper"
    adaptive_window: int = 5      # Alg. 3 window c
    hetero: float = 0.0           # per-cluster data heterogeneity (xi^2>0)
    # heterogeneous local-step scheduling (core.adaptive.HSpec): "balance"
    # gives each cluster its own per-round H from step_times (the measured
    # or assumed per-cluster step seconds) so slow sites do fewer local
    # steps; the inner scan stays h_steps long and masks the tail
    # (core.diloco.masked_local_steps) — a uniform schedule is bitwise the
    # scalar path
    h_policy: str = "global"      # global | balance
    h_min: int = 1
    step_times: Optional[Any] = None   # per-cluster step seconds (len C)
    # inner engine: "scalar" runs single-replica inner steps (vmapped over
    # clusters, the historical path); "pp" runs every cluster's H steps
    # through the sharded pipeline-parallel engine
    # (parallel.inner_engine) on a ("data","model") unit mesh of
    # pp_stages faked devices — the hosting process must set
    # XLA_FLAGS=--xla_force_host_platform_device_count>=pp_stages BEFORE
    # jax initializes (text models only; see parallel/inner_engine.py)
    inner_engine: str = "scalar"  # scalar | pp
    pp_stages: int = 2
    pp_micro: int = 2
    seed: int = 0


def _hetero_bias(tcfg: TrainConfig, branching: int):
    """Per-cluster successor-slot bias (Assumption 3.3 heterogeneity) —
    shared by the scalar and pp inner engines so both draw the same
    per-cluster data distribution."""
    if tcfg.hetero <= 0:
        return None
    base = jnp.zeros((tcfg.n_clusters, branching))
    boost = jnp.log(1.0 + tcfg.hetero * branching
                    / (1 - tcfg.hetero + 1e-9))
    return jax.vmap(lambda i: base[0].at[i % branching].set(boost))(
        jnp.arange(tcfg.n_clusters))


def make_inner_fn(cfg: ModelConfig, tcfg: TrainConfig, data_tables,
                  h_vec=None):
    """Returns inner_fn(params, inner_opt_stacked, round_idx) -> (stacked
    params after H local AdamW steps per cluster, new inner state).
    Data is drawn deterministically from per-cluster PRNG streams; with
    tcfg.hetero > 0 each cluster prefers a different successor slot
    (Assumption 3.3 heterogeneity).

    ``h_vec`` (a (C,) int32 per-cluster local-step schedule, e.g. from
    ``core.adaptive.plan_h``) switches to heterogeneous-H mode: every
    cluster runs the same ``h_steps``-long masked scan but only its own
    first ``h_vec[c]`` steps apply, and the per-round aux becomes the
    per-cluster mean loss."""
    from repro.data.synthetic import _gen_batch

    branching = 4
    bias_all = _hetero_bias(tcfg, branching)

    def step_body(carry, h, cluster_idx, round_idx):
        # shared step so the plain and h-masked scans run the identical body
        params, opt_state = carry
        key = jax.random.fold_in(
            jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(tcfg.seed + 7),
                                   cluster_idx), round_idx), h)
        toks = _gen_batch(key, tcfg.local_batch, tcfg.seq_len, 4,
                          data_tables,
                          None if bias_all is None
                          else bias_all[cluster_idx])
        batch = {"tokens": toks}
        if cfg.modality != "text":
            emb = jax.random.normal(
                key, (tcfg.local_batch, cfg.n_frontend_tokens,
                      cfg.d_model), jnp.float32) * 0.02
            batch["frontend"] = emb
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt_state = adamw.update(g, opt_state, params,
                                         lr=tcfg.inner_lr)
        return (params, opt_state), loss

    def one_cluster(params, opt_state, cluster_idx, round_idx):
        step = lambda carry, h: step_body(carry, h, cluster_idx, round_idx)
        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state), jnp.arange(tcfg.h_steps))
        return params, opt_state, losses

    def one_cluster_h(params, opt_state, cluster_idx, round_idx, h_c):
        step = lambda carry, h: step_body(carry, h, cluster_idx, round_idx)
        (params, opt_state), mean_loss = diloco.masked_local_steps(
            step, (params, opt_state), tcfg.h_steps, h_c)
        return params, opt_state, mean_loss

    def inner_fn(params, inner_opt_stacked, round_idx):
        f = lambda opt, ci: one_cluster(params, opt, ci, round_idx)
        params_s, opt_s, losses = jax.vmap(f)(
            inner_opt_stacked, jnp.arange(tcfg.n_clusters))
        return params_s, opt_s, losses

    if h_vec is None:
        return inner_fn

    h_arr = jnp.asarray(h_vec, jnp.int32)

    def inner_fn_h(params, inner_opt_stacked, round_idx):
        f = lambda opt, ci, hc: one_cluster_h(params, opt, ci, round_idx,
                                              hc)
        params_s, opt_s, mean_losses = jax.vmap(f)(
            inner_opt_stacked, jnp.arange(tcfg.n_clusters), h_arr)
        return params_s, opt_s, mean_losses

    return inner_fn_h


def make_pp_inner_fn(cfg: ModelConfig, tcfg: TrainConfig, data_tables,
                     mesh, pcfg, h_vec=None):
    """Pipeline-parallel counterpart of ``make_inner_fn``: the same
    per-(cluster, round, step) PRNG data stream, but every inner step runs
    through ``parallel.inner_engine.make_pp_train_step`` (the shard_map
    GPipe loss on the unit mesh) instead of the single-replica loss, and
    the clusters are UNROLLED python-side rather than vmapped — vmapping
    would batch the pipeline matmuls into a different (~1 ulp) program
    (see ``inner_engine.make_pp_inner_fns``).  Numerics vs the scalar
    engine are tolerance-level, not bitwise (inner_engine module doc)."""
    from repro.data.synthetic import _gen_batch
    from repro.parallel import inner_engine as IE

    branching = 4
    bias_all = _hetero_bias(tcfg, branching)
    train_step = IE.make_pp_train_step(cfg, mesh, pcfg,
                                       inner_lr=tcfg.inner_lr)

    def step_body(carry, h, cluster_idx, round_idx):
        params, opt_state = carry
        key = jax.random.fold_in(
            jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(tcfg.seed + 7),
                                   cluster_idx), round_idx), h)
        toks = _gen_batch(key, tcfg.local_batch, tcfg.seq_len, branching,
                          data_tables,
                          None if bias_all is None
                          else bias_all[cluster_idx])
        params, opt_state, loss = train_step(params, opt_state, toks)
        return (params, opt_state), loss

    def one_cluster(params, opt_state, cluster_idx, round_idx):
        step = lambda carry, h: step_body(carry, h, cluster_idx, round_idx)
        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state), jnp.arange(tcfg.h_steps))
        return params, opt_state, losses

    def one_cluster_h(params, opt_state, cluster_idx, round_idx, h_c):
        step = lambda carry, h: step_body(carry, h, cluster_idx, round_idx)
        (params, opt_state), mean_loss = diloco.masked_local_steps(
            step, (params, opt_state), tcfg.h_steps, h_c)
        return params, opt_state, mean_loss

    def _stack(trees):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    if h_vec is None:
        def inner_fn(params, inner_opt_stacked, round_idx):
            outs = [one_cluster(params,
                                diloco.take_row(inner_opt_stacked, c),
                                jnp.asarray(c, jnp.int32), round_idx)
                    for c in range(tcfg.n_clusters)]
            return (_stack([o[0] for o in outs]),
                    _stack([o[1] for o in outs]),
                    _stack([o[2] for o in outs]))

        return inner_fn

    h_list = [int(h) for h in h_vec]

    def inner_fn_h(params, inner_opt_stacked, round_idx):
        outs = [one_cluster_h(params,
                              diloco.take_row(inner_opt_stacked, c),
                              jnp.asarray(c, jnp.int32), round_idx,
                              jnp.asarray(h_list[c], jnp.int32))
                for c in range(tcfg.n_clusters)]
        return (_stack([o[0] for o in outs]),
                _stack([o[1] for o in outs]),
                _stack([o[2] for o in outs]))

    return inner_fn_h


def cluster_mean(stacked_tree):
    return jax.tree.map(lambda x: x.mean(axis=0), stacked_tree)


@dataclass
class RunResult:
    losses: List[float]
    eval_losses: List[float]
    wire_bytes_per_round: List[int]
    h_per_round: List[int]
    r_per_round: List[int]
    wall_s: float
    # per-cluster executed local steps per round (heterogeneous h_policy
    # only; empty under the global policy) — h_per_round stays the budget
    h_by_per_round: List[tuple] = field(default_factory=list)


def run_diloco_training(cfg: ModelConfig, tcfg: TrainConfig, n_rounds: int,
                        eval_every: int = 1) -> RunResult:
    """Full training run; returns per-round mean train loss + eval loss on a
    held-out stream + per-round wire bytes (feeds the throughput model)."""
    if tcfg.inner_engine not in ("scalar", "pp"):
        raise ValueError(f"inner_engine must be 'scalar' or 'pp', got "
                         f"{tcfg.inner_engine!r}")
    pp = tcfg.inner_engine == "pp"
    rng = jax.random.PRNGKey(tcfg.seed)
    if pp:
        if cfg.modality != "text":
            raise ValueError("inner_engine='pp' supports text models only "
                             "(the pipeline loss takes a token batch)")
        from repro.parallel import inner_engine as IE
        from repro.parallel import pipeline as PP
        pcfg = PP.PipelineConfig(n_stages=tcfg.pp_stages,
                                 n_micro=tcfg.pp_micro)
        mesh = IE.unit_mesh(pcfg)      # raises if too few faked devices
        params = PP.init_pp_params(cfg, rng, pcfg)
    else:
        params = M.init_params(cfg, rng)
    compressor = make_compressor(tcfg.compressor, **tcfg.compressor_kw)

    # per-cluster inner optimizer states (stacked)
    opt0 = adamw.init(params)
    inner_stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (tcfg.n_clusters,) + x.shape).copy(),
        opt0)

    state = diloco.init_state(params, inner_stacked, tcfg.n_clusters,
                              compressor)
    rcfg = diloco.RoundConfig(
        outer_lr=tcfg.outer_lr, outer_momentum=tcfg.outer_momentum,
        delay=tcfg.delay, compress=tcfg.compress,
        error_feedback=tcfg.error_feedback)

    data = SyntheticLM(cfg.vocab_size, tcfg.seq_len, tcfg.local_batch,
                       seed=tcfg.seed)
    eval_data = SyntheticLM(cfg.vocab_size, tcfg.seq_len, 16,
                            seed=tcfg.seed, data_shard=9999)
    eval_batch = with_frontend(eval_data.next_batch(), cfg)

    # heterogeneous local-step schedule: the single-host trainer has no
    # modeled clock, so the per-cluster step times come from the config
    # (measured on the real sites, or assumed); they are static, hence one
    # schedule serves every round
    h_by = None
    if tcfg.h_policy != "global":
        t_by = (tcfg.step_times if tcfg.step_times is not None
                else (1.0,) * tcfg.n_clusters)
        if len(t_by) != tcfg.n_clusters:
            raise ValueError(f"step_times has {len(t_by)} entries for "
                             f"{tcfg.n_clusters} clusters")
        h_map = adaptive.plan_h(
            adaptive.HSpec(policy=tcfg.h_policy, h_min=tcfg.h_min),
            tcfg.h_steps, np.asarray(t_by, float),
            np.ones(tcfg.n_clusters, bool))
        h_by = tuple(h_map[c] for c in range(tcfg.n_clusters))
    # uniform-at-budget schedules run the plain scan (bitwise today's
    # path); only a genuinely heterogeneous schedule pays the masked
    # program — the same dispatch rule the simulator backends apply
    uniform = h_by is None or all(h == tcfg.h_steps for h in h_by)
    if pp:
        inner_fn = make_pp_inner_fn(cfg, tcfg, data.table, mesh, pcfg,
                                    h_vec=None if uniform else h_by)
    else:
        inner_fn = make_inner_fn(cfg, tcfg, data.table,
                                 h_vec=None if uniform else h_by)

    def _round(state, rank_scalar):
        return diloco.diloco_round(state, inner_fn, compressor,
                                   cluster_mean, rcfg, rank_scalar)

    round_jit = jax.jit(_round)
    if pp:
        pp_eval_loss = PP.make_pp_loss(cfg, mesh, pcfg,
                                       cluster_stacked=False)
        eval_jit = jax.jit(lambda p: pp_eval_loss(p, eval_batch["tokens"]))
    else:
        eval_jit = jax.jit(lambda p: M.loss_fn(p, cfg, eval_batch)[0])

    ada_cfg = adaptive.AdaGradCmpConfig(
        r1=getattr(compressor, "rank", 64), h1=tcfg.h_steps,
        mode=tcfg.adaptive_mode, window=tcfg.adaptive_window)
    ada_state = adaptive.AdaGradCmpState.create(ada_cfg)

    shapes = tree_shapes(params)
    losses, evals, wires, hs, rs, h_rows = [], [], [], [], [], []
    t0 = time.time()
    rank_scalar = jnp.asarray(ada_state.r_t, jnp.int32)
    for r in range(n_rounds):
        # the controller state ENTERING the round is what this round
        # executes (rank_scalar above was derived from it); log that, not
        # the post-observe state — which is round r+1's budget
        r_exec, h_exec = ada_state.r_t, ada_state.h_t
        state, round_losses = round_jit(state, rank_scalar)
        losses.append(float(np.mean(np.asarray(round_losses))))
        evals.append(float(eval_jit(state.params)))
        wires.append(compressor.wire_bytes(
            shapes, rank=r_exec if tcfg.adaptive else None)
            if tcfg.compress else
            sum(int(np.prod(s)) * 4 for s in shapes.values()))
        hs.append(h_exec if tcfg.adaptive else tcfg.h_steps)
        rs.append(r_exec)
        _log.debug(f"round {r}: loss={losses[-1]:.4f} eval={evals[-1]:.4f}",
                   round=r, loss=losses[-1], eval_loss=evals[-1],
                   wire_bytes=wires[-1], h=hs[-1], rank=rs[-1])
        if h_by is not None:
            h_rows.append(h_by)
        if tcfg.adaptive and tcfg.compress:
            ada_state = adaptive.observe_mean_pseudo_grad(
                ada_state, cluster_mean(state.delta_pending), ada_cfg)
            rank_scalar = jnp.asarray(ada_state.r_t, jnp.int32)
    return RunResult(losses, evals, wires, hs, rs, time.time() - t0,
                     h_by_per_round=h_rows)


def run_allreduce_training(cfg: ModelConfig, tcfg: TrainConfig,
                           n_steps: int) -> RunResult:
    """Vanilla synchronous AllReduce baseline (paper's first baseline): the
    D clusters' gradients are averaged every step."""
    rng = jax.random.PRNGKey(tcfg.seed)
    params = M.init_params(cfg, rng)
    opt = adamw.init(params)
    data = [SyntheticLM(cfg.vocab_size, tcfg.seq_len, tcfg.local_batch,
                        seed=tcfg.seed, data_shard=i, hetero=tcfg.hetero)
            for i in range(tcfg.n_clusters)]
    eval_data = SyntheticLM(cfg.vocab_size, tcfg.seq_len, 16,
                            seed=tcfg.seed, data_shard=9999)
    eval_batch = with_frontend(eval_data.next_batch(), cfg)

    @jax.jit
    def step(params, opt, toks_stacked):
        def loss_one(p, toks):
            return M.loss_fn(p, cfg, {"tokens": toks})[0]

        def mean_loss(p):
            return jnp.mean(jax.vmap(lambda t: loss_one(p, t))(toks_stacked))

        loss, g = jax.value_and_grad(mean_loss)(params)
        params, opt = adamw.update(g, opt, params, lr=tcfg.inner_lr)
        return params, opt, loss

    eval_jit = jax.jit(lambda p: M.loss_fn(p, cfg, eval_batch)[0])
    shapes = tree_shapes(params)
    wire = sum(int(np.prod(s)) * 4 for s in shapes.values())
    losses, evals = [], []
    t0 = time.time()
    for s in range(n_steps):
        toks = jnp.stack([d.next_batch()["tokens"] for d in data])
        params, opt, loss = step(params, opt, toks)
        losses.append(float(loss))
        evals.append(float(eval_jit(params)))
    return RunResult(losses, evals, [wire] * n_steps, [1] * n_steps,
                     [0] * n_steps, time.time() - t0)


def run_compressed_ddp_training(cfg: ModelConfig, tcfg: TrainConfig,
                                n_steps: int) -> RunResult:
    """CocktailSGD-style baseline (paper §4.1.3): NO local training — every
    step each cluster compresses its gradient (with error feedback), the
    compressed gradients are averaged, and a shared AdamW applies them."""
    rng = jax.random.PRNGKey(tcfg.seed)
    params = M.init_params(cfg, rng)
    opt = adamw.init(params)
    compressor = make_compressor(tcfg.compressor, **tcfg.compressor_kw)
    comp_state0 = compressor.init_state(params)
    comp_state = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (tcfg.n_clusters,) + x.shape).copy(),
        comp_state0)
    error = jax.tree.map(
        lambda p: jnp.zeros((tcfg.n_clusters,) + p.shape, jnp.float32),
        params)
    data = [SyntheticLM(cfg.vocab_size, tcfg.seq_len, tcfg.local_batch,
                        seed=tcfg.seed, data_shard=i, hetero=tcfg.hetero)
            for i in range(tcfg.n_clusters)]
    eval_data = SyntheticLM(cfg.vocab_size, tcfg.seq_len, 16,
                            seed=tcfg.seed, data_shard=9999)
    eval_batch = with_frontend(eval_data.next_batch(), cfg)

    @jax.jit
    def step(params, opt, error, comp_state, toks_stacked):
        def grad_one(toks):
            (l, _), g = jax.value_and_grad(
                lambda p: M.loss_fn(p, cfg, {"tokens": toks}),
                has_aux=True)(params)
            return l, g

        losses, grads = jax.vmap(grad_one)(toks_stacked)   # per cluster
        with_err = jax.tree.map(lambda g, e: g + e, grads, error)
        comp_fn = lambda d, s: compressor.roundtrip(d, s, None)
        g_hat, comp_state = jax.vmap(comp_fn)(with_err, comp_state)
        error = jax.tree.map(lambda w, gh: w - gh, with_err, g_hat)
        g_mean = jax.tree.map(lambda x: x.mean(0), g_hat)
        params, opt = adamw.update(g_mean, opt, params, lr=tcfg.inner_lr)
        return params, opt, error, comp_state, losses.mean()

    eval_jit = jax.jit(lambda p: M.loss_fn(p, cfg, eval_batch)[0])
    shapes = tree_shapes(params)
    wire = compressor.wire_bytes(shapes)
    losses, evals = [], []
    t0 = time.time()
    for s in range(n_steps):
        toks = jnp.stack([d.next_batch()["tokens"] for d in data])
        params, opt, error, comp_state, loss = step(params, opt, error,
                                                    comp_state, toks)
        losses.append(float(loss))
        evals.append(float(eval_jit(params)))
    return RunResult(losses, evals, [wire] * n_steps, [1] * n_steps,
                     [0] * n_steps, time.time() - t0)
