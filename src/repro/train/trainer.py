"""Single-host DiLoCoX trainer: D clusters simulated by a vmap'd leading
axis (the same algebra the mesh runtime uses with the cluster dim sharded
over the "pod"/"data" axis — see DESIGN.md §3 and launch/train.py).

Drives the paper's convergence experiments (Fig. 3, Table 1): AllReduce,
OpenDiLoCo-style, CocktailSGD and DiLoCoX all run through ``diloco_round``
with different RoundConfig/Compressor settings.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adaptive, diloco
from repro.core.compression import Compressor, make_compressor, tree_shapes
from repro.data.synthetic import SyntheticLM, with_frontend
from repro.models import model as M
from repro.optim import adamw


@dataclass
class TrainConfig:
    n_clusters: int = 2           # D (paper's decentralized clusters)
    local_batch: int = 8
    seq_len: int = 64
    inner_lr: float = 1e-3
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    h_steps: int = 20             # H (local steps per round)
    compressor: str = "diloco_x"
    compressor_kw: Dict[str, Any] = field(default_factory=dict)
    delay: bool = True
    compress: bool = True
    error_feedback: bool = True
    adaptive: bool = False        # run AdaGradCmp (Alg. 3)
    adaptive_mode: str = "paper"
    adaptive_window: int = 5      # Alg. 3 window c
    hetero: float = 0.0           # per-cluster data heterogeneity (xi^2>0)
    seed: int = 0


def make_inner_fn(cfg: ModelConfig, tcfg: TrainConfig, data_tables):
    """Returns inner_fn(params, inner_opt_stacked, round_idx) -> (stacked
    params after H local AdamW steps per cluster, new inner state).
    Data is drawn deterministically from per-cluster PRNG streams; with
    tcfg.hetero > 0 each cluster prefers a different successor slot
    (Assumption 3.3 heterogeneity)."""
    from repro.data.synthetic import _gen_batch

    branching = 4
    if tcfg.hetero > 0:
        base = jnp.zeros((tcfg.n_clusters, branching))
        boost = jnp.log(1.0 + tcfg.hetero * branching
                        / (1 - tcfg.hetero + 1e-9))
        bias_all = jax.vmap(
            lambda i: base[0].at[i % branching].set(boost))(
            jnp.arange(tcfg.n_clusters))
    else:
        bias_all = None

    def one_cluster(params, opt_state, cluster_idx, round_idx):
        def step(carry, h):
            params, opt_state = carry
            key = jax.random.fold_in(
                jax.random.fold_in(
                    jax.random.fold_in(jax.random.PRNGKey(tcfg.seed + 7),
                                       cluster_idx), round_idx), h)
            toks = _gen_batch(key, tcfg.local_batch, tcfg.seq_len, 4,
                              data_tables,
                              None if bias_all is None
                              else bias_all[cluster_idx])
            batch = {"tokens": toks}
            if cfg.modality != "text":
                emb = jax.random.normal(
                    key, (tcfg.local_batch, cfg.n_frontend_tokens,
                          cfg.d_model), jnp.float32) * 0.02
                batch["frontend"] = emb
            (loss, _), g = jax.value_and_grad(
                lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
            params, opt_state = adamw.update(g, opt_state, params,
                                             lr=tcfg.inner_lr)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state), jnp.arange(tcfg.h_steps))
        return params, opt_state, losses

    def inner_fn(params, inner_opt_stacked, round_idx):
        f = lambda opt, ci: one_cluster(params, opt, ci, round_idx)
        params_s, opt_s, losses = jax.vmap(f)(
            inner_opt_stacked, jnp.arange(tcfg.n_clusters))
        return params_s, opt_s, losses

    return inner_fn


def cluster_mean(stacked_tree):
    return jax.tree.map(lambda x: x.mean(axis=0), stacked_tree)


@dataclass
class RunResult:
    losses: List[float]
    eval_losses: List[float]
    wire_bytes_per_round: List[int]
    h_per_round: List[int]
    r_per_round: List[int]
    wall_s: float


def run_diloco_training(cfg: ModelConfig, tcfg: TrainConfig, n_rounds: int,
                        eval_every: int = 1) -> RunResult:
    """Full training run; returns per-round mean train loss + eval loss on a
    held-out stream + per-round wire bytes (feeds the throughput model)."""
    rng = jax.random.PRNGKey(tcfg.seed)
    params = M.init_params(cfg, rng)
    compressor = make_compressor(tcfg.compressor, **tcfg.compressor_kw)

    # per-cluster inner optimizer states (stacked)
    opt0 = adamw.init(params)
    inner_stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (tcfg.n_clusters,) + x.shape).copy(),
        opt0)

    state = diloco.init_state(params, inner_stacked, tcfg.n_clusters,
                              compressor)
    rcfg = diloco.RoundConfig(
        outer_lr=tcfg.outer_lr, outer_momentum=tcfg.outer_momentum,
        delay=tcfg.delay, compress=tcfg.compress,
        error_feedback=tcfg.error_feedback)

    data = SyntheticLM(cfg.vocab_size, tcfg.seq_len, tcfg.local_batch,
                       seed=tcfg.seed)
    eval_data = SyntheticLM(cfg.vocab_size, tcfg.seq_len, 16,
                            seed=tcfg.seed, data_shard=9999)
    eval_batch = with_frontend(eval_data.next_batch(), cfg)
    inner_fn = make_inner_fn(cfg, tcfg, data.table)

    def _round(state, rank_scalar):
        return diloco.diloco_round(state, inner_fn, compressor,
                                   cluster_mean, rcfg, rank_scalar)

    round_jit = jax.jit(_round)
    eval_jit = jax.jit(lambda p: M.loss_fn(p, cfg, eval_batch)[0])

    ada_cfg = adaptive.AdaGradCmpConfig(
        r1=getattr(compressor, "rank", 64), h1=tcfg.h_steps,
        mode=tcfg.adaptive_mode, window=tcfg.adaptive_window)
    ada_state = adaptive.AdaGradCmpState.create(ada_cfg)

    shapes = tree_shapes(params)
    losses, evals, wires, hs, rs = [], [], [], [], []
    t0 = time.time()
    rank_scalar = jnp.asarray(ada_state.r_t, jnp.int32)
    for r in range(n_rounds):
        # the controller state ENTERING the round is what this round
        # executes (rank_scalar above was derived from it); log that, not
        # the post-observe state — which is round r+1's budget
        r_exec, h_exec = ada_state.r_t, ada_state.h_t
        state, round_losses = round_jit(state, rank_scalar)
        losses.append(float(np.mean(np.asarray(round_losses))))
        evals.append(float(eval_jit(state.params)))
        wires.append(compressor.wire_bytes(
            shapes, rank=r_exec if tcfg.adaptive else None)
            if tcfg.compress else
            sum(int(np.prod(s)) * 4 for s in shapes.values()))
        hs.append(h_exec if tcfg.adaptive else tcfg.h_steps)
        rs.append(r_exec)
        if tcfg.adaptive and tcfg.compress:
            ada_state = adaptive.observe_mean_pseudo_grad(
                ada_state, cluster_mean(state.delta_pending), ada_cfg)
            rank_scalar = jnp.asarray(ada_state.r_t, jnp.int32)
    return RunResult(losses, evals, wires, hs, rs, time.time() - t0)


def run_allreduce_training(cfg: ModelConfig, tcfg: TrainConfig,
                           n_steps: int) -> RunResult:
    """Vanilla synchronous AllReduce baseline (paper's first baseline): the
    D clusters' gradients are averaged every step."""
    rng = jax.random.PRNGKey(tcfg.seed)
    params = M.init_params(cfg, rng)
    opt = adamw.init(params)
    data = [SyntheticLM(cfg.vocab_size, tcfg.seq_len, tcfg.local_batch,
                        seed=tcfg.seed, data_shard=i, hetero=tcfg.hetero)
            for i in range(tcfg.n_clusters)]
    eval_data = SyntheticLM(cfg.vocab_size, tcfg.seq_len, 16,
                            seed=tcfg.seed, data_shard=9999)
    eval_batch = with_frontend(eval_data.next_batch(), cfg)

    @jax.jit
    def step(params, opt, toks_stacked):
        def loss_one(p, toks):
            return M.loss_fn(p, cfg, {"tokens": toks})[0]

        def mean_loss(p):
            return jnp.mean(jax.vmap(lambda t: loss_one(p, t))(toks_stacked))

        loss, g = jax.value_and_grad(mean_loss)(params)
        params, opt = adamw.update(g, opt, params, lr=tcfg.inner_lr)
        return params, opt, loss

    eval_jit = jax.jit(lambda p: M.loss_fn(p, cfg, eval_batch)[0])
    shapes = tree_shapes(params)
    wire = sum(int(np.prod(s)) * 4 for s in shapes.values())
    losses, evals = [], []
    t0 = time.time()
    for s in range(n_steps):
        toks = jnp.stack([d.next_batch()["tokens"] for d in data])
        params, opt, loss = step(params, opt, toks)
        losses.append(float(loss))
        evals.append(float(eval_jit(params)))
    return RunResult(losses, evals, [wire] * n_steps, [1] * n_steps,
                     [0] * n_steps, time.time() - t0)


def run_compressed_ddp_training(cfg: ModelConfig, tcfg: TrainConfig,
                                n_steps: int) -> RunResult:
    """CocktailSGD-style baseline (paper §4.1.3): NO local training — every
    step each cluster compresses its gradient (with error feedback), the
    compressed gradients are averaged, and a shared AdamW applies them."""
    rng = jax.random.PRNGKey(tcfg.seed)
    params = M.init_params(cfg, rng)
    opt = adamw.init(params)
    compressor = make_compressor(tcfg.compressor, **tcfg.compressor_kw)
    comp_state0 = compressor.init_state(params)
    comp_state = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (tcfg.n_clusters,) + x.shape).copy(),
        comp_state0)
    error = jax.tree.map(
        lambda p: jnp.zeros((tcfg.n_clusters,) + p.shape, jnp.float32),
        params)
    data = [SyntheticLM(cfg.vocab_size, tcfg.seq_len, tcfg.local_batch,
                        seed=tcfg.seed, data_shard=i, hetero=tcfg.hetero)
            for i in range(tcfg.n_clusters)]
    eval_data = SyntheticLM(cfg.vocab_size, tcfg.seq_len, 16,
                            seed=tcfg.seed, data_shard=9999)
    eval_batch = with_frontend(eval_data.next_batch(), cfg)

    @jax.jit
    def step(params, opt, error, comp_state, toks_stacked):
        def grad_one(toks):
            (l, _), g = jax.value_and_grad(
                lambda p: M.loss_fn(p, cfg, {"tokens": toks}),
                has_aux=True)(params)
            return l, g

        losses, grads = jax.vmap(grad_one)(toks_stacked)   # per cluster
        with_err = jax.tree.map(lambda g, e: g + e, grads, error)
        comp_fn = lambda d, s: compressor.roundtrip(d, s, None)
        g_hat, comp_state = jax.vmap(comp_fn)(with_err, comp_state)
        error = jax.tree.map(lambda w, gh: w - gh, with_err, g_hat)
        g_mean = jax.tree.map(lambda x: x.mean(0), g_hat)
        params, opt = adamw.update(g_mean, opt, params, lr=tcfg.inner_lr)
        return params, opt, error, comp_state, losses.mean()

    eval_jit = jax.jit(lambda p: M.loss_fn(p, cfg, eval_batch)[0])
    shapes = tree_shapes(params)
    wire = compressor.wire_bytes(shapes)
    losses, evals = [], []
    t0 = time.time()
    for s in range(n_steps):
        toks = jnp.stack([d.next_batch()["tokens"] for d in data])
        params, opt, error, comp_state, loss = step(params, opt, error,
                                                    comp_state, toks)
        losses.append(float(loss))
        evals.append(float(eval_jit(params)))
    return RunResult(losses, evals, [wire] * n_steps, [1] * n_steps,
                     [0] * n_steps, time.time() - t0)
