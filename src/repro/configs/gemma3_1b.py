"""gemma3-1b [dense] — 5:1 local:global sliding window, 128k ctx
[hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    sliding_window=512, global_every=6,      # layer idx % 6 == 5 -> global
    rope_theta=1_000_000.0, tie_embeddings=True,
    sub_quadratic=True,   # sliding-window locals; 4 global layers keep full cache
)
