"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone
[arXiv:2308.11596]. Frontend (mel+conv codec) is a stub per spec: inputs are
precomputed frame embeddings of shape (B, n_frames, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    source="arXiv:2308.11596",
    n_layers=24, n_enc_layers=24, is_encdec=True,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    norm="layernorm", modality="audio",
    n_frontend_tokens=1024,       # encoder frames per example
)
