"""stablelm-12b [dense] — parallel residual [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab_size=100352, head_dim=160,
    norm="layernorm", parallel_residual=True,
)
