"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    source="arXiv:2411.15242",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm=SSMConfig(kind="mamba2", d_state=64, expand=2, chunk=64),
    hybrid=HybridConfig(shared_attn_period=6, shared_d_ff=8192),
    sub_quadratic=True,
)
