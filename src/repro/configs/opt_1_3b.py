"""opt-1.3b — the paper's small-scale experiment model (§4.1.1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="opt-1.3b", family="dense",
    source="arXiv:2205.01068 (paper §4.1.1)",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=50272, head_dim=64, norm="layernorm",
)
