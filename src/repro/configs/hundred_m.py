"""~100M-param dense model for the end-to-end example driver (deliverable
b: "train ~100M model for a few hundred steps")."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hundred-m", family="dense",
    source="examples/pretrain_diloco.py",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=3072, vocab_size=8192, head_dim=64,
)
