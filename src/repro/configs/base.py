"""Configuration system for the DiLoCoX reproduction framework.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
The config fully determines the parameter pytree and the forward semantics;
``reduced()`` produces the CPU-smoke variant of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0          # per-expert hidden dim
    n_shared_experts: int = 0     # deepseek-style always-on experts
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    d_ff_dense: int = 0           # hidden dim of dense path (arctic residual /
                                  # deepseek first dense layer)
    first_k_dense: int = 0        # deepseek: first k layers use dense FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64       # decoupled rope dims per head
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"          # mamba2 | xlstm
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    n_ssm_heads: int = 0          # mamba2 heads (0 -> d_inner//64)
    chunk: int = 64               # chunked scan length
    # xlstm: within each unit of `xlstm_unit` layers, the last is sLSTM
    xlstm_unit: int = 8


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2: shared-weight attention block applied every `period` layers."""
    shared_attn_period: int = 6
    shared_d_ff: int = 0          # d_ff of the shared block's MLP


@dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    family: str = "dense"         # dense | moe | hybrid | audio | vlm | ssm
    source: str = ""              # citation for the config
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 512
    eos_id: int = 2               # end-of-sequence id the serving loops stop on
    head_dim: int = 0             # 0 -> d_model // n_heads
    # attention flavour
    attn_type: str = "gqa"        # gqa | mla
    rope_theta: float = 10_000.0
    mrope: bool = False           # qwen2-vl M-RoPE (t,h,w sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # sliding-window pattern: window size (0 = full attention) and the
    # local:global pattern period (gemma3: 5 local then 1 global)
    sliding_window: int = 0
    global_every: int = 0         # 0 = all layers same; k>0: layer is global
                                  # iff (idx % k == k-1)
    # norms / residual structure
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    parallel_residual: bool = False   # stablelm-2 style attn+FFN in parallel
    tie_embeddings: bool = False
    # enc-dec (audio)
    is_encdec: bool = False
    n_enc_layers: int = 0
    # modality frontend stubs
    modality: str = "text"        # text | audio | vlm
    n_frontend_tokens: int = 0    # patches / frames prepended for audio & vlm
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"   # bf16 on TPU targets
    # which layers are SSM vs attention for hybrid stacks; "all_ssm" for
    # zamba-style (attention lives in the shared block)
    sub_quadratic: bool = False   # eligible for long_500k

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_ff_resolved(self) -> int:
        return self.d_ff

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (<=2 layers,
        d_model<=512, <=4 experts)."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=4,
            n_kv_heads=min(max(1, self.n_kv_heads), 2),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=32,
            n_frontend_tokens=min(self.n_frontend_tokens, 8) if self.n_frontend_tokens else 0,
        )
        if self.is_encdec:
            kw["n_enc_layers"] = 2
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 128),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                d_ff_dense=min(self.moe.d_ff_dense, 128) if self.moe.d_ff_dense else 0,
                first_k_dense=min(self.moe.first_k_dense, 1),
            )
        if self.mla is not None:
            kw["mla"] = replace(
                self.mla, kv_lora_rank=32, q_lora_rank=32,
                rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
            kw["head_dim"] = 0
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, chunk=16, xlstm_unit=2)
        if self.hybrid is not None:
            kw["hybrid"] = replace(self.hybrid, shared_attn_period=2,
                                   shared_d_ff=min(self.hybrid.shared_d_ff or 256, 256))
        if self.mrope:
            kw["mrope_sections"] = (4, 6, 6)   # sums to reduced head_dim/2
        if self.global_every:
            kw["global_every"] = 2
        if self.sliding_window:
            kw["sliding_window"] = 8
        return replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and memory plans)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "granite-3-8b", "deepseek-v2-236b", "arctic-480b", "stablelm-12b",
    "phi3-medium-14b", "zamba2-1.2b", "seamless-m4t-large-v2",
    "qwen2-vl-7b", "xlstm-1.3b", "gemma3-1b",
    # the paper's own models
    "opt-1.3b", "qwen1.5-107b",
]

_MODULE_FOR = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
               for a in ARCH_IDS}
# extra configs usable via --arch but not part of the assigned matrix
_MODULE_FOR["hundred-m"] = "repro.configs.hundred_m"


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(_MODULE_FOR[arch])
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Which (arch x shape) pairs run; mirrors DESIGN.md skip table."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention (DESIGN.md)"
    return True, ""
