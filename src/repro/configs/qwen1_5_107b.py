"""qwen1.5-107b — the paper's modified Qwen1.5 (80 -> 78 layers, §4.1.1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-107b", family="dense",
    source="paper §4.1.1 (modified Qwen1.5-110B, 78 layers)",
    n_layers=78, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab_size=152064, head_dim=128,
)
