"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, no FFN [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    source="arXiv:2405.04517",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=512,
    ssm=SSMConfig(kind="xlstm", d_state=0, expand=2, chunk=64, xlstm_unit=8),
    sub_quadratic=True,
)
