"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864,                     # dense-residual hidden dim
    vocab_size=32000, head_dim=128,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True, d_ff_dense=4864),
)
