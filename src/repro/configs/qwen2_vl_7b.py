"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].
Vision tower is a stub per spec: inputs include precomputed patch embeddings
(B, n_patches, d_model) merged into the prefix of the token sequence."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    source="arXiv:2409.12191",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    mrope=True, mrope_sections=(16, 24, 24),
    modality="vlm", n_frontend_tokens=256,
)
