"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434]."""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    source="arXiv:2405.04434",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288,                    # dense first-layer FFN hidden
    vocab_size=102400,
    attn_type="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                  n_shared_experts=2, first_k_dense=1, d_ff_dense=12288),
)
