"""Paged-gather decode attention: ref path (bitwise vs ``decode_gqa``) and
a Pallas gather-attention kernel behind ``backend={"ref","pallas"}``.

Shapes (per segment unit):
  q            (S, 1, H, dh)      one query token per slot
  cache k/v    (1 + n_pages, page_size, KV, dh)   page 0 = scratch
  page_tables  (S, max_pages)     int32; 0 = unallocated -> scratch page
  lengths      (S,)               tokens already cached (== query position)
  active       (S,)               bool slot mask

Masking contract (jit-shape-stable — one executable for every occupancy):
the gathered key position is computed from the *table column index*
(``page * page_size + slot``), never from page contents, and the additive
``k_pos <= q_pos`` bias kills every position past ``lengths`` — including
whatever the scratch page holds for unallocated entries (finite garbage;
``exp(-1e30)`` underflows to exactly 0.0, so masked lanes contribute
exact zeros). Inactive slots read the all-zero table row -> scratch page
and their output is discarded by the scheduler.

The ref path gathers each sequence's pages into a contiguous
``(S, max_pages*page_size, KV, dh)`` view and reuses the *exact*
``_mask_bias`` + ``grouped_attend`` that ``attention.decode_gqa`` runs:
with ``max_pages * page_size == s_max`` the two are bitwise-identical,
which is what the paged ≡ dense greedy-equivalence gate asserts.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models import attention as attn

NEG_INF = attn.NEG_INF


# ---------------------------------------------------------------------------
# scatter this step's K/V rows into their page slots
# ---------------------------------------------------------------------------

def write_kv(cache, k_new, v_new, page_tables, lengths, active):
    """cache {"k","v"}: (P, ps, KV, dh); k_new/v_new: (S, KV, dh). Writes
    row i at (table[i, len_i // ps], len_i % ps); inactive rows are routed
    to the scratch page (never read unmasked)."""
    ps = cache["k"].shape[1]
    log_page = lengths // ps
    slot = lengths % ps
    phys = jnp.take_along_axis(page_tables, log_page[:, None], axis=1)[:, 0]
    phys = jnp.where(active, phys, 0)
    return {"k": cache["k"].at[phys, slot].set(
                k_new.astype(cache["k"].dtype)),
            "v": cache["v"].at[phys, slot].set(
                v_new.astype(cache["v"].dtype))}


# ---------------------------------------------------------------------------
# ref backend
# ---------------------------------------------------------------------------

def ref_paged_attention(q, cache, page_tables, lengths, *, window: int = 0):
    """Gather pages -> contiguous per-sequence KV, then the same
    ``_mask_bias`` + ``grouped_attend`` as the dense decode path.
    Returns (S, 1, H, dh) pre-``wo`` attention output."""
    S, P = page_tables.shape
    ps = cache["k"].shape[1]
    k = cache["k"][page_tables].reshape(S, P * ps, *cache["k"].shape[2:])
    v = cache["v"][page_tables].reshape(S, P * ps, *cache["v"].shape[2:])
    pos = lengths[:, None]
    k_pos = jnp.arange(P * ps, dtype=jnp.int32)[None, :]
    bias = attn._mask_bias(pos, k_pos, causal=True, window=window)
    return attn.grouped_attend(q, k, v, bias)


# ---------------------------------------------------------------------------
# pallas backend
# ---------------------------------------------------------------------------

def _paged_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, ps: int, n_pages: int, kv: int,
                  g: int, scale: float, window: int):
    """Grid (S, max_pages): one query row streams its pages (online
    softmax, flash recurrence); the page table is a scalar-prefetch input
    so each page's BlockSpec index map gathers the *physical* page."""
    s = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)             # (H, dh)
    k = k_ref[0].astype(jnp.float32)             # (ps, KV, dh)
    v = v_ref[0].astype(jnp.float32)
    dh = q.shape[-1]
    qg = q.reshape(kv, g, dh)
    # scores (KV, G, ps): batch over KV, contract dh
    sc = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32) * scale
    q_pos = len_ref[s]
    k_pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, (kv, g, ps), 2)
    ok = k_pos <= q_pos
    if window > 0:
        ok = ok & (k_pos > q_pos - window)
    sc = jnp.where(ok, sc, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(sc, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    pexp = jnp.exp(sc - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + pexp.sum(axis=-1)
    # (KV, G, ps) @ (ps, KV, dh) batched over KV -> (KV, G, dh)
    pv = jax.lax.dot_general(pexp, v, (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _flush():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = o.reshape(kv * g, dh).astype(o_ref.dtype)


def pallas_paged_attention(q, cache, page_tables, lengths, *,
                           window: int = 0, interpret: bool = True):
    """Same contract as ``ref_paged_attention`` (ulp-bounded, not bitwise:
    the online-softmax recurrence reassociates the reduction)."""
    S, _, H, dh = q.shape
    P = page_tables.shape[1]
    ps, KV = cache["k"].shape[1], cache["k"].shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, P),
        in_specs=[
            pl.BlockSpec((1, H, dh), lambda s, p, t, l: (s, 0, 0)),
            pl.BlockSpec((1, ps, KV, dh),
                         lambda s, p, t, l: (t[s, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, KV, dh),
                         lambda s, p, t, l: (t[s, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, dh), lambda s, p, t, l: (s, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),        # running max
            pltpu.VMEM((KV, G), jnp.float32),        # running denom
            pltpu.VMEM((KV, G, dh), jnp.float32),    # accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, ps=ps, n_pages=P, kv=KV, g=G,
                          scale=scale, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, H, dh), q.dtype),
        interpret=interpret,
    )(page_tables, lengths, q.reshape(S, H, dh), cache["k"], cache["v"])
    return out.reshape(S, 1, H, dh)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def paged_attention(q, cache, page_tables, lengths, *, window: int = 0,
                    backend: str = "ref", interpret: bool = True):
    if backend == "ref":
        return ref_paged_attention(q, cache, page_tables, lengths,
                                   window=window)
    if backend == "pallas":
        return pallas_paged_attention(q, cache, page_tables, lengths,
                                      window=window, interpret=interpret)
    raise ValueError(f"unknown paged-attention backend {backend!r}")
