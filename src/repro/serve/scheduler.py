"""Continuous-batching admission + per-sequence state machine (jax-free).

Every engine iteration is one fixed-shape device step over ``max_seqs``
slots; the scheduler decides what each slot feeds it:

  WAITING --admit--> PREFILL --last prompt token--> DECODE --EOS/len--> DONE

Prefill is *by decode*: an admitted sequence feeds one prompt token per
step (same executable as decode — one compiled step serves every phase and
occupancy). The model output of a prefill step is discarded except for the
last prompt token's, which is the sequence's first generated token.

Admission (``admit_ready``) is FIFO over the waiting queue, gated on
arrival step, a free slot, and the page manager's worst-case reservation
(page-exhaustion backpressure defers admission — head-of-line, so the
admission order stays deterministic and is fingerprinted for the CI
determinism gate). ``policy="static"`` is the classic static-batch
baseline: admit only when every slot is idle, then drain the whole wave —
used by ``benchmarks/serve_load.py`` to isolate the continuous-batching
win with the identical compiled step.

Arrival times are measured in *engine steps*, not wall clock, so a trace
replays identically on any machine.
"""
from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.pages import PageManager

WAITING, PREFILL, DECODE, DONE = "WAITING", "PREFILL", "DECODE", "DONE"


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    arrival: int = 0                 # engine step at which it becomes visible
    # filled in by the scheduler:
    state: str = WAITING
    generated: List[int] = field(default_factory=list)
    admit_step: Optional[int] = None
    first_token_step: Optional[int] = None
    done_step: Optional[int] = None
    admit_wall: Optional[float] = None
    first_token_wall: Optional[float] = None
    done_wall: Optional[float] = None
    finish_reason: Optional[str] = None          # "eos" | "length"

    @property
    def total_len(self) -> int:
        return len(self.prompt) + self.max_new


@dataclass
class _Slot:
    req: Request
    fed: int = 0         # tokens fed to the model so far (== cache length)


class Scheduler:
    def __init__(self, pages: PageManager, *, max_seqs: int,
                 eos_id: Optional[int] = None, policy: str = "continuous"):
        if policy not in ("continuous", "static"):
            raise ValueError(policy)
        self.pages = pages
        self.max_seqs = int(max_seqs)
        self.eos_id = eos_id
        self.policy = policy
        self.waiting: Deque[Request] = deque()
        self.slots: List[Optional[_Slot]] = [None] * self.max_seqs
        self.done: List[Request] = []
        self.admissions: List[Tuple[int, int, int]] = []  # (step, rid, slot)
        self.deferred = 0          # page-backpressure admission deferrals

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.state = WAITING
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    # -- admission ---------------------------------------------------------
    def admit_ready(self, now: int, wall: float = 0.0) -> int:
        """Admit FIFO-eligible requests into free slots; returns how many
        were admitted this step."""
        if self.policy == "static" and self.n_active:
            return 0
        n = 0
        while self.waiting and self.waiting[0].arrival <= now:
            free = [i for i, s in enumerate(self.slots) if s is None]
            if not free:
                break
            req = self.waiting[0]
            if not self.pages.can_admit(req.total_len):
                self.deferred += 1
                break          # head-of-line: keeps admission deterministic
            self.waiting.popleft()
            slot = free[0]
            self.pages.admit(slot, req.total_len)
            req.state = PREFILL
            req.admit_step = now
            req.admit_wall = wall
            self.slots[slot] = _Slot(req)
            self.admissions.append((now, req.rid, slot))
            n += 1
        return n

    # -- one engine step ---------------------------------------------------
    def plan_step(self):
        """Builds the fixed-shape step inputs ``(tokens, lengths, active)``
        (each ``(max_seqs,)``; inactive slots masked) and allocates the
        physical page each active slot's next token lands in. Returns None
        when no slot is active (e.g. all arrivals are in the future)."""
        tokens = np.zeros(self.max_seqs, np.int32)
        lengths = np.zeros(self.max_seqs, np.int32)
        active = np.zeros(self.max_seqs, bool)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            req = s.req
            tokens[i] = (req.prompt[s.fed] if s.fed < len(req.prompt)
                         else req.generated[-1])
            lengths[i] = s.fed
            active[i] = True
            self.pages.ensure(i, s.fed)
        if not active.any():
            return None
        return tokens, lengths, active

    def commit(self, next_tokens: Sequence[int], step: int,
               wall: float = 0.0) -> None:
        """Processes the device step's outputs: state transitions, EOS /
        length-cap finishes, slot + page recycling."""
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            req = s.req
            out = int(next_tokens[i])
            s.fed += 1
            if s.fed < len(req.prompt):
                continue           # mid-prefill: output is prompt-forced
            if req.state == PREFILL:
                req.state = DECODE
                req.first_token_step = step
                req.first_token_wall = wall
            req.generated.append(out)
            if self.eos_id is not None and out == self.eos_id:
                self._finish(i, step, wall, "eos")
            elif len(req.generated) >= req.max_new:
                self._finish(i, step, wall, "length")

    def _finish(self, slot: int, step: int, wall: float,
                reason: str) -> None:
        req = self.slots[slot].req
        req.state = DONE
        req.done_step = step
        req.done_wall = wall
        req.finish_reason = reason
        self.pages.release(slot)
        self.slots[slot] = None
        self.done.append(req)

    # -- determinism gate --------------------------------------------------
    def admission_fingerprint(self) -> str:
        h = hashlib.sha256()
        for step, rid, slot in self.admissions:
            h.update(f"{step}:{rid}:{slot};".encode())
        return h.hexdigest()[:16]
