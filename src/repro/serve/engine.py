"""Paged continuous-batching serve engine.

One jit-compiled, shape-stable decode step serves every phase and
occupancy: ``(params, caches, tokens(S,), lengths(S,), active(S,),
page_tables(S,P)) -> (next_tokens(S,), caches')`` with the cache buffers
donated (the page pool is updated in place, never copied per step).
Prefill is by decode — the scheduler feeds prompt tokens one per step —
so there is exactly one executable, compiled once.

The per-unit math mirrors ``model.decode_step`` + ``attention.decode_gqa``
operation for operation (same ``_qkv``/rope/mask/``grouped_attend``/
``apply_ffn_unit`` calls on the ref backend), which is what makes the
paged ≡ dense greedy-token equivalence gate bitwise on matching shapes
(``max_pages_per_seq * page_size == s_max``).
"""
from __future__ import annotations

import contextlib
import math
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import model as M
from repro.models.layers import apply_norm, apply_rope
from repro.serve import attention_paged as pa
from repro.serve.pages import PageManager
from repro.serve.scheduler import Request, Scheduler


def supports_paged(cfg: ModelConfig) -> Tuple[bool, str]:
    """Which architectures the paged engine serves. SSM/hybrid state is
    recurrent (nothing to page); MLA's latent cache and the enc-dec/mrope
    position machinery are follow-ups (serve/README.md)."""
    if cfg.family not in ("dense", "moe"):
        return False, f"family {cfg.family!r}: only dense/moe attention " \
                      f"stacks have a pageable KV cache"
    if cfg.attn_type != "gqa":
        return False, "mla latent cache is not paged yet"
    if cfg.is_encdec or cfg.modality != "text":
        return False, "enc-dec / multimodal prefill is not paged yet"
    if cfg.mrope:
        return False, "mrope positions are not paged yet"
    return True, ""


def init_kv_pages(cfg: ModelConfig, *, n_pages: int, page_size: int,
                  dtype=None) -> List[Dict[str, jnp.ndarray]]:
    """Per-segment paged KV stores ``(n_units, 1 + n_pages, ps, KV, dh)``.
    Index 0 along the page dim is the scratch page (PageManager contract);
    one physical page id addresses the same slot in every unit's store."""
    dt = jnp.dtype(dtype or cfg.param_dtype)
    hd = cfg.resolved_head_dim
    shape = (n_pages + 1, page_size, cfg.n_kv_heads, hd)
    return [{"k": jnp.zeros((s.n,) + shape, dt),
             "v": jnp.zeros((s.n,) + shape, dt)}
            for s in M.build_segments(cfg)]


def kv_pool_bytes(cfg: ModelConfig, *, n_pages: int, page_size: int,
                  dtype=None) -> int:
    dt = jnp.dtype(dtype or cfg.param_dtype)
    n_units = sum(s.n for s in M.build_segments(cfg))
    return (n_units * n_pages * page_size * cfg.n_kv_heads
            * cfg.resolved_head_dim * 2 * dt.itemsize)


def dense_kv_bytes(cfg: ModelConfig, *, n_seqs: int, s_max: int,
                   dtype=None) -> int:
    """What the dense serving loop keeps resident for the same concurrency:
    every sequence owns a full (s_max, KV, dh) strip per unit for its whole
    lifetime, whether it uses it or not."""
    dt = jnp.dtype(dtype or cfg.param_dtype)
    n_units = sum(s.n for s in M.build_segments(cfg))
    return (n_units * n_seqs * s_max * cfg.n_kv_heads
            * cfg.resolved_head_dim * 2 * dt.itemsize)


def make_paged_decode_step(cfg: ModelConfig, *, backend: str = "ref"):
    ok, why = supports_paged(cfg)
    if not ok:
        raise NotImplementedError(why)
    hd = cfg.resolved_head_dim
    segs = M.build_segments(cfg)

    def unit_step(p, x1, cache, lengths, active, page_tables, *,
                  window: int, use_moe: bool):
        # mirrors model decode_unit / attention.decode_gqa op-for-op
        h = apply_norm(p["ln1"], x1, cfg.norm)
        q, k_new, v_new = attn._qkv(p["attn"], h, cfg.n_heads,
                                    cfg.n_kv_heads, hd)
        pos = lengths[:, None]
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
        cache = pa.write_kv(cache, k_new[:, 0], v_new[:, 0], page_tables,
                            lengths, active)
        o = pa.paged_attention(q, cache, page_tables, lengths,
                               window=window, backend=backend)
        a = o.reshape(x1.shape[0], 1, -1) @ p["attn"]["wo"]
        if cfg.parallel_residual and not use_moe:
            f, _ = M.apply_ffn_unit(p, x1, cfg, use_moe=use_moe)
            x1 = x1 + a + f
        else:
            x1 = x1 + a
            f, _ = M.apply_ffn_unit(p, x1, cfg, use_moe=use_moe)
            x1 = x1 + f
        return x1, cache

    def step(params, caches, tokens, lengths, active, page_tables):
        x1 = M.embed_tokens(params, cfg, tokens[:, None])
        x1 = M.shard_act(x1, "act")
        new_caches = []
        for s, sp, cache in zip(segs, params["segments"], caches):
            window = cfg.sliding_window if s.kind == "local" else 0
            use_moe = s.kind == "moe"

            def scan_fn(x1, pc, _w=window, _m=use_moe):
                p, c = pc
                x1, c = unit_step(p, x1, c, lengths, active, page_tables,
                                  window=_w, use_moe=_m)
                return x1, c

            x1, nc = jax.lax.scan(scan_fn, x1, (sp, cache))
            new_caches.append(nc)
        logits = M.logits_fn(params, cfg, x1)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, new_caches

    return step


def _pct(vals, q):
    if not vals:
        return float("nan")
    return float(np.percentile(np.asarray(vals, np.float64), q))


class ServeEngine:
    """Ties the page manager, scheduler, and jitted paged step together.

    ``eos_id`` defaults to ``cfg.eos_id``; pass ``None`` to disable EOS
    (equivalence tests / fixed-length load traces). ``step_fn`` lets
    callers share one jitted executable across engines (the benchmark's
    continuous-vs-static fairness: identical compiled step, only the
    admission policy differs).
    """

    def __init__(self, params, cfg: ModelConfig, *, max_seqs: int,
                 page_size: int, n_pages: int, max_pages_per_seq: int,
                 backend: str = "ref", eos_id: Any = "cfg",
                 policy: str = "continuous", dtype=None, step_fn=None,
                 metrics=None, span=None):
        ok, why = supports_paged(cfg)
        if not ok:
            raise NotImplementedError(f"{cfg.name}: {why}")
        self.params = params
        self.cfg = cfg
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self._dtype = dtype
        self.pages = PageManager(n_pages, page_size, max_seqs,
                                 max_pages_per_seq)
        self.sched = Scheduler(self.pages, max_seqs=max_seqs,
                               eos_id=(cfg.eos_id if eos_id == "cfg"
                                       else eos_id),
                               policy=policy)
        self.caches = init_kv_pages(cfg, n_pages=n_pages,
                                    page_size=page_size, dtype=dtype)
        self._fn = step_fn if step_fn is not None else jax.jit(
            make_paged_decode_step(cfg, backend=backend),
            donate_argnums=(1,))
        self.step_count = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self._metrics = metrics
        self._span = (span if span is not None
                      else (lambda name, **kw: contextlib.nullcontext()))
        self._rid = 0

    # -- submission --------------------------------------------------------
    def submit(self, prompt, max_new: int, arrival: int = 0) -> Request:
        prompt = [int(t) for t in prompt]
        total = len(prompt) + int(max_new)
        cap = self.pages.max_pages_per_seq * self.page_size
        if total > cap:
            raise ValueError(f"request needs {total} tokens > "
                             f"max_pages_per_seq*page_size = {cap}")
        req = Request(rid=self._rid, prompt=prompt, max_new=int(max_new),
                      arrival=int(arrival))
        self._rid += 1
        self.sched.submit(req)
        return req

    # -- stepping ----------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration (admit -> plan -> device step -> commit).
        Returns False when there is nothing left to do."""
        sched = self.sched
        if not sched.has_work():
            return False
        with self._span("admit"):
            sched.admit_ready(self.step_count, time.monotonic())
        plan = sched.plan_step()
        if plan is None:
            # every remaining request arrives in the future: tick the clock
            self.step_count += 1
            return True
        tokens, lengths, active = plan
        with self._span("device_step", n_active=int(active.sum())):
            nxt, self.caches = self._fn(
                self.params, self.caches, jnp.asarray(tokens),
                jnp.asarray(lengths), jnp.asarray(active),
                jnp.asarray(self.pages.page_table))
            nxt = np.asarray(nxt)
        n_prefill = sum(1 for s in sched.slots
                        if s is not None and s.fed < len(s.req.prompt) - 1)
        n_active = int(active.sum())
        self.prefill_tokens += n_prefill
        self.decode_tokens += n_active - n_prefill
        with self._span("commit"):
            sched.commit(nxt, self.step_count, time.monotonic())
        if self._metrics is not None:
            m = self._metrics
            m.counter("repro_serve_steps").inc()
            m.counter("repro_serve_prefill_tokens").inc(n_prefill)
            m.counter("repro_serve_decode_tokens").inc(n_active - n_prefill)
            m.gauge("repro_serve_pages_in_use").set(self.pages.used_pages)
            m.gauge("repro_serve_waiting").set(len(sched.waiting))
        self.step_count += 1
        return True

    def run(self, max_steps: int = 100_000) -> Dict[str, Any]:
        t0 = time.monotonic()
        while self.step():
            if self.step_count >= max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} "
                                   f"steps")
        wall = time.monotonic() - t0
        return self.stats(wall)

    # -- reporting ---------------------------------------------------------
    def stats(self, wall_s: float) -> Dict[str, Any]:
        done = self.sched.done
        ttft_steps = [r.first_token_step - r.arrival for r in done
                      if r.first_token_step is not None]
        ttft_ms = [(r.first_token_wall - r.admit_wall) * 1e3 for r in done
                   if r.first_token_wall is not None]
        per_tok_ms = [(r.done_wall - r.first_token_wall) * 1e3
                      / max(1, len(r.generated) - 1) for r in done
                      if r.done_wall is not None and len(r.generated) > 1]
        steps = max(1, self.step_count)
        return {
            "requests_done": len(done),
            "steps": self.step_count,
            "wall_s": wall_s,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_tok_s": self.prefill_tokens / max(wall_s, 1e-9),
            "decode_tok_s": self.decode_tokens / max(wall_s, 1e-9),
            # deterministic throughput: both policies run the identical
            # compiled step, so tokens-per-step ratios ARE tokens/s ratios
            "decode_tok_per_step": self.decode_tokens / steps,
            "ttft_steps_p50": _pct(ttft_steps, 50),
            "ttft_steps_p99": _pct(ttft_steps, 99),
            "ttft_ms_p50": _pct(ttft_ms, 50),
            "ttft_ms_p99": _pct(ttft_ms, 99),
            "per_token_ms_p50": _pct(per_tok_ms, 50),
            "per_token_ms_p99": _pct(per_tok_ms, 99),
            "admission_fingerprint": self.sched.admission_fingerprint(),
            "admission_deferrals": self.sched.deferred,
            "peak_pages_used": self.pages.peak_pages_used,
            "kv_pool_bytes": self.kv_pool_bytes(),
            "kv_peak_bytes": self.kv_resident_bytes(
                self.pages.peak_pages_used),
            "dense_equiv_bytes": self.dense_equiv_bytes(),
        }

    def kv_pool_bytes(self) -> int:
        return kv_pool_bytes(self.cfg, n_pages=self.n_pages,
                             page_size=self.page_size, dtype=self._dtype)

    def kv_resident_bytes(self, n_used: int) -> int:
        return kv_pool_bytes(self.cfg, n_pages=n_used,
                             page_size=self.page_size, dtype=self._dtype)

    def dense_equiv_bytes(self) -> int:
        return dense_kv_bytes(
            self.cfg, n_seqs=self.pages.max_seqs,
            s_max=self.pages.max_pages_per_seq * self.page_size,
            dtype=self._dtype)
