"""Host-side paged KV-cache bookkeeping (jax-free, like ``repro.obs``).

The device-side KV store is a pool of fixed-size *physical pages*
(``(n_units, 1 + n_pages, page_size, KV, dh)`` per segment — see
``serve.engine.init_kv_pages``); one page index addresses the same slot in
every layer's store, so a single free list and a single per-sequence page
table serve the whole stack (vLLM layout).

Contract:

* **Physical page 0 is the reserved scratch page.** It is never in the
  free list; inactive engine slots route their KV writes there, and
  unallocated page-table entries point at it (reads are killed by the
  position mask, see serve/README.md).
* ``admit(slot, total)`` *reserves* the worst case
  ``ceil(total / page_size)`` pages up front but allocates none; physical
  pages are taken lazily by ``ensure(slot, length)`` as the sequence
  crosses page boundaries. Admission is refused while the reservation does
  not fit in the unreserved free pool, so a mid-decode ``ensure`` can
  never fail: the engine gets a never-OOM guarantee with no preemption.
* ``release(slot)`` returns owned pages (and any untouched reservation)
  to the pool on EOS / length-cap finish.

``check_partition`` asserts the invariant the property tests drive: the
free list and the union of per-slot owned pages always partition
``{1..n_pages}`` exactly, and outstanding reservations never exceed the
free pool.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List

import numpy as np


class PageManager:
    def __init__(self, n_pages: int, page_size: int, max_seqs: int,
                 max_pages_per_seq: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError((n_pages, page_size))
        self.n_pages = int(n_pages)          # usable pages (scratch excluded)
        self.page_size = int(page_size)
        self.max_seqs = int(max_seqs)
        self.max_pages_per_seq = int(max_pages_per_seq)
        # FIFO free list keeps allocation order deterministic
        self._free: deque = deque(range(1, self.n_pages + 1))
        self._owned: Dict[int, List[int]] = {}
        self._reserved: Dict[int, int] = {}
        self.page_table = np.zeros((self.max_seqs, self.max_pages_per_seq),
                                   np.int32)
        self.peak_pages_used = 0

    # -- accounting --------------------------------------------------------
    def pages_needed(self, total_len: int) -> int:
        return -(-int(total_len) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return sum(len(v) for v in self._owned.values())

    @property
    def reserved_pages(self) -> int:
        return sum(self._reserved.values())

    # -- admission ---------------------------------------------------------
    def can_admit(self, total_len: int) -> bool:
        need = self.pages_needed(total_len)
        return (need <= self.max_pages_per_seq
                and need <= self.free_pages - self.reserved_pages)

    def admit(self, slot: int, total_len: int) -> None:
        """Reserve worst-case pages for a sequence of ``total_len`` tokens."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already admitted")
        if not self.can_admit(total_len):
            raise ValueError(f"cannot admit {total_len} tokens "
                             f"(free={self.free_pages}, "
                             f"reserved={self.reserved_pages})")
        self._owned[slot] = []
        self._reserved[slot] = self.pages_needed(total_len)

    # -- growth / release --------------------------------------------------
    def ensure(self, slot: int, length: int) -> int:
        """Make sure the page holding token position ``length`` of ``slot``
        is allocated; returns its physical page id. Called once per active
        slot per engine step (extend-on-decode)."""
        owned = self._owned[slot]
        page_idx = int(length) // self.page_size
        if page_idx > len(owned):
            raise ValueError(f"slot {slot}: position {length} skips a page")
        if page_idx == len(owned):
            if self._reserved[slot] <= 0:
                raise ValueError(f"slot {slot}: grew past its reservation")
            phys = self._free.popleft()      # cannot fail: reservation held
            owned.append(phys)
            self._reserved[slot] -= 1
            self.page_table[slot, page_idx] = phys
            self.peak_pages_used = max(self.peak_pages_used, self.used_pages)
        return owned[page_idx]

    def release(self, slot: int) -> None:
        for phys in self._owned.pop(slot):
            self._free.append(phys)
        self._reserved.pop(slot, None)       # untouched reservation lapses
        self.page_table[slot, :] = 0

    # -- invariants --------------------------------------------------------
    def check_partition(self) -> None:
        free = set(self._free)
        owned = [p for v in self._owned.values() for p in v]
        assert len(free) == len(self._free), "duplicate page in free list"
        assert len(owned) == len(set(owned)), "page owned twice"
        assert 0 not in free and 0 not in owned, "scratch page handed out"
        assert free | set(owned) == set(range(1, self.n_pages + 1)), \
            "free + owned does not partition the pool"
        assert not (free & set(owned)), "page both free and owned"
        assert self.reserved_pages <= self.free_pages, \
            "reservations exceed the free pool"
        for slot, pages in self._owned.items():
            for idx, phys in enumerate(pages):
                assert self.page_table[slot, idx] == phys, \
                    f"page table desync at slot {slot} page {idx}"
            assert (self.page_table[slot, len(pages):] == 0).all(), \
                f"stale table entries for slot {slot}"
