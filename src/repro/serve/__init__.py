"""Paged KV-cache + continuous-batching serving engine (see README.md).

``pages``/``scheduler`` are jax-free host-side bookkeeping; ``engine``
builds the jit-shape-stable paged decode step on top of
``attention_paged`` and ties the three together behind ``ServeEngine``.
"""
from repro.serve.pages import PageManager
from repro.serve.scheduler import (DECODE, DONE, PREFILL, WAITING, Request,
                                   Scheduler)

__all__ = ["PageManager", "Request", "Scheduler",
           "WAITING", "PREFILL", "DECODE", "DONE"]
