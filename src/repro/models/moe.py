"""Mixture-of-Experts layer: top-k router, shared experts, dense residual.

Dispatch is GShard-style capacity scatter/gather over token-major slots.
SPMD history (§Perf hillclimb B, EXPERIMENTS.md): the baseline leaked
1.34 GB f32 per inner step across the *cluster* (1 Gbps) boundary. The
culprit was ``lax.top_k`` (GSPMD replicates its operand across every
sharded dim, clusters included) — replaced by ``topk_spmd`` below. A
per-row grouped dispatch with a vmapped scatter was also tried and
REVERTED: GSPMD replicated the batched scatter operands in f32 over the
data axis (83 -> 309 GB/device). The flat scatter partitions fine.

Memory is O(T*k*cf*d) for the expert buffer — the inherent dispatched
volume; never O(E*T*d).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_swiglu, dense_init, init_swiglu,
                                 shard_act, split)


def init_experts(key, n_experts: int, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = split(key, 3)

    def e_init(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dtype))(
            jax.random.split(k, n_experts))

    return {"w_gate": e_init(k1, d, d_ff),
            "w_up": e_init(k2, d, d_ff),
            "w_down": e_init(k3, d_ff, d)}


def init_moe(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    ks = split(key, 4)
    p = {"router": dense_init(ks[0], cfg.d_model, m.n_experts, dtype),
         "experts": init_experts(ks[1], m.n_experts, cfg.d_model,
                                 m.d_ff_expert, dtype)}
    if m.n_shared_experts:
        p["shared"] = init_swiglu(ks[2], cfg.d_model,
                                  m.d_ff_expert * m.n_shared_experts, dtype)
    if m.dense_residual:
        p["dense"] = init_swiglu(ks[3], cfg.d_model, m.d_ff_dense, dtype)
    return p


def topk_spmd(x, k: int):
    """Iterative top-k over the last dim using only elementwise ops +
    reductions. ``lax.top_k`` has no useful SPMD partitioning: GSPMD
    all-gathers the operand over every sharded dim INCLUDING the cluster
    axis (measured: 1.34 GB f32 per step crossing the 1 Gbps boundary for
    deepseek's router — §Perf hillclimb B iter 3). k is 2-6 for the
    assigned MoEs, so k masked max-passes are cheap and fully local."""
    E = x.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    masked = x
    vals, idxs = [], []
    for _ in range(k):
        v = masked.max(axis=-1, keepdims=True)
        is_max = masked == v
        idx = jnp.min(jnp.where(is_max, iota, E), axis=-1)
        vals.append(v[..., 0])
        idxs.append(idx)
        masked = jnp.where(iota == idx[..., None], -jnp.inf, masked)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def apply_moe(p, x, cfg):
    """x: (B,S,d). Returns (out, router_aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    T = B * S
    Cg = max(k, int(T * k * m.capacity_factor / E))   # global capacity
    xt = x.reshape(T, d)
    logits = (xt @ p["router"]).astype(jnp.float32)   # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = topk_spmd(probs, k)              # (T,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # GShard position-in-expert: cumsum over token-major flattened slots.
    # (A per-row grouped variant with a vmapped scatter was tried as
    # hillclimb B iter 2 — GSPMD replicated the batched scatter operands
    # in f32 over the data axis, 4x worse memory. The flat scatter
    # partitions fine; the cross-cluster leak was lax.top_k all along.)
    flat_e = top_idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (Tk,E)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    my_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = (my_pos < Cg)
    dest = jnp.where(keep, flat_e * Cg + my_pos, E * Cg)

    upd = jnp.repeat(xt, k, axis=0)                   # (Tk,d)
    buf = jnp.zeros((E * Cg + 1, d), x.dtype).at[dest].add(
        upd * keep[:, None].astype(x.dtype))
    xe = buf[: E * Cg].reshape(E, Cg, d)

    h = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                    p["experts"]["w_down"]).reshape(E * Cg, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)

    gathered = ye[dest]                               # (Tk,d)
    wts = (top_w.reshape(T * k).astype(x.dtype)
           * keep.astype(x.dtype))[:, None]
    out = (gathered * wts).reshape(T, k, d).sum(axis=1).reshape(B, S, d)

    # Switch-style load-balance aux loss
    me = probs.mean(axis=0)
    ce = (onehot.astype(jnp.float32).reshape(T, k, E).sum(1).mean(0)
          / max(k, 1))
    aux = E * jnp.sum(me * ce) * m.router_aux_weight

    # shared/dense paths operate on (B,S,d) directly: reshaping to (B*S,d)
    # merged the sharded batch dim and GSPMD replicated the merged tensor
    # across clusters (1.34 GB f32 on the 1 Gbps boundary per inner step —
    # §Perf hillclimb B iter 2).
    if "shared" in p:
        out = out + apply_swiglu(p["shared"], x)
    if "dense" in p:
        out = out + apply_swiglu(p["dense"], x)
    return out, aux
