"""State-space blocks: Mamba2 (chunked SSD) and xLSTM (mLSTM + sLSTM).

TPU adaptation notes (DESIGN.md §3): the Mamba2 recurrence is computed in
the chunked matmul form (intra-chunk quadratic with decay masks + inter-chunk
scan), which maps onto the MXU instead of a length-S sequential scan. mLSTM
uses its stabilized parallel form with query chunking; sLSTM is inherently
sequential and uses ``lax.scan`` over time (it is 1/8 of xLSTM layers).
Decode paths are O(1)-state recurrent steps, which is what makes these
families ``long_500k``-eligible.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (dense_init, split, init_norm, apply_norm,
                                 shard_act)


# ---------------------------------------------------------------------------
# causal depthwise conv1d (kernel k, channels last)
# ---------------------------------------------------------------------------

def init_conv1d(key, channels: int, k: int, dtype=jnp.float32):
    w = jax.random.normal(key, (k, channels), jnp.float32) / math.sqrt(k)
    return {"w": w.astype(dtype), "b": jnp.zeros((channels,), dtype)}


def apply_conv1d(p, x):
    """x: (B,S,C) -> causal depthwise conv."""
    k = p["w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * p["w"][i] for i in range(k))
    return out + p["b"]


def conv1d_step(p, buf, x1):
    """buf: (B,k-1,C) past inputs; x1: (B,1,C). Returns (y1, new_buf)."""
    k = p["w"].shape[0]
    window = jnp.concatenate([buf, x1], axis=1)          # (B,k,C)
    y = jnp.einsum("bkc,kc->bc", window, p["w"]) + p["b"]
    return y[:, None, :], window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------

def mamba2_dims(d_model: int, ssm):
    d_inner = ssm.expand * d_model
    head_dim = 64 if d_inner % 64 == 0 else max(8, d_inner // 8)
    nh = ssm.n_ssm_heads or d_inner // head_dim
    head_dim = d_inner // nh
    return d_inner, nh, head_dim


def init_mamba2(key, d_model: int, ssm, dtype=jnp.float32):
    d_inner, nh, hd = mamba2_dims(d_model, ssm)
    ds = ssm.d_state
    ks = split(key, 4)
    d_in_proj = 2 * d_inner + 2 * ds + nh   # [z, x, B, C, dt]
    return {
        "in_proj": dense_init(ks[0], d_model, d_in_proj, dtype),
        "conv": init_conv1d(ks[1], d_inner + 2 * ds, 4, dtype),
        "A_log": jnp.zeros((nh,), dtype),            # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "gate_norm": init_norm("rmsnorm", d_inner, dtype),
        "out_proj": dense_init(ks[2], d_inner, d_model, dtype),
    }


def _mamba2_split(p, u, d_inner, ds, nh):
    zxbcdt = u @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * ds], axis=-1)
    return z, xBC, dt


def _ssd_chunk_scan(x, dtv, a_log, Bm, Cm, chunk: int):
    """Chunked SSD. x: (B,S,nh,hd); dtv: (B,S,nh) (already softplus'ed);
    a_log: (B,S,nh) = A*dt (log decay, negative); Bm, Cm: (B,S,ds).
    Returns y: (B,S,nh,hd)."""
    Bsz, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    nc = S // chunk
    L = chunk
    xc = x.reshape(Bsz, nc, L, nh, hd)
    dc = dtv.reshape(Bsz, nc, L, nh)
    ac = a_log.reshape(Bsz, nc, L, nh)
    Bc = Bm.reshape(Bsz, nc, L, ds)
    Cc = Cm.reshape(Bsz, nc, L, ds)

    la = jnp.cumsum(ac, axis=2)                          # (B,nc,L,nh)
    # intra-chunk: Y[i] += sum_{s<=i} exp(la_i - la_s) dt_s (C_i.B_s) x_s
    G = jnp.einsum("bnld,bnsd->bnls", Cc, Bc)            # (B,nc,L,L)
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]    # (B,nc,L,L,nh)
    mask = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    Y_intra = jnp.einsum("bnlsh,bnls,bnsh,bnshd->bnlhd",
                         M, G, dc, xc)
    # chunk-end states and inter-chunk scan
    decay_end = jnp.exp(la[:, :, -1:, :] - la)           # (B,nc,L,nh)
    states = jnp.einsum("bnlh,bnlh,bnlhd,bnls->bnhds",
                        decay_end, dc, xc, Bc)           # (B,nc,nh,hd,ds)
    chunk_decay = jnp.exp(la[:, :, -1, :])               # (B,nc,nh)

    def scan_fn(h, inp):
        st, cd = inp                                     # (B,nh,hd,ds), (B,nh)
        h_new = h * cd[:, :, None, None] + st
        return h_new, h                                  # emit state *entering* chunk

    h0 = jnp.zeros((Bsz, nh, hd, ds), x.dtype)
    _, h_in = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                 # (B,nc,nh,hd,ds)
    Y_inter = jnp.einsum("bnlh,bnls,bnhds->bnlhd",
                         jnp.exp(la), Cc, h_in)
    return (Y_intra + Y_inter).reshape(Bsz, S, nh, hd)


def apply_mamba2(p, x, ssm, *, d_model: int):
    """x: (B,S,d) -> (B,S,d)."""
    d_inner, nh, hd = mamba2_dims(d_model, ssm)
    ds = ssm.d_state
    z, xBC, dt_raw = _mamba2_split(p, x, d_inner, ds, nh)
    xBC = jax.nn.silu(apply_conv1d(p["conv"], xBC))
    xi, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + ds], axis=-1)
    dtv = jax.nn.softplus(dt_raw + p["dt_bias"])         # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # (nh,)
    a_log = dtv * A                                      # (B,S,nh)
    xh = xi.reshape(*xi.shape[:2], nh, hd)
    S = x.shape[1]
    chunk = ssm.chunk if S % ssm.chunk == 0 else S
    y = _ssd_chunk_scan(xh.astype(jnp.float32), dtv, a_log,
                        Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    y = apply_norm(p["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    return y @ p["out_proj"]


def init_mamba2_state(batch: int, d_model: int, ssm, dtype=jnp.float32):
    d_inner, nh, hd = mamba2_dims(d_model, ssm)
    ds = ssm.d_state
    return {"conv_buf": jnp.zeros((batch, 3, d_inner + 2 * ds), dtype),
            "h": jnp.zeros((batch, nh, hd, ds), dtype)}


def decode_mamba2(p, x1, state, ssm, *, d_model: int):
    """Single-token recurrent step. x1: (B,1,d)."""
    d_inner, nh, hd = mamba2_dims(d_model, ssm)
    ds = ssm.d_state
    z, xBC, dt_raw = _mamba2_split(p, x1, d_inner, ds, nh)
    xBC, conv_buf = conv1d_step(p["conv"], state["conv_buf"], xBC)
    xBC = jax.nn.silu(xBC)
    xi, Bm, Cm = jnp.split(xBC[:, 0], [d_inner, d_inner + ds], axis=-1)
    dtv = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"])   # (B,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dtv * A)                                 # (B,nh)
    xh = xi.reshape(-1, nh, hd)
    h = (state["h"] * a[:, :, None, None]
         + jnp.einsum("bh,bhd,bs->bhds", dtv, xh, Bm))
    y = jnp.einsum("bhds,bs->bhd", h, Cm) + xh * p["D"][None, :, None]
    y = y.reshape(-1, 1, d_inner).astype(x1.dtype)
    y = apply_norm(p["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    return y @ p["out_proj"], {"conv_buf": conv_buf, "h": h}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (parallel, stabilized) and sLSTM (sequential)
# ---------------------------------------------------------------------------

def mlstm_dims(d_model: int, ssm):
    d_inner = ssm.expand * d_model
    nh = 4
    return d_inner, nh, d_inner // nh


def init_mlstm(key, d_model: int, ssm, dtype=jnp.float32):
    d_inner, nh, hd = mlstm_dims(d_model, ssm)
    ks = split(key, 8)
    return {
        "up_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv": init_conv1d(ks[1], d_inner, 4, dtype),
        # per-head block-diagonal q/k/v (xLSTM paper; keeps 1.3B nameplate)
        "wq": (jax.random.normal(ks[2], (nh, hd, hd), jnp.float32)
               / math.sqrt(hd)).astype(dtype),
        "wk": (jax.random.normal(ks[3], (nh, hd, hd), jnp.float32)
               / math.sqrt(hd)).astype(dtype),
        "wv": (jax.random.normal(ks[4], (nh, hd, hd), jnp.float32)
               / math.sqrt(hd)).astype(dtype),
        "w_if": dense_init(ks[5], d_inner, 2 * nh, dtype),
        "skip": jnp.ones((d_inner,), dtype),
        "out_norm": init_norm("rmsnorm", d_inner, dtype),
        "down_proj": dense_init(ks[6], d_inner, d_model, dtype),
    }


def _mlstm_parallel(q, k, v, i_pre, f_pre, chunk: int = 512):
    """Stabilized parallel mLSTM, query-chunked. q,k,v: (B,S,nh,hd);
    i_pre,f_pre: (B,S,nh). Returns h: (B,S,nh,hd).

    The decay matrix D[t,s] = exp(F_t - F_s + i_s - m_t) factors through
    1-D cumulative quantities (F = cumsum log f, m = F + cummax(i - F)),
    so it can be built PER QUERY CHUNK: peak memory is (B, cq, S, nh)
    instead of (B, S, S, nh) — at 4k train that is the difference between
    a 17 GB/device buffer GSPMD replicates across clusters (412 GB of
    cross-cluster all-gather in the baseline dry-run) and a chunk that
    stays local. Backward recomputes per chunk (jax.checkpoint),
    flash-style. [EXPERIMENTS.md §Perf hillclimb A]"""
    B, S, nh, hd = q.shape
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))     # (B,S,nh)
    F = jnp.cumsum(logf, axis=1)                             # (B,S,nh)
    g = i_pre.astype(jnp.float32) - F
    m = F + jax.lax.cummax(g, axis=1)                        # (B,S,nh)
    scale = 1.0 / math.sqrt(hd)
    # NOTE [hillclimb A iter 3, REFUTED]: context-parallel keys (S over
    # "model" for k/v/gates) predicted ~8x less ICI via s-contraction
    # psums, but measured 2.47s -> 3.37s: GSPMD re-gathers the sharded
    # keys for the masked-decay einsum inside the chunk loop. Reverted.
    i_f = i_pre.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def block(q_blk, F_blk, m_blk, t0):
        # q_blk: (B,cq,nh,hd); F_blk, m_blk: (B,cq,nh); keys: full prefix
        cq = q_blk.shape[1]
        logD = (F_blk[:, :, None, :] - F[:, None, :, :]
                + i_f[:, None, :, :]
                - m_blk[:, :, None, :])                      # (B,cq,S,nh)
        t_pos = t0 + jnp.arange(cq)[:, None]
        s_pos = jnp.arange(S)[None, :]
        D = jnp.where((s_pos <= t_pos)[None, :, :, None],
                      jnp.exp(logD), 0.0)
        Sc = jnp.einsum("bthd,bshd->btsh", q_blk.astype(jnp.float32),
                        kf) * scale
        Sd = shard_act(Sc * D, "act4")
        norm = jnp.maximum(jnp.abs(Sd.sum(axis=2)), jnp.exp(-m_blk))
        h = jnp.einsum("btsh,bshd->bthd", Sd, vf)
        return shard_act((h / norm[:, :, :, None]).astype(q.dtype), "act4")

    if chunk and S > chunk and S % chunk == 0:
        n = S // chunk
        qc = q.reshape(B, n, chunk, nh, hd).transpose(1, 0, 2, 3, 4)
        Fc = F.reshape(B, n, chunk, nh).transpose(1, 0, 2, 3)
        mc = m.reshape(B, n, chunk, nh).transpose(1, 0, 2, 3)
        t0s = jnp.arange(n) * chunk
        blk = jax.checkpoint(block)
        hc = jax.lax.map(lambda args: blk(*args), (qc, Fc, mc, t0s))
        return hc.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, hd)
    return block(q, F, m, 0)


def apply_mlstm(p, x, ssm, *, d_model: int):
    d_inner, nh, hd = mlstm_dims(d_model, ssm)
    uz = x @ p["up_proj"]
    u, z = jnp.split(uz, 2, axis=-1)
    c = jax.nn.silu(apply_conv1d(p["conv"], u))
    B, S = x.shape[:2]
    ch = c.reshape(B, S, nh, hd)
    uh = u.reshape(B, S, nh, hd)
    q = shard_act(jnp.einsum("bshd,hde->bshe", ch, p["wq"]), "act4")
    k = shard_act(jnp.einsum("bshd,hde->bshe", ch, p["wk"]), "act4")
    v = shard_act(jnp.einsum("bshd,hde->bshe", uh, p["wv"]), "act4")
    if_pre = c @ p["w_if"]
    i_pre, f_pre = jnp.split(if_pre.reshape(B, S, 2, nh), 2, axis=2)
    h = _mlstm_parallel(q, k, v, i_pre[:, :, 0], f_pre[:, :, 0])
    h = h.reshape(B, S, d_inner) + p["skip"] * c
    h = apply_norm(p["out_norm"], h, "rmsnorm")
    return (h * jax.nn.silu(z)) @ p["down_proj"]


def init_mlstm_state(batch: int, d_model: int, ssm, dtype=jnp.float32):
    d_inner, nh, hd = mlstm_dims(d_model, ssm)
    return {"conv_buf": jnp.zeros((batch, 3, d_inner), dtype),
            "C": jnp.zeros((batch, nh, hd, hd), dtype),
            "n": jnp.zeros((batch, nh, hd), dtype),
            "m": jnp.full((batch, nh), -1e30, dtype)}


def decode_mlstm(p, x1, state, ssm, *, d_model: int):
    d_inner, nh, hd = mlstm_dims(d_model, ssm)
    B = x1.shape[0]
    uz = x1 @ p["up_proj"]
    u, z = jnp.split(uz, 2, axis=-1)
    c, conv_buf = conv1d_step(p["conv"], state["conv_buf"], u)
    c = jax.nn.silu(c)
    ch = c.reshape(B, nh, hd)
    uh = u[:, 0].reshape(B, nh, hd)
    q = jnp.einsum("bhd,hde->bhe", ch, p["wq"])
    k = jnp.einsum("bhd,hde->bhe", ch, p["wk"])
    v = jnp.einsum("bhd,hde->bhe", uh, p["wv"])
    if_pre = (c @ p["w_if"]).reshape(B, 2, nh)
    i_pre, f_pre = if_pre[:, 0].astype(jnp.float32), if_pre[:, 1].astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"].astype(jnp.float32), i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + state["m"].astype(jnp.float32) - m_new)
    scale = 1.0 / math.sqrt(hd)
    C = (state["C"].astype(jnp.float32) * f_g[..., None, None]
         + i_g[..., None, None] * jnp.einsum("bhd,bhe->bhde", v, k * scale))
    n = (state["n"].astype(jnp.float32) * f_g[..., None]
         + i_g[..., None] * k * scale)
    num = jnp.einsum("bhde,bhe->bhd", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n, q)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, d_inner).astype(x1.dtype)
    h = h + p["skip"] * c
    h = apply_norm(p["out_norm"], h, "rmsnorm")
    out = (h * jax.nn.silu(z)) @ p["down_proj"]
    new_state = {"conv_buf": conv_buf, "C": C.astype(state["C"].dtype),
                 "n": n.astype(state["n"].dtype),
                 "m": m_new.astype(state["m"].dtype)}
    return out, new_state


def init_slstm(key, d_model: int, ssm, dtype=jnp.float32):
    nh = 4
    hd = d_model // nh
    ks = split(key, 4)
    return {
        "conv": init_conv1d(ks[0], d_model, 4, dtype),
        "w_gates": dense_init(ks[1], d_model, 4 * d_model, dtype),  # i,f,z,o
        "r_gates": (jax.random.normal(ks[2], (nh, hd, 4 * hd), jnp.float32)
                    / math.sqrt(hd)).astype(dtype),  # block-diag recurrent
        "out_norm": init_norm("rmsnorm", d_model, dtype),
        "w_up": dense_init(ks[3], d_model, int(d_model * 4 / 3) // 2 * 2, dtype),
        "w_down": dense_init(split(key, 5)[4], int(d_model * 4 / 3) // 2 * 2,
                             d_model, dtype),
    }


def _slstm_cell(p, xg, hcnm, nh, hd):
    """One time step. xg: (B,4*d) pre-activations from input path."""
    h, c, n, m = hcnm
    B = h.shape[0]
    rec = jnp.einsum("bhd,hdk->bhk", h.reshape(B, nh, hd), p["r_gates"])
    g = xg.reshape(B, nh, 4 * hd) + rec
    i_pre, f_pre, z_pre, o_pre = jnp.split(g, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, i_pre.astype(jnp.float32))
    i_g = jnp.exp(i_pre.astype(jnp.float32) - m_new)
    f_g = jnp.exp(logf + m - m_new)
    z = jnp.tanh(z_pre.astype(jnp.float32))
    o = jax.nn.sigmoid(o_pre.astype(jnp.float32))
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1e-6)
    return (h_new, c_new, n_new, m_new)


def apply_slstm(p, x, ssm, *, d_model: int):
    nh = 4
    hd = d_model // nh
    B, S, _ = x.shape
    xc = jax.nn.silu(apply_conv1d(p["conv"], x))
    xg = xc @ p["w_gates"]                               # (B,S,4d)

    h0 = jnp.zeros((B, nh, hd), jnp.float32)
    init = (h0, h0, h0, jnp.full((B, nh, hd), -1e30, jnp.float32))

    def step(carry, xg_t):
        new = _slstm_cell(p, xg_t, carry, nh, hd)
        return new, new[0]

    _, hs = jax.lax.scan(step, init, xg.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d_model).astype(x.dtype)
    h = apply_norm(p["out_norm"], h, "rmsnorm")
    up = h @ p["w_up"]
    return jax.nn.gelu(up) @ p["w_down"]


def init_slstm_state(batch: int, d_model: int, ssm, dtype=jnp.float32):
    nh = 4
    hd = d_model // nh
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"conv_buf": jnp.zeros((batch, 3, d_model), dtype),
            "h": z, "c": z, "n": z, "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}


def decode_slstm(p, x1, state, ssm, *, d_model: int):
    nh = 4
    hd = d_model // nh
    xc, conv_buf = conv1d_step(p["conv"], state["conv_buf"], x1)
    xc = jax.nn.silu(xc)
    xg = (xc @ p["w_gates"])[:, 0]
    carry = (state["h"], state["c"], state["n"], state["m"])
    h_new, c_new, n_new, m_new = _slstm_cell(p, xg, carry, nh, hd)
    h = h_new.reshape(-1, 1, d_model).astype(x1.dtype)
    h = apply_norm(p["out_norm"], h, "rmsnorm")
    out = jax.nn.gelu(h @ p["w_up"]) @ p["w_down"]
    return out, {"conv_buf": conv_buf, "h": h_new, "c": c_new,
                 "n": n_new, "m": m_new}
