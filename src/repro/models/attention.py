"""Attention flavours: GQA (+RoPE/M-RoPE/sliding-window), DeepSeek-V2 MLA,
cross-attention, with train/prefill and cached single-token decode paths.

Long sequences use a query-chunked formulation so the (Sq, Sk) score matrix
never materialises at full size (peak is (chunk, Sk)); the Pallas flash
kernel in ``repro.kernels.flash_attention`` is the TPU hot-spot version and
``repro.models.attention`` is its semantic reference.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_mrope, apply_rope, dense_init, split)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mask construction (position-id based, chunk friendly)
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: int) -> jnp.ndarray:
    """Returns additive bias (..., Sq, Sk). window==0 -> no sliding limit."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        ok = ok & (kp <= qp)
    if window > 0:
        ok = ok & (kp > qp - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def grouped_attend(q, k, v, bias, *, chunk: int = 0,
                   plain_causal: bool = False) -> jnp.ndarray:
    """GQA core. q: (B,Sq,H,dh); k,v: (B,Sk,KV,dh[v]); bias: (B|1,Sq,Sk) or
    (Sq,Sk) additive. Returns (B,Sq,H,dv).

    plain_causal=True marks a pure causal self-attention call (no window,
    qk dims equal) — eligible for the Pallas flash kernel when
    REPRO_USE_PALLAS=1 (kernels/flash_attention; validated vs this code)."""
    import os
    if (plain_causal and os.environ.get("REPRO_USE_PALLAS", "0") == "1"
            and q.shape[1] == k.shape[1] and q.shape[-1] == v.shape[-1]
            and q.shape[1] % 128 == 0):
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=True)
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, Sq, KV, G, dh)
    if bias.ndim == 2:
        bias = bias[None]

    def _block(q_blk, bias_blk):
        # q_blk (B,cq,KV,G,dh) ; bias_blk (B|1,cq,Sk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        s = s + bias_blk[:, None, None, :, :]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
        return o

    if chunk and Sq > chunk and Sq % chunk == 0:
        n = Sq // chunk
        qc = qg.reshape(B, n, chunk, KV, G, dh).transpose(1, 0, 2, 3, 4, 5)
        bc = bias.reshape(bias.shape[0], n, chunk, -1).transpose(1, 0, 2, 3)
        # checkpoint per chunk: backward recomputes the (cq,Sk) scores
        # instead of stashing every chunk's f32 scores as scan residuals
        blk = jax.checkpoint(_block)
        oc = jax.lax.map(lambda args: blk(*args), (qc, bc))
        o = oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, v.shape[-1])
    else:
        o = _block(qg, bias)
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             dtype=jnp.float32):
    k1, k2, k3, k4 = split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_heads * head_dim, dtype),
        "wk": dense_init(k2, d_model, n_kv * head_dim, dtype),
        "wv": dense_init(k3, d_model, n_kv * head_dim, dtype),
        "wo": dense_init(k4, n_heads * head_dim, d_model, dtype),
    }


def _qkv(p, x, n_heads, n_kv, head_dim):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, S, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(B, S, n_kv, head_dim)
    return q, k, v


def apply_gqa(p, x, positions, *, n_heads, n_kv, head_dim, rope_theta,
              causal=True, window=0, chunk=0, mrope_positions=None,
              mrope_sections=None) -> jnp.ndarray:
    """Self-attention over a full sequence (train / prefill)."""
    q, k, v = _qkv(p, x, n_heads, n_kv, head_dim)
    if mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, rope_theta, mrope_sections)
        k = apply_mrope(k, mrope_positions, rope_theta, mrope_sections)
    else:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    bias = _mask_bias(positions, positions, causal=causal, window=window)
    o = grouped_attend(q, k, v, bias, chunk=chunk,
                       plain_causal=(causal and window == 0))
    return o.reshape(*x.shape[:2], -1) @ p["wo"]


def decode_gqa(p, x1, cache, index, *, n_heads, n_kv, head_dim, rope_theta,
               window=0, mrope_positions=None, mrope_sections=None):
    """One-token decode. x1: (B,1,d). cache: {"k","v"}: (B,Smax,KV,dh).
    index: scalar current position. Returns (out (B,1,d), new_cache)."""
    B = x1.shape[0]
    q, k_new, v_new = _qkv(p, x1, n_heads, n_kv, head_dim)
    pos = jnp.full((B, 1), index, dtype=jnp.int32)
    if mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, rope_theta, mrope_sections)
        k_new = apply_mrope(k_new, mrope_positions, rope_theta, mrope_sections)
    else:
        q = apply_rope(q, pos, rope_theta)
        k_new = apply_rope(k_new, pos, rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), index, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), index, axis=1)
    k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)[None, :]
    bias = _mask_bias(pos, k_pos, causal=True, window=window)
    o = grouped_attend(q, k, v, bias)
    out = o.reshape(B, 1, -1) @ p["wo"]
    return out, {"k": k, "v": v}


def init_gqa_cache(batch, s_max, n_kv, head_dim, *, window=0, dtype=jnp.float32):
    """Full-length cache for global layers; ring buffer of size `window`
    (plus a slot-position array) for sliding-window layers, so a 500k-context
    decode keeps O(window) memory on local layers."""
    if window > 0 and window < s_max:
        return {"k": jnp.zeros((batch, window, n_kv, head_dim), dtype),
                "v": jnp.zeros((batch, window, n_kv, head_dim), dtype),
                "pos": jnp.full((batch, window), -1, jnp.int32)}
    return {"k": jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
            "v": jnp.zeros((batch, s_max, n_kv, head_dim), dtype)}


def decode_gqa_ring(p, x1, cache, index, *, n_heads, n_kv, head_dim,
                    rope_theta):
    """Sliding-window decode against a ring buffer. The `pos` array tracks
    which absolute position each slot holds; all stored positions are within
    the window by construction, so the only mask is slot-validity."""
    B = x1.shape[0]
    W = cache["k"].shape[1]
    q, k_new, v_new = _qkv(p, x1, n_heads, n_kv, head_dim)
    pos = jnp.full((B, 1), index, dtype=jnp.int32)
    q = apply_rope(q, pos, rope_theta)
    k_new = apply_rope(k_new, pos, rope_theta)
    slot = jnp.mod(index, W)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    pos_arr = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((B, 1), index, jnp.int32), slot, axis=1)
    bias = jnp.where(pos_arr >= 0, 0.0, NEG_INF).astype(jnp.float32)[:, None, :]
    o = grouped_attend(q, k, v, bias)
    out = o.reshape(B, 1, -1) @ p["wo"]
    return out, {"k": k, "v": v, "pos": pos_arr}


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec)
# ---------------------------------------------------------------------------

def apply_cross(p, x, memory, *, n_heads, n_kv, head_dim):
    B, S, _ = x.shape
    Sm = memory.shape[1]
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    k = (memory @ p["wk"]).reshape(B, Sm, n_kv, head_dim)
    v = (memory @ p["wv"]).reshape(B, Sm, n_kv, head_dim)
    bias = jnp.zeros((1, S, Sm), jnp.float32)
    o = grouped_attend(q, k, v, bias)
    return o.reshape(B, S, -1) @ p["wo"]


def cross_kv(p, memory, *, n_kv, head_dim):
    B, Sm, _ = memory.shape
    k = (memory @ p["wk"]).reshape(B, Sm, n_kv, head_dim)
    v = (memory @ p["wv"]).reshape(B, Sm, n_kv, head_dim)
    return {"k": k, "v": v}


def decode_cross(p, x1, kv, *, n_heads, head_dim):
    B = x1.shape[0]
    q = (x1 @ p["wq"]).reshape(B, 1, n_heads, head_dim)
    bias = jnp.zeros((1, 1, kv["k"].shape[1]), jnp.float32)
    o = grouped_attend(q, kv["k"], kv["v"], bias)
    return o.reshape(B, 1, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# DeepSeek-V2 MLA (Multi-head Latent Attention)
# ---------------------------------------------------------------------------

def init_mla(key, d_model: int, n_heads: int, mla, dtype=jnp.float32):
    ks = split(key, 6)
    qd = mla.nope_head_dim + mla.rope_head_dim
    return {
        "wq_a": dense_init(ks[0], d_model, mla.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], mla.q_lora_rank, n_heads * qd, dtype),
        "wkv_a": dense_init(ks[2], d_model,
                            mla.kv_lora_rank + mla.rope_head_dim, dtype),
        "wkv_b": dense_init(ks[3], mla.kv_lora_rank,
                            n_heads * (mla.nope_head_dim + mla.v_head_dim), dtype),
        "wo": dense_init(ks[4], n_heads * mla.v_head_dim, d_model, dtype),
    }


def _mla_qkv(p, x, c_kv, k_rope_flat, positions, n_heads, mla, rope_theta):
    """Shared between prefill and decode. c_kv: (B,S,lora); k_rope_flat:
    (B,S,rope_dim) pre-RoPE'd latent rope key (shared across heads)."""
    B, Sq = x.shape[:2]
    qd = mla.nope_head_dim + mla.rope_head_dim
    q = ((x @ p["wq_a"]) @ p["wq_b"]).reshape(B, Sq, n_heads, qd)
    q_nope, q_rope = jnp.split(q, [mla.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, rope_theta)
    kv = (c_kv @ p["wkv_b"]).reshape(
        B, c_kv.shape[1], n_heads, mla.nope_head_dim + mla.v_head_dim)
    k_nope, v = jnp.split(kv, [mla.nope_head_dim], axis=-1)
    k_rope = jnp.broadcast_to(k_rope_flat[:, :, None, :],
                              (B, c_kv.shape[1], n_heads, mla.rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope], axis=-1)
    return q_full, k_full, v


def apply_mla(p, x, positions, *, n_heads, mla, rope_theta, chunk=0):
    B, S, _ = x.shape
    ckv_rope = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(ckv_rope, [mla.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0, :]
    q, k, v = _mla_qkv(p, x, c_kv, k_rope, positions, n_heads, mla, rope_theta)
    bias = _mask_bias(positions, positions, causal=True, window=0)
    o = grouped_attend(q, k, v, bias, chunk=chunk)
    return o.reshape(B, S, -1) @ p["wo"]


def init_mla_cache(batch, s_max, mla, dtype=jnp.float32):
    """The MLA cache stores only the compressed latent + shared rope key —
    the paper's memory win (kv_lora + rope_dim per token, not 2*H*dh)."""
    return {"c_kv": jnp.zeros((batch, s_max, mla.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, s_max, mla.rope_head_dim), dtype)}


def decode_mla(p, x1, cache, index, *, n_heads, mla, rope_theta):
    B = x1.shape[0]
    pos = jnp.full((B, 1), index, dtype=jnp.int32)
    ckv_rope = x1 @ p["wkv_a"]
    c_new, kr_new = jnp.split(ckv_rope, [mla.kv_lora_rank], axis=-1)
    kr_new = apply_rope(kr_new[:, :, None, :], pos, rope_theta)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), index, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), index, axis=1)
    q, k, v = _mla_qkv(p, x1, c_kv, k_rope, pos, n_heads, mla, rope_theta)
    k_pos = jnp.arange(c_kv.shape[1], dtype=jnp.int32)[None, :]
    bias = _mask_bias(pos, k_pos, causal=True, window=0)
    o = grouped_attend(q, k, v, bias)
    return o.reshape(B, 1, -1) @ p["wo"], {"c_kv": c_kv, "k_rope": k_rope}
